"""End-to-end training driver: a ~100M decoder-only LM on the synthetic
bigram stream, with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --steps 300          # ~100M
    PYTHONPATH=src python examples/train_lm.py --tiny --steps 60    # laptop
"""
import argparse
import time

import jax

from repro.configs.base import ModelConfig
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.runtime import FailoverConfig, Membership
from repro.train.step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def model_100m() -> ModelConfig:
    # ~110M params: 12L x 768, GQA 12/4, ff 3072, 32k vocab
    return ModelConfig(name="lm-100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=4,
                       d_ff=3072, vocab=32768, remat="none", loss_chunk=128)


def model_tiny() -> ModelConfig:
    return ModelConfig(name="lm-tiny", family="dense", num_layers=4,
                       d_model=128, num_heads=4, num_kv_heads=2,
                       d_ff=512, vocab=2048, remat="none", loss_chunk=64)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    model = Model(cfg)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    membership = Membership()
    for i in range(4):
        membership.request_join(f"10.0.0.{i}", 7000)

    trainer = Trainer(
        model,
        TrainerConfig(steps=args.steps, log_every=10,
                      train=TrainConfig(opt=adamw.OptConfig(
                          peak_lr=3e-4, warmup_steps=20,
                          total_steps=args.steps)),
                      failover=FailoverConfig(args.ckpt_dir,
                                              save_every_steps=50)),
        membership=membership, model_axis=1)

    data = Prefetcher(iter(SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=0))), depth=2)

    state = trainer.init_state(jax.random.PRNGKey(0))
    t0 = time.time()
    trainer.fit(state, data)
    for rec in trainer.history:
        print(rec)
    print(f"done in {time.time()-t0:.1f}s; "
          f"loss {trainer.history[0]['loss']:.3f} -> "
          f"{trainer.history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
