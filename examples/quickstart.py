"""Quickstart: the D1HT core in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import analysis, build_ring
from repro.core.tuning import EdraParams
from repro.dht import ChurnConfig, run_churn
from repro.kernels.ring_lookup.ops import ring_lookup

# 1. A consistent-hashing ring with full routing tables (paper §III)
ring = build_ring(1000, seed=0)
key = "checkpoint/step_420/shard_3"
print(f"owner of {key!r}: peer {ring.owner(key) % 10**6}")

# 2. Self-tuned EDRA parameters (paper §IV-D): every peer derives these
#    locally from the event rate it observes — no coordination.
p = EdraParams.derive(n=10**6, s_avg=174 * 60)
print(f"n=1e6 Gnutella: rho={p.rho} Theta={p.theta:.1f}s "
      f"T_detect={p.t_detect:.1f}s max_buffer={p.max_events:.0f} events")

# 3. Analytical maintenance traffic (paper Eq IV.5) vs the baselines
b = analysis.d1ht_bandwidth(10**6, 174 * 60)
c = analysis.calot_bandwidth(10**6, 174 * 60)
print(f"per-peer maintenance: D1HT={b/1e3:.1f} kbps, 1h-Calot={c/1e3:.1f} "
      f"kbps ({c/b:.0f}x)")

# 4. Protocol-level simulation: >99% one-hop lookups under churn (§VII)
r = run_churn(ChurnConfig(n=200, s_avg=174 * 60, duration=300, warmup=60,
                          protocol="d1ht", seed=1))
print(f"DES n=200: one-hop={r.one_hop_fraction*100:.2f}% "
      f"bandwidth sim/model={r.mean_out_bps/r.analytical_bps:.2f}")

# 5. The serving hot path: batched ring lookups via the Pallas kernel
table = np.sort(np.asarray([i >> 32 for i in ring.ids], np.uint32))
keys = np.random.default_rng(0).integers(0, 2**32, 4096, dtype=np.uint32)
idx = ring_lookup(jnp.asarray(keys), jnp.asarray(table))
print(f"ring_lookup kernel routed {len(keys)} keys; "
      f"first 5 -> peers {np.asarray(idx[:5]).tolist()}")
