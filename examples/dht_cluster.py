"""Live D1HT cluster demo: churn, failure detection, elastic re-meshing,
and real bytes surviving node crashes through the replicated data plane.

    PYTHONPATH=src python examples/dht_cluster.py

Exits nonzero if any stored block is lost, torn, or stale after the
induced failures — CI runs this as the data-plane smoke.
"""
import random
import sys

from repro.core.ring import RoutingTable, build_ring
from repro.core.tuning import EdraParams
from repro.dht.d1ht_node import D1HTPeer
from repro.dht.data import BlockStore
from repro.dht.des import LanDelay, SimNet
from repro.runtime import ElasticController, Membership, Placement

N = 64
net = SimNet(LanDelay(), seed=0)
params = EdraParams.derive(N, 174 * 60)
ids = list(build_ring(N, seed=0).ids)
for pid in ids:
    net.add_peer(D1HTPeer(pid, net, params))
net.ring = RoutingTable(ids)
rng = random.Random(1)
for pid in ids:
    p = net.peers[pid]
    p.table = RoutingTable(ids)
    net.schedule(rng.random() * params.theta, (lambda q: (lambda: q.start()))(p))
net.run_until(30)

# mirror protocol membership into the runtime control plane
membership = Membership()
for pid in ids:
    membership.admit(pid, ("10.0.0.1", 1117))
controller = ElasticController(membership, model_axis=4)
print(f"cluster up: {membership.size()} nodes, "
      f"mesh plan {controller.replan().data_axis}x4")

# replicated data plane over the same ring: put real bytes, 3 copies each
store = BlockStore(membership.ring_state, replication=3)
payloads = {f"demo/block-{i}": bytes(rng.getrandbits(8) for _ in range(256))
            for rng, i in ((random.Random(100 + i), i) for i in range(32))}
for name, value in payloads.items():
    store.put(name, value)
print(f"data plane: {len(payloads)} blocks stored, "
      f"{store.upload_bytes} upload bytes ({store.replication} copies each)")

# crash three nodes; EDRA disseminates, controller re-plans, and the
# data plane re-replicates exactly the blocks each victim held.  The
# victims are ring-ADJACENT (one whole replica group for their arc), so
# repair must run between detections — r simultaneous unrepaired crashes
# of one group is unrecoverable by construction.
repair = {"checked": 0, "repaired": 0, "copied_bytes": 0, "lost": 0}
for victim in ids[10:13]:
    net.peers[victim].stop(crash=True)
    net.ring.remove(victim)
    membership.fail(victim)
    store.drop_node(victim)            # crash destroys the local store
    for k, n in store.sync().items():  # detection-driven re-replication
        repair[k] += n
net.run_until(net.now + 20 * params.theta)
stale = sum(1 for pid in ids[13:20]
            if any(v in net.peers[pid].table for v in ids[10:13]))
plan = controller.plan
print(f"after 3 crashes: peers with stale entries={stale}, "
      f"new plan {plan.data_axis}x{plan.model_axis} "
      f"(dropped {len(plan.dropped)})")

bad = [name for name, value in payloads.items() if store.get(name) != value]
counts = store.replica_counts()
print(f"after 3 crashes: {len(payloads) - len(bad)}/{len(payloads)} blocks "
      f"intact, re-replication checked {repair['checked']} keys, copied "
      f"{repair['copied_bytes']} bytes, min live replicas "
      f"{min(counts.values())}")
if bad or min(counts.values()) < store.replication:
    print(f"DATA LOSS: bad={bad}, counts={counts}")
    sys.exit(1)

placement = Placement(membership.table)
print("placement balance:", placement.balance_stats(2048))
print("expert shards for 32 experts over 4 EP groups:",
      placement.expert_assignment(32, 4).tolist())
