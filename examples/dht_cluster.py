"""Live D1HT cluster demo: churn, failure detection, elastic re-meshing.

    PYTHONPATH=src python examples/dht_cluster.py
"""
import random

from repro.core.ring import RoutingTable, build_ring
from repro.core.tuning import EdraParams
from repro.dht.d1ht_node import D1HTPeer
from repro.dht.des import LanDelay, SimNet
from repro.runtime import ElasticController, Membership, Placement

N = 64
net = SimNet(LanDelay(), seed=0)
params = EdraParams.derive(N, 174 * 60)
ids = list(build_ring(N, seed=0).ids)
for pid in ids:
    net.add_peer(D1HTPeer(pid, net, params))
net.ring = RoutingTable(ids)
rng = random.Random(1)
for pid in ids:
    p = net.peers[pid]
    p.table = RoutingTable(ids)
    net.schedule(rng.random() * params.theta, (lambda q: (lambda: q.start()))(p))
net.run_until(30)

# mirror protocol membership into the runtime control plane
membership = Membership()
for pid in ids:
    membership.admit(pid, ("10.0.0.1", 1117))
controller = ElasticController(membership, model_axis=4)
print(f"cluster up: {membership.size()} nodes, "
      f"mesh plan {controller.replan().data_axis}x4")

# crash three nodes; EDRA disseminates, controller re-plans
for victim in ids[10:13]:
    net.peers[victim].stop(crash=True)
    net.ring.remove(victim)
    membership.fail(victim)
net.run_until(net.now + 20 * params.theta)
stale = sum(1 for pid in ids[13:20]
            if any(v in net.peers[pid].table for v in ids[10:13]))
plan = controller.plan
print(f"after 3 crashes: peers with stale entries={stale}, "
      f"new plan {plan.data_axis}x{plan.model_axis} "
      f"(dropped {len(plan.dropped)})")

placement = Placement(membership.table)
print("placement balance:", placement.balance_stats(2048))
print("expert shards for 32 experts over 4 EP groups:",
      placement.expert_assignment(32, 4).tolist())
