"""Geo-distributed serving demo: two regions, one churned workload, two
placement policies (DESIGN.md §13).

    PYTHONPATH=src python examples/geo_serve.py

Eight ring nodes are pinned half to "us", half to "eu" (~40 ms one-way
between them); the same request stream — origins alternating between
the regions — and the same two node failures are replayed under
``RingSuccessor`` (placement blind to geography, the pre-policy
behavior) and ``LatencyAware`` (ranks each session's replica set by RTT
from its origin).  Every admission or migration that lands a session
outside its origin region is metered by the serve plane.

Exits 1 unless LatencyAware measurably cuts cross-region placements —
CI runs this as the placement smoke.
"""
import sys

import jax

from repro.configs import get_smoke_config
from repro.models import Model
from repro.runtime import LatencyAware, Membership, RingSuccessor, Topology
from repro.serve import Request, ServeCluster

N_PER_REGION = 4
REQUESTS = 12
FAIL = 2                      # nodes killed mid-decode, one per region

cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

topo = Topology({"us": (0.0, 0.0), "eu": (40.0, 0.0)})


def run(policy):
    t = [0.0]
    m = Membership(t_q=60.0, now=lambda: t[0], policy=policy)
    by_region = {"us": [], "eu": []}
    for r, region in enumerate(("us", "eu")):
        for i in range(N_PER_REGION):
            nid = m.request_join(f"10.9.{r}.{i}", 7100 + 10 * r + i)
            topo.place(nid, region)
            by_region[region].append(nid)
    cluster = ServeCluster(m, model, params, slots=8, max_len=48)
    import numpy as np
    rng = np.random.default_rng(0)
    for i in range(REQUESTS):
        req = Request(f"g{i}", rng.integers(0, cfg.vocab, 4 + (i % 3) * 5,
                                            dtype=np.int32),
                      max_new_tokens=6)
        cluster.submit(req, origin=("us", "eu")[i % 2])
    for _ in range(2):
        cluster.step()
    # kill one node per region mid-decode: every session it owned gets
    # re-placed by the policy (RingSuccessor -> whatever id sorts next;
    # LatencyAware -> the lowest-RTT surviving replica-set member)
    m.fail(by_region["us"][0])
    m.fail(by_region["eu"][0])
    cluster.run(max_rounds=64)
    s = cluster.stats()
    done = sum(1 for rec in cluster.sessions.values() if rec.done)
    return s, done


base_policy = RingSuccessor(topology=topo)   # topology only for metering
geo_policy = LatencyAware(topo, affinity_ms=5.0)

results = {}
for pol in (base_policy, geo_policy):
    s, done = run(pol)
    cross = s["cross_region_admits"] + s["cross_region_migrations"]
    results[pol.name] = cross
    print(f"{pol.name:>15}: {done}/{REQUESTS} sessions finished, "
          f"{s['migrated']} migrations, cross-region placements: "
          f"{s['cross_region_admits']} admits + "
          f"{s['cross_region_migrations']} migrations = {cross}")
    if done != REQUESTS:
        print(f"FAIL: {pol.name} lost sessions")
        sys.exit(1)

rs, la = results["ring_successor"], results["latency_aware"]
if la >= rs:
    print(f"FAIL: latency_aware did not cut cross-region placements "
          f"({la} vs {rs})")
    sys.exit(1)
print(f"ok: latency_aware cut cross-region placements {rs} -> {la} "
      f"({1 - la / rs:.0%} fewer)")
