"""Serving demo: batched requests routed to replicas by session id over
the D1HT ring, decode rounds over a shared KV slab.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Model
from repro.runtime import Membership
from repro.serve import Replica, Request, SessionRouter

cfg = get_smoke_config("qwen2.5-3b")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

membership = Membership()
for i in range(4):
    membership.request_join(f"10.2.0.{i}", 9000)
router = SessionRouter(membership)

rng = np.random.default_rng(0)
reqs = [Request(f"user-{i}", rng.integers(0, cfg.vocab, 8, dtype=np.int32),
                max_new_tokens=8) for i in range(6)]
owners = router.route([r.session_id for r in reqs])
print("session -> replica routing (single-hop ring lookups):")
for r, o in zip(reqs, owners):
    print(f"  {r.session_id} -> node {o % 10**6}")

# run one replica locally for its share of the sessions
me = owners[0]
mine = [r for r, o in zip(reqs, owners) if o == me]
rep = Replica(model, slots=8, max_len=32)
rep.attach_params(params)
gen = {r.session_id: [rep.admit(r)] for r in mine}
for _ in range(7):
    for sid, tok in rep.decode_round().items():
        gen[sid].append(tok)
print(f"replica {me % 10**6} generated:")
for sid, toks in gen.items():
    print(f"  {sid}: {toks}")
