"""Serving demo: a churn-aware continuous-batching cluster over the
D1HT ring.

Sessions are routed to replicas by single-hop ring lookup; every replica
decodes all its slots at their own cache positions per round; killing a
replica mid-decode migrates exactly its sessions to their replica_set
successors (re-prefilled from the transcript) with zero losses, and a
quarantined spot node proxies requests as a §V gateway without owning
sessions.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Model
from repro.runtime import Membership
from repro.serve import Request, ServeCluster

cfg = get_smoke_config("qwen2.5-3b")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

membership = Membership(t_q=60.0, now=lambda: 0.0)
for i in range(4):
    membership.request_join(f"10.2.0.{i}", 9000)
cluster = ServeCluster(membership, model, params, slots=8, max_len=64)

# a quarantined spot node: proxies as a gateway, owns nothing (paper §V)
gateway = membership.request_join("10.2.9.9", 9999, preemptible=True)

rng = np.random.default_rng(0)
reqs = [Request(f"user-{i}",
                rng.integers(0, cfg.vocab, 4 + (i % 3) * 4, dtype=np.int32),
                max_new_tokens=8) for i in range(6)]
print("session -> replica routing (single-hop ring lookups, via gateway):")
for r in reqs:
    cluster.submit(r, via=gateway)
    rec = cluster.sessions[r.session_id]
    print(f"  {r.session_id} (prompt {len(r.prompt):>2} tok) "
          f"-> node {rec.owner % 10**6}")
print(f"gateway {gateway % 10**6} proxied {cluster.proxied[gateway]} "
      f"requests, owns {0 if gateway not in cluster.replicas else 1} slabs")

# decode a few rounds, then kill the busiest replica mid-stream
for _ in range(3):
    cluster.step()
busiest = max(cluster.replicas, key=lambda n: cluster.replicas[n].num_active)
print(f"\nkilling node {busiest % 10**6} "
      f"({cluster.replicas[busiest].num_active} active sessions)...")
membership.fail(busiest)
print(f"migrated {cluster.migrated_sessions} sessions to their "
      f"replica_set successors (re-prefilled from transcripts)")

rounds = cluster.run()
print(f"\nall sessions completed ({rounds} more rounds, zero losses):")
for sid, rec in cluster.sessions.items():
    mark = f"  [migrated x{rec.migrations}]" if rec.migrations else ""
    print(f"  {sid}: {rec.generated}{mark}")
