"""Tensor-parallel ServeCluster smoke: one ring node = a 2-device group.

Runs on 8 forced host devices (the env var below must be set before jax
initializes its backend, hence the top-of-file placement): a 4-node
ring over four tp=2 replica groups serves six sessions, survives a
ring-node failure AND a partial-group device loss, and must finish with
every token stream bit-identical to a tp=1 run of the same workload.
Exits nonzero on any divergence — CI's multi-device gate.

Usage: PYTHONPATH=src python examples/tp_cluster.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Model
from repro.runtime import Membership
from repro.serve import Request, ServeCluster


def run(model, params, cfg, tp: int) -> tuple:
    m = Membership(t_q=60.0, now=lambda: 0.0)
    for i in range(4):
        m.request_join(f"10.3.0.{i}", 7000 + i)
    cluster = ServeCluster(m, model, params, slots=8, max_len=64, tp=tp)
    rng = np.random.default_rng(0)
    for i in range(6):
        cluster.submit(Request(
            f"s{i}", rng.integers(0, cfg.vocab, 40, dtype=np.int32),
            max_new_tokens=8))
    for _ in range(2):
        cluster.step()
    # churn leg 1: a whole ring node fails -> its sessions re-home via
    # the per-shard KV-block handoff (each device's kv_heads slice is
    # fetched separately and reassembled under the target group)
    m.fail(cluster.sessions["s0"].owner)
    cluster.step()
    if tp > 1:
        # churn leg 2: ONE device of a live group dies -> the whole
        # replica is lost (partial-group policy) and migrates too
        node, devs = next(iter(cluster.supervisor._groups.items()))
        assert cluster.lose_device(devs[-1]) == node
    cluster.run()
    toks = {sid: list(rec.generated)
            for sid, rec in cluster.sessions.items()}
    return toks, cluster.stats()


def main() -> int:
    n = len(jax.devices())
    if n != 8:
        print(f"need 8 host devices, got {n}")
        return 2
    cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    base, st1 = run(model, params, cfg, tp=1)
    tp2, st2 = run(model, params, cfg, tp=2)
    if tp2 != base:
        print("FAIL: tp=2 token streams diverged from tp=1")
        return 1
    if st2.get("migrated", 0) < 2:
        print(f"FAIL: expected migrations from both churn legs: {st2}")
        return 1
    if st2.get("handoffs", 0) < 1 or st2.get("handoff_misses", 0):
        print(f"FAIL: per-shard KV handoff not exercised cleanly: {st2}")
        return 1
    if st2.get("dead_groups") != 1:
        print(f"FAIL: partial-group loss not recorded: {st2}")
        return 1
    print(f"ok: 6 sessions token-identical tp=1 vs tp=2 through a node "
          f"failure + a partial-group device loss "
          f"(migrated={st2['migrated']}, handoffs={st2['handoffs']}, "
          f"dead_groups={st2['dead_groups']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
