"""Figs. 5-6: lookup latency — single-hop DHTs vs Pastry vs a directory
server, idle and 100%-CPU nodes.

Since the measured request-latency plane landed (DESIGN.md §9) this
figure runs the closed-loop generator with a measured service profile —
the closed-form ``latency_sweep`` values ride along as the oracle
column.  ``--full`` additionally lets f' emerge from the churn plane
(measured ONCE per n — staleness is regime-independent — and reused for
idle and busy); quick mode uses the paper's nominal fractions to stay a
seconds-long smoke (bench_latency.py is the committed-artifact run).
"""
from repro.dht.latency_sim import (latency_point, measure_profile,
                                   measured_retry_fraction)

from .common import emit


def run(full: bool = False) -> None:
    sizes = [800, 1600, 2400, 3200, 4000]
    requests = 100_000 if full else 10_000
    profile = measure_profile(requests=20_000 if full else 10_000)
    emit("fig5/profile", 0.0,
         f"mu={profile.dserver_mu:.0f}/s "
         f"sat={profile.saturation_clients():.0f}clients "
         f"route={profile.route_us_per_key:.2f}us/key "
         f"peer_svc={profile.peer_service_us:.2f}us")
    rows = {False: [], True: []}
    for n in sizes:
        fp = {p: measured_retry_fraction(n, protocol=p)
              for p in ("d1ht", "calot")} if full else \
            {"d1ht": 0.01, "calot": 0.012}
        for busy in (False, True):
            rows[busy].append(latency_point(
                n, busy=busy, profile=profile, fprime=fp,
                requests=requests, window_s=2.0))
    for busy in (False, True):
        for r in rows[busy]:
            s = r["systems"]
            emit(f"fig5/{'busy' if busy else 'idle'}/n={r['n']}", 0.0,
                 f"d1ht={s['d1ht']['p50_ms']:.3f}ms "
                 f"calot={s['calot']['p50_ms']:.3f}ms "
                 f"pastry={s['pastry']['p50_ms']:.3f}ms "
                 f"dserver={s['dserver']['p50_ms']:.3f}ms "
                 f"dserver/d1ht={s['dserver']['mean_ms'] / s['d1ht']['mean_ms']:.1f}x "
                 f"model_ratio={s['d1ht']['ratio_measured_over_model']}")
