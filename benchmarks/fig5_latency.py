"""Figs. 5-6: lookup latency — single-hop DHTs vs Pastry vs a directory
server, idle and 100%-CPU nodes."""
from repro.dht.latency import latency_sweep

from .common import emit, timed


def run(full: bool = False) -> None:
    sizes = [800, 1600, 2400, 3200, 4000]
    for busy in (False, True):
        pts = latency_sweep(sizes, busy=busy, nodes=400)
        for n, p in pts.items():
            emit(f"fig5/{'busy' if busy else 'idle'}/n={n}", 0.0,
                 f"d1ht={p.d1ht_ms:.3f}ms calot={p.calot_ms:.3f}ms "
                 f"pastry={p.pastry_ms:.3f}ms dserver={p.dserver_ms:.3f}ms "
                 f"dserver/d1ht={p.dserver_ms/p.d1ht_ms:.1f}x")
