"""Hot-path benchmark: RingState batched lookup + incremental updates.

Measures, for ring sizes n in {10^3, 10^4, 10^5}:

  * batched-lookup throughput (keys/s) through the device-resident
    hi/lo table and the ring_lookup64 Pallas kernel (interpret mode by
    default — on a real TPU pass --no-interpret for compiled numbers);
  * update latency (events/s) for batched EDRA delta application
    (joins+leaves merged incrementally, never a full rebuild).

Emits BENCH_ring_lookup.json (cwd by default) so future PRs can track
the hot path against these numbers.

Usage: PYTHONPATH=src python benchmarks/bench_ring_lookup.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.edra import Event
from repro.core.ringstate import RingState

RNG = np.random.default_rng(0)


def _rand_ids(k: int) -> np.ndarray:
    x = RNG.integers(0, 2**64, size=2 * k, dtype=np.uint64)
    x = np.unique(x)[:k]
    assert x.size == k
    return x


def bench_lookup(state: RingState, q: int, reps: int,
                 interpret: bool) -> float:
    keys = RNG.integers(0, 2**64, size=q, dtype=np.uint64)
    state.lookup(keys, interpret=interpret)  # warmup: upload + jit compile
    t0 = time.perf_counter()
    for _ in range(reps):
        state.lookup(keys, interpret=interpret)
    dt = time.perf_counter() - t0
    return reps * q / dt


def bench_updates(state: RingState, batch: int, reps: int) -> float:
    done = 0
    t0 = time.perf_counter()
    for _ in range(reps):
        live = state.active_ids()
        leave = live[RNG.integers(0, live.size, size=batch // 2)]
        join = _rand_ids(batch // 2)
        evs = [Event(subject_id=int(p), kind="leave") for p in leave]
        evs += [Event(subject_id=int(p), kind="join") for p in join]
        done += len(evs)
        state.apply_events(evs)
    dt = time.perf_counter() - t0
    return done / dt


def run(full: bool = False, *, out: str = "BENCH_ring_lookup.json",
        interpret: bool = True, sizes=None) -> list:
    """Harness entry point (benchmarks.run registers this): quick sizes
    unless ``full``; also reused by the __main__ CLI below."""
    qbatch = 4096 if full else 1024
    reps = 5 if full else 2
    if sizes is None:
        sizes = (10**3, 10**4, 10**5) if full else (10**3, 10**4)
    results = []
    for n in sizes:
        state = RingState(_rand_ids(n))
        keys_per_s = bench_lookup(state, qbatch, reps, interpret)
        events_per_s = bench_updates(state, 64, reps * 4)
        row = {
            "n": n,
            "query_batch": qbatch,
            "lookup_keys_per_s": round(keys_per_s, 1),
            "update_events_per_s": round(events_per_s, 1),
            "device_uploads": state.upload_count,
            "device_capacity": state.device_capacity,
        }
        results.append(row)
        print(f"n={n:>7}  lookup={keys_per_s:>12.0f} keys/s  "
              f"updates={events_per_s:>10.0f} events/s  "
              f"uploads={state.upload_count}", flush=True)

    payload = {
        "benchmark": "ring_lookup",
        "mode": "pallas-interpret-cpu" if interpret else "pallas-compiled",
        "results": results,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ring_lookup.json")
    ap.add_argument("--quick", action="store_true",
                    help="fewer reps / smaller batches (CI smoke)")
    ap.add_argument("--no-interpret", action="store_true",
                    help="run the compiled Pallas kernel (real TPU only)")
    args = ap.parse_args()
    run(full=not args.quick, out=args.out,
        interpret=not args.no_interpret,
        sizes=(10**3, 10**4, 10**5))


if __name__ == "__main__":
    main()
