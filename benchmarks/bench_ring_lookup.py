"""Hot-path benchmark: RingState batched lookup + incremental updates.

Measures, for ring sizes n in {10^3, 10^4, 10^5, 10^6}:

  * batched-lookup throughput (keys/s) through the device-resident
    routing table — the two-level bucket index above 2048 peers, the
    flat hi/lo compare-and-count scan below it (DESIGN.md §7); interpret
    mode by default — on a real TPU pass --no-interpret for compiled
    numbers;
  * update latency (events/s) for batched EDRA delta application
    (joins+leaves merged incrementally, never a full rebuild);
  * device maintenance traffic: bucket-directory occupancy stats and
    the delta-upload bytes one EDRA batch costs at the serve plane's
    apply -> lookup cadence (O(touched buckets), vs the O(n) full-table
    re-ship the flat path pays).

Emits BENCH_ring_lookup.json (cwd by default) so future PRs can track
the hot path against these numbers.

Usage: PYTHONPATH=src python benchmarks/bench_ring_lookup.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

try:
    from .common import ensure_tuned, provenance, time_best_of
except ImportError:           # standalone: python benchmarks/bench_ring_lookup.py
    from common import ensure_tuned, provenance, time_best_of

from repro.core.edra import Event
from repro.core.ringstate import RingState

RNG = np.random.default_rng(0)


def _rand_ids(k: int) -> np.ndarray:
    x = RNG.integers(0, 2**64, size=2 * k, dtype=np.uint64)
    x = np.unique(x)[:k]
    assert x.size == k
    return x


def _churn_batch(state: RingState, batch: int) -> list:
    live = state.active_ids()
    leave = live[RNG.integers(0, live.size, size=batch // 2)]
    join = _rand_ids(batch // 2)
    evs = [Event(subject_id=int(p), kind="leave") for p in leave]
    evs += [Event(subject_id=int(p), kind="join") for p in join]
    return evs


def bench_lookup(state: RingState, q: int, reps: int,
                 interpret) -> float:
    """Best-rep throughput via time_best_of (min per-rep wall time is
    the hardware's answer; means make the CI regression gate flap)."""
    keys = RNG.integers(0, 2**64, size=q, dtype=np.uint64)
    us = time_best_of(lambda: state.lookup(keys, interpret=interpret),
                      reps=reps, warmup=1)   # warmup: upload + jit compile
    return q / (us / 1e6)


def bench_updates(state: RingState, batch: int, reps: int) -> float:
    done = 0
    t0 = time.perf_counter()
    for _ in range(reps):
        evs = _churn_batch(state, batch)
        done += len(evs)
        state.apply_events(evs)
    dt = time.perf_counter() - t0
    return done / dt


def bench_delta_traffic(state: RingState, batch: int, reps: int,
                        interpret: bool) -> float:
    """Device maintenance bytes per EDRA batch at the serve cadence
    (apply a membership batch, resync on the next routed lookup)."""
    keys = RNG.integers(0, 2**64, size=256, dtype=np.uint64)
    state.lookup(keys, interpret=interpret)      # settle to a synced table
    b0 = state.upload_bytes
    for _ in range(reps):
        state.apply_events(_churn_batch(state, batch))
        state.lookup(keys, interpret=interpret)
    return (state.upload_bytes - b0) / reps


def run(full: bool = False, *, out: str = "BENCH_ring_lookup.json",
        interpret=None, sizes=None) -> list:
    """Harness entry point (benchmarks.run registers this): quick sizes
    unless ``full``; also reused by the __main__ CLI below.
    ``interpret=None`` autodetects (compiled on TPU, interpret on CPU)."""
    ensure_tuned()
    qbatch = 4096 if full else 1024
    reps = 5 if full else 2
    # lookups are µs-scale per batch once bucketized: time enough of
    # them that the CI regression gate compares signal, not jitter
    lookup_reps = 40 if full else 8
    batch = 64
    if sizes is None:
        sizes = (10**3, 10**4, 10**5, 10**6) if full else (10**3, 10**4)
    results = []
    for n in sizes:
        state = RingState(_rand_ids(n))
        keys_per_s = bench_lookup(state, qbatch, lookup_reps, interpret)
        events_per_s = bench_updates(state, batch, reps * 4)
        delta_bytes = bench_delta_traffic(state, batch, reps * 2, interpret)
        bkt = state.bucket_stats()
        row = {
            "n": n,
            "query_batch": qbatch,
            "lookup_keys_per_s": round(keys_per_s, 1),
            "update_events_per_s": round(events_per_s, 1),
            "device_uploads": state.upload_count,
            "device_capacity": state.device_capacity,
            "lookup_path": "bucketized" if bkt.get("valid") else "flat",
            "events_per_batch": batch,
            "delta_upload_bytes_per_batch": round(delta_bytes, 1),
        }
        if bkt.get("enabled"):
            row["bucket_directory"] = {
                "buckets": bkt["buckets"],
                "row_width": bkt["row_width"],
                "max_occupancy": bkt["max_occupancy"],
                "mean_occupancy": round(bkt["mean_occupancy"], 2),
                "directory_bytes": bkt["directory_bytes"],
                "matrix_bytes": bkt["matrix_bytes"],
            }
            full_bytes = bkt["matrix_bytes"] + bkt["directory_bytes"]
        else:
            full_bytes = state.device_capacity * 8 + 4
        row["full_table_bytes"] = full_bytes
        results.append(row)
        print(f"n={n:>8}  lookup={keys_per_s:>12.0f} keys/s  "
              f"updates={events_per_s:>10.0f} events/s  "
              f"delta={delta_bytes:>10.0f} B/batch "
              f"(full={full_bytes}) path={row['lookup_path']}", flush=True)

    prov = provenance(interpret)
    payload = {
        "benchmark": "ring_lookup",
        "mode": prov["mode"],
        "provenance": prov,
        "results": results,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ring_lookup.json")
    ap.add_argument("--quick", action="store_true",
                    help="fewer reps / smaller batches (CI smoke)")
    ap.add_argument("--no-interpret", action="store_true",
                    help="force the compiled Pallas kernel (real TPU only)")
    ap.add_argument("--interpret", action="store_true",
                    help="force interpreter mode (default: autodetect)")
    ap.add_argument("--sizes", type=int, nargs="+", default=None,
                    help="ring sizes to sweep (default: 1e3..1e6 full)")
    args = ap.parse_args()
    interpret = None
    if args.no_interpret:
        interpret = False
    elif args.interpret:
        interpret = True
    run(full=not args.quick, out=args.out, interpret=interpret,
        sizes=tuple(args.sizes) if args.sizes else None)


if __name__ == "__main__":
    main()
