"""Shared benchmark plumbing: CSV emission, timing, and provenance.

Every BENCH_*.json carries a ``provenance`` block naming the execution
mode (``pallas-interpret-cpu`` vs ``pallas-compiled-tpu``), backend,
device kind/count, jax version and the autotune resolution state
("defaults" when no cache was consulted).  CI regression gates compare
numbers ONLY within the same mode — see DESIGN.md §10.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Callable, Optional


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


@contextmanager
def timed():
    t0 = time.perf_counter()
    box = {}
    yield box
    box["us"] = (time.perf_counter() - t0) * 1e6


def header() -> None:
    print("name,us_per_call,derived", flush=True)


def time_best_of(fn: Callable, *, reps: int = 5, warmup: int = 1,
                 block: bool = True) -> float:
    """Best-of-``reps`` wall time of ``fn()`` in µs (timeit practice:
    the min is the hardware's answer; means fold scheduler pauses and
    GC into the number and make CI regression gates flap).

    ``block`` waits on the returned arrays with ``jax.block_until_ready``
    so async dispatch does not make compiled backends look free — every
    bench timing loop in this tree goes through here for that reason.
    """
    import jax

    for _ in range(max(warmup, 0)):
        out = fn()
        if block and out is not None:
            jax.block_until_ready(out)
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        out = fn()
        if block and out is not None:
            jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def provenance(interpret: Optional[bool] = None) -> dict:
    """Mode/backend/autotune provenance block for BENCH_*.json files."""
    from repro.analysis import sanitize
    from repro.kernels import autotune, backend

    p = backend.provenance(interpret)
    p["autotune"] = autotune.status_label()
    # numbers taken with the runtime invariant sanitizer installed are
    # NOT comparable to plain runs (every RingState/BlockStore/Replica
    # mutation pays an extra oracle check) — record the flag so gates
    # and readers can refuse the comparison
    p["sanitize"] = sanitize.enabled()
    return p


def ensure_tuned(budget_s: Optional[float] = None) -> str:
    """Autotune all kernels when running compiled; no-op ("defaults")
    under interpret mode where tile timings are meaningless."""
    from repro.kernels import autotune
    from repro.kernels.backend import default_interpret

    if default_interpret():
        return "defaults"
    autotune.autotune_all(budget_s=budget_s)
    return autotune.status_label()
