"""Shared benchmark plumbing: CSV emission + timing."""
from __future__ import annotations

import sys
import time
from contextlib import contextmanager
from typing import Iterable


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


@contextmanager
def timed():
    t0 = time.perf_counter()
    box = {}
    yield box
    box["us"] = (time.perf_counter() - t0) * 1e6


def header() -> None:
    print("name,us_per_call,derived", flush=True)
