"""Tensor-parallel replica-group sweep: tok/s, per-device KV bytes, and
collective bytes per decode round at tp = 1 / 2 / 4.

The sweep runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag must be
set before jax initializes), builds one ``TPReplicaGroup`` per tp
degree on the same smoke model, and reports per degree:

  * greedy decode tokens/s for a fixed 4-slot batch (best-of timing of
    the group's fused shard_map decode program);
  * per-device KV-cache bytes (``addressable_shards[0]`` of the sharded
    cache — must scale as 1/TP);
  * collective bytes per decode round from the loop-aware HLO analyzer
    (``launch.hlo_cost.fn_cost``): the psum traffic TP pays per round,
    the roofline's collective term;
  * the full greedy token stream, asserted bit-identical across the
    sweep (exact row/column weight shards + deterministic psum order).

Host-CPU "devices" share one memory bus, so absolute tok/s across tp is
runner noise — the committed numbers are for the BYTES columns and the
identity bit; compare throughput only on real multi-chip hardware.

Usage: PYTHONPATH=src python benchmarks/bench_tp.py
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys

try:
    from .common import emit, provenance
except ImportError:                # standalone: python benchmarks/bench_tp.py
    from common import emit, provenance

CHILD = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.launch.hlo_cost import fn_cost
from repro.launch.mesh import replica_groups
from repro.models import Model
from repro.models.tp import TPReplicaGroup

REPS = int(os.environ.get("TP_BENCH_REPS", "10"))
cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32",
                                                    num_kv_heads=4)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
B, MAXLEN, STEPS = 4, 64, 8
prompt = np.random.default_rng(0).integers(0, cfg.vocab, 12, dtype=np.int32)

rows, streams = [], {}
for tp in (1, 2, 4):
    g = TPReplicaGroup(model, replica_groups(None, tp)[0])
    sp = g.shard_params(params)
    cache = g.init_cache(B, MAXLEN)
    per_dev = g.per_device_cache_bytes(cache)
    prefill, decode_full, _, _ = g.fns()
    toks_b = jnp.tile(jnp.asarray(prompt)[None], (B, 1))
    logits, cache = prefill(sp, {"tokens": toks_b}, cache)
    toks = [int(jnp.argmax(logits[0]))]
    n = jnp.full((B,), len(prompt), jnp.int32)
    for _ in range(STEPS - 1):
        t = jnp.full((B, 1), toks[-1], jnp.int32)
        logits, cache = decode_full(sp, cache, t, n)
        toks.append(int(jnp.argmax(logits[0])))
        n = n + 1
    streams[tp] = toks
    t = jnp.full((B, 1), toks[-1], jnp.int32)
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(decode_full(sp, cache, t, n))
        best = min(best, time.perf_counter() - t0)
    round_us = best * 1e6
    cost = fn_cost(lambda p, c, tt, nn: decode_full(p, c, tt, nn),
                   sp, cache, t, n)
    rows.append({
        "tp": tp,
        "groups": 8 // tp,
        "round_us": round(round_us, 1),
        "tokens_per_s": round(B / (round_us / 1e6), 1),
        "per_device_kv_bytes": int(per_dev),
        "collective_bytes_per_round": int(cost["collective_bytes"]),
        "collective_bytes_by_op": {k: int(v) for k, v in
                                   cost["collective_bytes_by_op"].items()},
    })
base = streams[1]
ident = all(s == base for s in streams.values())
ratios_ok = all(r["per_device_kv_bytes"]
                == rows[0]["per_device_kv_bytes"] // r["tp"] for r in rows)
print("TPBENCH_JSON:" + json.dumps({
    "tokens_identical": ident, "kv_bytes_scale_1_over_tp": ratios_ok,
    "decode_batch": B, "decode_steps": STEPS, "sweep": rows}))
"""


def collect(full: bool = False) -> dict:
    """Run the 8-device sweep in a subprocess and return its payload
    (provenance attached from this process — same backend/mode)."""
    env = {**__import__("os").environ, "PYTHONPATH": "src",
           "TP_BENCH_REPS": "20" if full else "10"}
    out = subprocess.run([sys.executable, "-c", CHILD],
                         capture_output=True, text=True, timeout=1200,
                         env=env)
    line = next((ln for ln in out.stdout.splitlines()
                 if ln.startswith("TPBENCH_JSON:")), None)
    if line is None:
        raise RuntimeError(f"tp sweep failed:\n{out.stderr[-4000:]}")
    payload = json.loads(line[len("TPBENCH_JSON:"):])
    payload["provenance"] = provenance()
    assert payload["tokens_identical"], "tp>1 decode diverged from tp=1"
    assert payload["kv_bytes_scale_1_over_tp"], \
        f"per-device KV bytes do not scale 1/TP: {payload['sweep']}"
    return payload


def run(full: bool = False) -> dict:
    payload = collect(full=full)
    for r in payload["sweep"]:
        emit(f"tp_decode_tp{r['tp']}", r["round_us"],
             f"{r['tokens_per_s']:.0f} tok/s, "
             f"kv/dev={r['per_device_kv_bytes']}B, "
             f"coll/round={r['collective_bytes_per_round']}B")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None,
                    help="optionally write the payload as JSON")
    args = ap.parse_args()
    payload = run(full=args.full)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
