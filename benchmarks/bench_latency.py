"""Request-latency benchmark: the §VII-D D1HT-vs-directory-server
comparison (Figs 5-6), measured instead of asserted.

For ring sizes n in {800..4000} (the paper's 400-node testbed sweep) and
both CPU regimes (idle / 100%-busy co-scheduling) the measured plane
(``repro.dht.latency_sim``) plays a closed-loop lookup workload:

  * the load generator drives real batched lookups through
    ``RingState.lookup`` (``ring_lookup_bucketed`` at scale) — the route
    component is timed, not assumed;
  * the directory server is an FCFS queue over the service rate measured
    by SATURATING one local ``DirectoryWorker`` — the paper's Cluster-B
    1,600-client methodology, so the saturation point is a measurement
    of this host, not the hardcoded ``DSERVER_SAT_CLIENTS``;
  * the stale-table retry fraction f' is measured per (n, protocol) by
    the PR-4 vectorized churn plane, not a free parameter;
  * every row carries the closed-form oracle evaluated AT the measured
    parameters and the measured/model ratio (the cross-validation
    ladder's latency rung, like BENCH_maintenance's sim/model column).

n in {10^4..10^6} rows extend the sweep with the closed form anchored to
the same measured parameters (``mode: model-extended``), mirroring how
the paper could only model past its testbed.

Emits BENCH_latency.json.  The CI gate checks ORDERINGS and RATIOS
(D1HT ≈ dserver sub-saturation, dserver diverging past the measured
saturation, Pastry ≥ 3x, measured/model within [0.7, 1.4]) — never
absolute milliseconds, so the gate is runner-speed-neutral: a slower
host measures a lower mu and the saturation point moves WITH it.

Usage: PYTHONPATH=src python benchmarks/bench_latency.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.dht.latency_sim import (latency_point, measure_profile,
                                   measured_retry_fraction,
                                   model_extended_point)

SIZES = (800, 1600, 2400, 3200, 4000)


def _fmt(row: dict) -> str:
    s = row["systems"]
    if row["mode"] == "model-extended":
        return (f"n={row['n']:>8} {'busy' if row['busy'] else 'idle'} "
                f"[model] d1ht={s['d1ht']['model_ms']:>7}ms "
                f"dserver={s['dserver']['model_ms']:>10}ms "
                f"pastry={s['pastry']['model_ms']:>7}ms")
    return (f"n={row['n']:>8} {'busy' if row['busy'] else 'idle'} "
            f"util={row['dserver_util']:>5} "
            f"d1ht={s['d1ht']['p50_ms']:>6}/{s['d1ht']['p99_ms']:>7}ms "
            f"dserver={s['dserver']['p50_ms']:>8}/{s['dserver']['p99_ms']:>9}ms "
            f"pastry={s['pastry']['p50_ms']:>6}ms "
            f"ds/d1ht={s['dserver']['mean_ms'] / s['d1ht']['mean_ms']:>6.1f}x "
            f"ratios d1ht={s['d1ht']['ratio_measured_over_model']} "
            f"ds={s['dserver']['ratio_measured_over_model']}")


def run(full: bool = False, *, out: str = "BENCH_latency.json",
        sizes=None, requests: int = None, window_s: float = None,
        seed: int = 1) -> dict:
    """Harness entry point (benchmarks.run registers this).

    ``full`` uses the committed-JSON settings (200k sampled requests per
    system, a 10 s queue window, 600 s churn windows, the 10^4..10^6
    model extension); quick mode shrinks everything for the CI smoke but
    keeps the same measured methodology, so the gate's ordering/ratio
    checks apply to both."""
    sizes = tuple(sizes) if sizes else SIZES
    requests = requests or (200_000 if full else 20_000)
    window_s = window_s or (10.0 if full else 2.0)
    churn_duration = 600.0 if full else 240.0
    churn_warmup = 120.0 if full else 60.0
    ext_sizes = (10**4, 10**5, 10**6) if full else (10**4,)

    t0 = time.perf_counter()
    profile = measure_profile(requests=25_000 if full else 10_000,
                              repeats=7 if full else 5)
    print(f"measured profile ({time.perf_counter() - t0:.1f}s): "
          f"route={profile.route_us_per_key:.2f}us/key  "
          f"dserver service={profile.dserver_service_us:.2f}us "
          f"(mu={profile.dserver_mu:,.0f}/s -> saturates at "
          f"{profile.saturation_clients():,.0f} clients x 30 lkp/s)  "
          f"peer service={profile.peer_service_us:.2f}us", flush=True)

    # adaptive knee coverage: on a runner whose worker is fast enough
    # that the standard sweep never crosses its measured saturation
    # point, extend the sweep — the Fig-5a divergence claim must stay
    # testable (and CI-gated) at ANY runner speed
    sat_n = int(-(-1.3 * profile.saturation_clients() // 400)) * 400
    if sat_n > max(sizes):
        sizes = (*sizes, sat_n)
        print(f"sweep extended to n={sat_n}: the measured saturation "
              f"point sits above the standard sizes", flush=True)

    results = []
    for n in (*sizes, *ext_sizes):
        # f' is regime-independent (staleness comes from dissemination,
        # not CPU load): measure once per (n, protocol), reuse for both
        fp = {p: measured_retry_fraction(
            n, protocol=p, duration=churn_duration, warmup=churn_warmup,
            seed=seed) for p in ("d1ht", "calot")}
        for busy in (False, True):
            if n in sizes:
                row = latency_point(n, busy=busy, profile=profile,
                                    fprime=fp, window_s=window_s,
                                    requests=requests, seed=seed)
            else:
                row = model_extended_point(n, busy=busy, profile=profile,
                                           fprime=fp, window_s=window_s)
            results.append(row)
            print(_fmt(row), flush=True)

    try:
        from .common import provenance
    except ImportError:
        from common import provenance
    prov = provenance()
    payload = {
        "benchmark": "latency",
        "window": "full" if full else "quick",
        "mode": prov["mode"],
        "provenance": prov,
        "lookup_rate_per_client": 30.0,
        "window_s": window_s,
        "requests_per_system": requests,
        "profile": {
            "route_us_per_key": round(profile.route_us_per_key, 3),
            "dserver_service_us": round(profile.dserver_service_us, 3),
            "dserver_mu_per_s": round(profile.dserver_mu, 1),
            "saturation_clients": round(profile.saturation_clients(), 1),
            "peer_service_us": round(profile.peer_service_us, 3),
            "table_n": profile.table_n,
        },
        "results": results,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_latency.json")
    ap.add_argument("--quick", action="store_true",
                    help="short windows + fewer samples (CI smoke)")
    ap.add_argument("--sizes", type=int, nargs="+", default=None)
    args = ap.parse_args()
    run(full=not args.quick, out=args.out, sizes=args.sizes)


if __name__ == "__main__":
    main()
