"""Paper-claim validation table (C1-C6) — the §Paper-validation rows of
EXPERIMENTS.md are generated from this."""
from repro.core import analysis as A
from repro.core.jax_sim import SimConfig, simulate
from repro.dht.latency import latency_sweep

from .common import emit, timed


def run(full: bool = False) -> None:
    # C2
    for mins, expect in ((60, 20.7), (169, 7.3), (174, 7.1), (780, 1.6)):
        got = A.d1ht_bandwidth(10**6, mins * 60) / 1e3
        emit(f"C2/d1ht_1e6/{mins}min", 0.0,
             f"got={got:.2f}kbps paper={expect}kbps "
             f"delta={abs(got-expect)/expect*100:.1f}%")
    # C3
    d1 = A.d1ht_bandwidth(10**6, 169 * 60)
    ca = A.calot_bandwidth(10**6, 169 * 60)
    oh = A.onehop_bandwidth(10**6, 169 * 60)
    emit("C3/ratios_1e6_kad", 0.0,
         f"calot/d1ht={ca/d1:.1f}x onehop_slice/d1ht="
         f"{oh.slice_leader_bps/d1:.1f}x onehop_ord/d1ht="
         f"{oh.ordinary_bps/d1:.2f}x (paper: ~10x / ~10-20x / ~1x)")
    # C4
    for lbl, s, vol in (("kad", 169, 0.24), ("gnutella", 174, 0.31)):
        red = A.quarantine_reduction(10**7, s * 60, vol)
        emit(f"C4/quarantine/{lbl}", 0.0,
             f"reduction={red*100:.1f}% paper~{vol*100:.0f}%")
    # C1/C5 via the vectorized simulator
    n = 2048 if full else 512
    with timed() as t:
        r = simulate(SimConfig(n=n, s_avg=174 * 60,
                               duration=1800.0 if full else 900.0, seed=0))
    emit(f"C1_C5/jax_sim/n={n}", t["us"],
         f"one_hop={r.one_hop_fraction*100:.2f}% (paper >99%) "
         f"mean_ack={r.mean_ack_time:.1f}s bound={r.theorem1_bound:.1f}s "
         f"sim/model_bw={r.mean_out_bps/r.analytical_bps:.2f}")
    # C6
    pts = latency_sweep([1600, 4000], busy=False)
    emit("C6/latency", 0.0,
         f"dserver/d1ht@1600={pts[1600].dserver_ms/pts[1600].d1ht_ms:.1f}x "
         f"@4000={pts[4000].dserver_ms/pts[4000].d1ht_ms:.1f}x "
         f"(paper: ~1x then >10x)")
