"""Fig. 7: analytical per-peer maintenance bandwidth, D1HT vs 1h-Calot vs
OneHop (best/worst roles), n = 1e4..1e7, four session lengths."""
from repro.core import analysis as A
from repro.core.tuning import SESSION_LENGTHS_MIN

from .common import emit, timed


def run(full: bool = False) -> None:
    sizes = [10**4, 10**5, 10**6, 10**7]
    for label, mins in sorted(SESSION_LENGTHS_MIN.items(),
                              key=lambda kv: kv[1]):
        s = mins * 60
        for n in sizes:
            with timed() as t:
                d1 = A.d1ht_bandwidth(n, s)
                ca = A.calot_bandwidth(n, s)
                oh = A.onehop_bandwidth(n, s)
            emit(f"fig7/{label}/n={n:.0e}", t["us"],
                 f"d1ht={d1/1e3:.2f}kbps calot={ca/1e3:.2f}kbps "
                 f"onehop_slice={oh.slice_leader_bps/1e3:.2f}kbps "
                 f"onehop_ord={oh.ordinary_bps/1e3:.2f}kbps "
                 f"calot/d1ht={ca/d1:.1f}x")
