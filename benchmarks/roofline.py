"""§Roofline: three-term roofline per (arch x shape) from the dry-run.

Reads results/dryrun.jsonl (written by repro.launch.dryrun) and derives,
per cell on the single-pod 16x16 mesh:

    compute term    = matmul_flops_per_device / peak_bf16
    memory term     = hbm_bytes_per_device / hbm_bw
    collective term = collective_bytes_per_device / (links * link_bw)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE; decode D = batch tokens),
the useful-compute ratio, the dominant term, and a one-line lever.

Terms come from the loop-aware HLO analyzer (hlo_cost), NOT XLA's
cost_analysis (which counts while bodies once — see EXPERIMENTS.md).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from .common import emit

PEAK = 197e12          # bf16 FLOP/s per v5e chip
HBM_BW = 819e9         # B/s
LINK_BW = 50e9         # B/s per ICI link
LINKS = 2              # usable links per axis direction on a 2D torus slice

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.jsonl")

LEVERS = {
    "compute": "raise MXU utilization: fuse attention (Pallas), drop remat",
    "memory": "keep flash tiles in VMEM (Pallas kernel), cut fp32 temps",
    "collective": "re-map logical axes (less TP), overlap or shrink "
                  "grad/dispatch reductions",
}


def load(path: str = RESULTS, tag: str = "baseline",
         mesh: str = "16x16") -> List[Dict]:
    recs = []
    seen = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("tag") != tag or r.get("mesh") != mesh:
                continue
            if not r.get("ok"):
                continue
            seen[(r["arch"], r["shape"])] = r   # last record wins
    return list(seen.values())


def terms(rec: Dict) -> Dict[str, float]:
    return {
        "compute": rec["matmul_flops_per_device"] / PEAK,
        "memory": rec["hbm_bytes_per_device"] / HBM_BW,
        "collective": rec["collective_bytes_per_device"] / (LINKS * LINK_BW),
    }


def model_flops(rec: Dict) -> float:
    n = rec["active_params"] or rec["params"]
    mult = 6.0 if rec["mode"] == "train" else 2.0
    return mult * n * rec["tokens"]


def analyze_record(rec: Dict) -> Dict:
    t = terms(rec)
    dom = max(t, key=t.get)
    mf = model_flops(rec)
    hlo_total = rec["matmul_flops_per_device"] * rec["devices"]
    return {
        **t,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / max(hlo_total, 1.0),
        "step_s_bound": max(t.values()),
        "roofline_fraction": t["compute"] / max(max(t.values()), 1e-12),
        "lever": LEVERS[dom],
    }


def run(full: bool = False) -> None:
    recs = load()
    if not recs:
        emit("roofline/missing", 0.0, "run repro.launch.dryrun --all first")
        return
    for rec in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        a = analyze_record(rec)
        emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
             f"compute={a['compute']*1e3:.1f}ms memory={a['memory']*1e3:.1f}ms "
             f"collective={a['collective']*1e3:.1f}ms dominant={a['dominant']} "
             f"useful={a['useful_ratio']*100:.0f}% "
             f"roofline_frac={a['roofline_fraction']*100:.1f}% "
             f"mem/dev={rec['peak_memory_per_device']/2**30:.1f}GiB")
