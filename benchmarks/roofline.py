"""§Roofline: three-term roofline per (arch x shape) from the dry-run,
plus a per-Pallas-kernel bytes/FLOP section next to measured throughput.

Reads results/dryrun.jsonl (written by repro.launch.dryrun) and derives,
per cell on the single-pod 16x16 mesh:

    compute term    = matmul_flops_per_device / peak_bf16
    memory term     = hbm_bytes_per_device / hbm_bw
    collective term = collective_bytes_per_device / (links * link_bw)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE; decode D = batch tokens),
the useful-compute ratio, the dominant term, and a one-line lever.

Terms come from the loop-aware HLO analyzer (hlo_cost), NOT XLA's
cost_analysis (which counts while bodies once — see EXPERIMENTS.md).

The kernel section (``kernel_rooflines``) times each of the five Pallas
kernels on a representative shape and puts an ANALYTIC bytes/ops model
beside the measurement: achieved GB/s and arithmetic intensity, so a
regression in either the tile choice or the data layout shows up as a
bandwidth cliff rather than an anonymous ms delta.  Interpret-mode
numbers are emulation throughput — compare within mode only.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from .common import emit, provenance, time_best_of

PEAK = 197e12          # bf16 FLOP/s per v5e chip
HBM_BW = 819e9         # B/s
LINK_BW = 50e9         # B/s per ICI link
LINKS = 2              # usable links per axis direction on a 2D torus slice

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.jsonl")

LEVERS = {
    "compute": "raise MXU utilization: fuse attention (Pallas), drop remat",
    "memory": "keep flash tiles in VMEM (Pallas kernel), cut fp32 temps",
    "collective": "re-map logical axes (less TP), overlap or shrink "
                  "grad/dispatch reductions",
}


def load(path: str = RESULTS, tag: str = "baseline",
         mesh: str = "16x16") -> List[Dict]:
    recs = []
    seen = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("tag") != tag or r.get("mesh") != mesh:
                continue
            if not r.get("ok"):
                continue
            seen[(r["arch"], r["shape"])] = r   # last record wins
    return list(seen.values())


def terms(rec: Dict) -> Dict[str, float]:
    return {
        "compute": rec["matmul_flops_per_device"] / PEAK,
        "memory": rec["hbm_bytes_per_device"] / HBM_BW,
        "collective": rec["collective_bytes_per_device"] / (LINKS * LINK_BW),
    }


def model_flops(rec: Dict) -> float:
    n = rec["active_params"] or rec["params"]
    mult = 6.0 if rec["mode"] == "train" else 2.0
    return mult * n * rec["tokens"]


def analyze_record(rec: Dict) -> Dict:
    t = terms(rec)
    dom = max(t, key=t.get)
    mf = model_flops(rec)
    hlo_total = rec["matmul_flops_per_device"] * rec["devices"]
    return {
        **t,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / max(hlo_total, 1.0),
        "step_s_bound": max(t.values()),
        "roofline_fraction": t["compute"] / max(max(t.values()), 1e-12),
        "lever": LEVERS[dom],
    }


# ---------------------------------------------------------------------------
# Per-kernel rooflines: analytic bytes/ops next to measured throughput
# ---------------------------------------------------------------------------

def _kernel_cases(full: bool):
    """(name, build) pairs; build() -> (thunk, bytes, ops).  ``bytes`` is
    the analytic HBM-traffic model of one call (tile re-reads included),
    ``ops`` the arithmetic work — both closed-form, so the achieved
    GB/s / ops-per-byte ratios are comparable across PRs even when the
    HLO under them changes."""
    import numpy as np
    import jax.numpy as jnp

    rng = np.random.default_rng(7)

    def ring_lookup_case():
        from repro.kernels.ring_lookup.ops import ring_lookup
        n, q = (50_000, 4096) if full else (4096, 1024)
        table = np.sort(rng.choice(2**32 - 1, size=n, replace=False)
                        ).astype(np.uint32)
        keys = jnp.asarray(rng.integers(0, 2**32, size=q, dtype=np.uint32))
        tbl = jnp.asarray(table)
        from repro.kernels.autotune import tiles_for
        bq = tiles_for("ring_lookup", q=q, n=n)["bq"]
        blocks = -(-q // bq)
        bytes_ = q * 4 + blocks * n * 4 + q * 4   # keys + per-block table scan + out
        ops = 2.0 * q * n                          # cmp + count per (key, entry)
        return (lambda: ring_lookup(keys, tbl)), bytes_, ops

    def bucketed_case():
        from repro.kernels.ring_lookup.kernel import BW
        from repro.kernels.ring_lookup.ops import ring_lookup_bucketed
        bits, q = (11, 4096) if full else (8, 1024)
        n = (1 << bits) * 8
        table = np.sort(np.unique(
            rng.integers(0, 2**64, size=n, dtype=np.uint64)))
        nb = 1 << bits
        edges = np.arange(nb, dtype=np.uint64) << np.uint64(64 - bits)
        starts = np.searchsorted(table, edges)
        ends = np.append(starts[1:], table.size)
        occ = (ends - starts).astype(np.int32)
        pad = table[ends % table.size]
        j = np.arange(BW)[None, :]
        idx = np.minimum(starts[:, None] + j, table.size - 1)
        vals = np.where(j < occ[:, None], table[idx], pad[:, None])
        hi = (vals >> np.uint64(32)).astype(np.uint32)
        lo = (vals & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        keys = rng.integers(0, 2**64, size=q, dtype=np.uint64)
        args = tuple(jnp.asarray(a) for a in (
            (keys >> np.uint64(32)).astype(np.uint32),
            (keys & np.uint64(0xFFFFFFFF)).astype(np.uint32),
            hi, lo, occ))
        bytes_ = q * 8 + q * (BW * 8 + 4) + q * 8  # keys + one row pair + out
        ops = 6.0 * q * BW                         # 2 cmps + select + min per slot
        return (lambda: ring_lookup_bucketed(*args)), bytes_, ops

    def edra_case():
        from repro.kernels.edra_tree.ops import edra_tree
        p = 65_536 if full else 8192
        n = 10 * p
        args = tuple(jnp.asarray(a) for a in (
            np.sort(rng.choice(n, size=p, replace=False)).astype(np.uint32),
            np.full(p, n, np.uint32),
            rng.integers(0, n, p).astype(np.uint32),
            rng.uniform(0, 50, p).astype(np.float32),
            rng.integers(0, 2**32, p, dtype=np.uint64).astype(np.uint32)))
        levels = max(int(np.ceil(np.log2(n))) // 2, 2)
        bytes_ = p * 4 * (5 + 5)               # five inputs, five outputs
        ops = 12.0 * p * levels                # per-level ack/ttl arithmetic
        return (lambda: edra_tree(*args, levels=levels, theta=0.25,
                                  delta_avg=0.02)), bytes_, ops

    def decode_case():
        from repro.kernels.decode_attention.ops import decode_attention
        b, h, hkv, hd, s = (4, 8, 2, 128, 1024) if full else (2, 8, 2, 128, 512)
        q = jnp.asarray(rng.standard_normal((b, h, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
        length = jnp.asarray(rng.integers(1, s, size=(b,)), jnp.int32)
        bytes_ = 4 * (b * h * hd + 2 * b * s * hkv * hd + b * h * hd)
        ops = 4.0 * b * s * h * hd             # qk + pv
        return (lambda: decode_attention(q, k, v, length)), bytes_, ops

    def flash_case():
        from repro.kernels.flash_attention.ops import flash_attention
        from repro.kernels.autotune import tiles_for
        b, s, h, hkv, hd = (2, 512, 8, 2, 128) if full else (2, 256, 4, 2, 128)
        q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
        bq = tiles_for("flash_attention", sq=s, sk=s)["bq"]
        passes = -(-s // bq)                   # k/v re-read per q block
        bytes_ = 4 * b * (s * h * hd * 2 + passes * 2 * s * hkv * hd)
        ops = 2.0 * b * h * s * s * hd         # causal: half the square, x2 matmuls
        return (lambda: flash_attention(q, k, v, causal=True)), bytes_, ops

    def ssm_case():
        from repro.kernels.ssm_scan.ops import ssm_scan
        b, l, din, ns = (2, 256, 512, 16) if full else (2, 64, 256, 16)
        x = jnp.asarray(rng.standard_normal((b, l, din)) * 0.1, jnp.float32)
        dt = jnp.asarray(np.abs(rng.standard_normal((b, l, din))) * 0.1,
                         jnp.float32)
        B = jnp.asarray(rng.standard_normal((b, l, ns)) * 0.5, jnp.float32)
        C = jnp.asarray(rng.standard_normal((b, l, ns)) * 0.5, jnp.float32)
        A = jnp.asarray(-np.abs(rng.standard_normal((din, ns))) - 0.1,
                        jnp.float32)
        D = jnp.ones((din,), jnp.float32)
        bytes_ = 4 * (2 * b * l * din + 2 * b * l * ns + din * ns + din
                      + b * l * din + b * din * ns)
        ops = 6.0 * b * l * din * ns           # discretize + state + output
        return (lambda: ssm_scan(x, dt, B, C, A, D)), bytes_, ops

    return [("ring_lookup", ring_lookup_case),
            ("ring_lookup_bucketed", bucketed_case),
            ("edra_tree", edra_case),
            ("decode_attention", decode_case),
            ("flash_attention", flash_case),
            ("ssm_scan", ssm_case)]


def kernel_rooflines(full: bool = False, reps: int = 5) -> List[Dict]:
    rows = []
    prov = provenance()
    for name, build in _kernel_cases(full):
        thunk, bytes_, ops = build()
        us = time_best_of(thunk, reps=reps, warmup=1)
        gb_s = bytes_ / (us / 1e6) / 1e9
        rows.append({
            "kernel": name, "mode": prov["mode"],
            "bytes": int(bytes_), "ops": int(ops),
            "ops_per_byte": round(ops / bytes_, 3),
            "us": round(us, 1),
            "achieved_gb_s": round(gb_s, 3),
        })
        emit(f"roofline/kernel/{name}", us,
             f"bytes={bytes_} ops={ops:.0f} ai={ops / bytes_:.2f} "
             f"achieved={gb_s:.2f}GB/s mode={prov['mode']}")
    return rows


SERVE_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serve.json")


def tp_decode_rows(path: str = SERVE_JSON) -> List[Dict]:
    """Collective-term rows for the TP decode sweep in BENCH_serve.json:
    psum bytes per decode round against the ICI budget, next to the
    1/TP per-device KV footprint.  On host-CPU runs the wall clock is
    emulation noise, but the BYTES are the compiled program's — the
    collective term is what a real multi-chip deployment would pay."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        payload = json.load(f)
    rows = []
    for r in payload.get("tp", {}).get("sweep", []):
        coll_s = r["collective_bytes_per_round"] / (LINKS * LINK_BW)
        rows.append({**r, "collective_term_s": coll_s})
        emit(f"roofline/tp_decode/tp{r['tp']}", r["round_us"],
             f"coll/round={r['collective_bytes_per_round']}B "
             f"coll_term={coll_s * 1e9:.1f}ns "
             f"kv/dev={r['per_device_kv_bytes']}B")
    return rows


def run(full: bool = False) -> None:
    for _ in kernel_rooflines(full):
        pass
    tp_decode_rows()
    recs = load()
    if not recs:
        emit("roofline/missing", 0.0, "run repro.launch.dryrun --all first")
        return
    for rec in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        a = analyze_record(rec)
        emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
             f"compute={a['compute']*1e3:.1f}ms memory={a['memory']*1e3:.1f}ms "
             f"collective={a['collective']*1e3:.1f}ms dominant={a['dominant']} "
             f"useful={a['useful_ratio']*100:.0f}% "
             f"roofline_frac={a['roofline_fraction']*100:.1f}% "
             f"mem/dev={rec['peak_memory_per_device']/2**30:.1f}GiB")
