"""Serve-plane benchmark: continuous-batching decode throughput, churn
migration latency across three re-home strategies, and the
cross-session prefix cache.

Four measurements, emitted to BENCH_serve.json:

  * **decode scaling** — aggregate decode tokens/s as the number of
    active slots grows on one replica.  The vectorized slot engine steps
    every active slot per jitted round, so the round time is ~flat and
    throughput must scale with the active count (the acceptance check:
    NOT gated by the longest session).
  * **migration variants** — wall time from the membership event to
    every affected session being fully re-homed, side by side for the
    three strategies the serve plane has grown: ``whole`` (synchronous
    whole-transcript re-prefill, one retrace per distinct length),
    ``chunked`` (fixed-shape chunk re-prefills overlapped with decode
    rounds), and ``handoff`` (DESIGN.md §11: fetch the victim's KV
    blocks from their replica sets, re-prefill only the final segment).
    Each variant also reports the decode-round degradation measured
    WHILE its migration drains — the handoff's claim is lower
    per-session latency AND a quieter drain.
  * **prefix cache** — admit latency for sessions sharing a system
    prompt, cold (first session computes and publishes the shared
    chunks) vs warm (later sessions import them), plus the hit rate and
    the prefill FLOPs the hits skipped.
  * **concurrent prefill** — decode-round throughput while a chunked
    prefill advances in the background vs idle; the overlap is only a
    win if decode degradation stays small.

Usage: PYTHONPATH=src python benchmarks/bench_serve.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

try:
    from .common import emit, ensure_tuned, provenance, time_best_of
except ImportError:                # standalone: python benchmarks/bench_serve.py
    from common import emit, ensure_tuned, provenance, time_best_of


def _setup(dtype="float32"):
    import jax
    from repro.configs import get_smoke_config
    from repro.models import Model

    cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype=dtype)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, count, seed=0):
    # prompt lengths cycle over a tiny set so prefill jit-compiles once
    # per length, not once per session
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (4, 8, 12)[i % 3], dtype=np.int32)
            for i in range(count)]


def _long_prompts(cfg, count, seed=0):
    # migration-variant prompts: long enough that every transcript
    # crosses chunk boundaries, so the handoff variant has KV blocks to
    # fetch (a 4-token prompt would make every fetch a trivial miss)
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (20, 28, 36)[i % 3], dtype=np.int32)
            for i in range(count)]


def bench_decode_scaling(cfg, model, params, *, slots, max_len,
                         actives, reps) -> list:
    from repro.serve import Replica, Request

    rows = []
    for active in actives:
        rep = Replica(model, slots=slots, max_len=max_len)
        rep.attach_params(params)
        for i, p in enumerate(_prompts(cfg, active)):
            rep.admit(Request(f"b{i}", p, max_new_tokens=max_len))
        # decode_round returns host-side tokens, so it is already synced
        round_us = time_best_of(rep.decode_round, reps=reps, warmup=1,
                                block=False)
        tokens_per_s = active / (round_us / 1e6)
        rows.append({"active_slots": active,
                     "tokens_per_s": round(tokens_per_s, 1),
                     "round_us": round(round_us, 1)})
        emit(f"serve_decode_slots{active}", round_us,
             f"{tokens_per_s:.0f} tok/s")
    return rows


def bench_concurrent_prefill(cfg, model, params, *, slots, max_len,
                             active, reps, chunk=16, duty=6) -> dict:
    """SUSTAINED decode throughput while chunked prefills advance in the
    background vs idle.  Mirrors the serve loop's stall-free schedule: a
    chunk advances only every ``duty``-th round, so the steady-state
    decode hit is ~chunk_cost/(duty*round_cost) instead of doubling
    every round.  Mean over whole duty windows (best-of would only ever
    sample the light rounds)."""
    from repro.serve import Replica, Request

    rep = Replica(model, slots=slots, max_len=max_len, prefill_chunk=chunk)
    rep.attach_params(params)
    for i, p in enumerate(_prompts(cfg, active)):
        rep.admit(Request(f"c{i}", p, max_new_tokens=max_len))

    rng = np.random.default_rng(17)
    prompt = rng.integers(0, cfg.vocab, 3 * chunk, dtype=np.int32)
    seq = [0]

    def busy_round(i: int):
        if rep.num_pending == 0:        # completed: recycle the slot
            sid = f"pf{seq[0]}"
            if sid in rep.sessions:
                rep.evict(sid)
            seq[0] += 1
            rep.begin_admit(Request(f"pf{seq[0]}", prompt, 4))
        if i % duty == 0:
            rep.advance_prefills()
        return rep.decode_round()

    rounds = max(reps, 5 * duty)        # whole duty windows
    # warm through TWO full prefill recycles: completion bumps the
    # active count across a decode bucket, so both bucket traces (and
    # the chunk trace) must be compiled before the timed window
    i = 0
    while seq[0] < 3:
        busy_round(i)
        i += 1
    # best of 3 paired windows: a scheduler hiccup can inflate one
    # ~30 ms window, not all three (same reasoning as the CI gates)
    idle_us = busy_us = None
    degradation = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(rounds):
            rep.decode_round()
        iu = (time.perf_counter() - t0) / rounds * 1e6
        t0 = time.perf_counter()
        for i in range(rounds):
            busy_round(i)
        bu = (time.perf_counter() - t0) / rounds * 1e6
        if bu / iu - 1.0 < degradation:
            idle_us, busy_us, degradation = iu, bu, bu / iu - 1.0
    emit("serve_decode_during_prefill", busy_us,
         f"idle={idle_us:.1f}us, +{degradation * 100:.1f}%")
    return {"active_slots": active, "prefill_chunk": chunk,
            "prefill_duty": duty, "rounds": rounds,
            "idle_round_us": round(idle_us, 1),
            "busy_round_us": round(busy_us, 1),
            "decode_degradation": round(degradation, 4)}


def bench_migration(cfg, model, params, *, slots, max_len, sessions, nodes,
                    variant="handoff", chunk=16, window=6) -> dict:
    """One node kill under one re-home strategy.  Reports both the
    per-session re-home latency AND the decode-round time measured while
    the migration drains (vs an idle window on the same cluster in the
    same run — runner speed cancels in the ratio)."""
    from repro.runtime import Membership
    from repro.serve import Request, ServeCluster

    prefill_chunk = None if variant == "whole" else chunk
    m = Membership(t_q=60.0, now=lambda: 0.0)
    for i in range(nodes):
        m.request_join(f"10.8.0.{i}", 7000 + i)
    cluster = ServeCluster(m, model, params, slots=slots, max_len=max_len,
                           prefill_chunk=prefill_chunk,
                           kv_blocks=(variant == "handoff"),
                           prefix_cache=False)
    for i, p in enumerate(_long_prompts(cfg, sessions, seed=3)):
        cluster.submit(Request(f"m{i}", p, max_new_tokens=24))
    cluster.step()                               # warm every replica's jit
    if prefill_chunk:
        # warm the (shared, fixed-shape) chunk trace so the timed event
        # measures the steady-state path, not one-time compilation
        rep = next(iter(cluster.replicas.values()))
        rep._run_chunks(np.zeros(3, np.int32),
                        model.init_cache(1, max_len))
    by_owner: dict = {}
    for rec in cluster.sessions.values():
        if not rec.done:
            by_owner.setdefault(rec.owner, []).append(rec)
    victim = max(by_owner, key=lambda o: len(by_owner[o]))
    n_victim = len(by_owner[victim])
    t0 = time.perf_counter()
    m.fail(victim)         # whole/handoff re-home inside the handler;
    event_s = time.perf_counter() - t0           # chunked only INITIATES
    steps = 0              # overlapped chunks drain with decode rounds
    busy = []
    while cluster.pending_migrations:
        t1 = time.perf_counter()
        cluster.step()
        busy.append(time.perf_counter() - t1)
        steps += 1
        assert steps < 256, "overlapped re-prefills failed to drain"
    dt = time.perf_counter() - t0
    while len(busy) < window:  # no (or short) drain: post-event rounds
        t1 = time.perf_counter()
        cluster.step()
        busy.append(time.perf_counter() - t1)
    busy_us = float(np.mean(busy)) * 1e6
    # idle baseline AFTER the drain, on the SAME post-kill replica count
    # (a pre-kill baseline steps one extra replica and reads as a
    # phantom speedup); runner speed cancels in the within-run ratio
    t1 = time.perf_counter()
    for _ in range(window):
        cluster.step()
    idle_us = (time.perf_counter() - t1) / window * 1e6
    degradation = busy_us / idle_us - 1.0
    moved = cluster.migrated_sessions
    per_session_ms = dt / max(moved, 1) * 1e3
    emit(f"serve_migration_{variant}", dt * 1e6,
         f"{moved} sessions, {per_session_ms:.1f} ms/session, "
         f"event={event_s * 1e6:.0f}us, drain +{degradation * 100:.1f}%")
    row = {"variant": variant, "nodes": nodes, "sessions": sessions,
           "victim_sessions": n_victim, "sessions_moved": moved,
           "prefill_chunk": prefill_chunk,
           "event_latency_s": round(event_s, 6),
           "drain_steps": steps,
           "rehome_latency_s": round(dt, 4),
           "per_session_ms": round(per_session_ms, 2),
           "idle_round_us": round(idle_us, 1),
           "drain_round_us": round(busy_us, 1),
           "drain_decode_degradation": round(degradation, 4)}
    if variant == "handoff":
        row.update({"handoffs": cluster.handoffs,
                    "handoff_misses": cluster.handoff_misses,
                    "handoff_chunks": cluster.handoff_chunks,
                    "exported_blocks": cluster.exported_blocks,
                    "block_upload_bytes": cluster.blocks.upload_bytes,
                    "block_repair_bytes": cluster.blocks.repair_bytes})
    return row


def bench_prefix_cache(cfg, model, params, *, max_len, chunk=16,
                       sessions=8) -> dict:
    """Cold vs warm admit latency for sessions sharing a 2-chunk system
    prompt: the first session computes and publishes the shared chunks,
    every later one imports them and prefills only its private tail."""
    from repro.core.ringstate import RingState
    from repro.dht.data import BlockStore, PrefixCache
    from repro.serve import Replica, Request

    state = RingState()
    for i in range(4):
        state.add((i + 1) * (2**64 // 5))
    pc = PrefixCache(BlockStore(state, replication=2), chunk=chunk,
                     salt=cfg.name)
    rep = Replica(model, slots=2, max_len=max_len, prefill_chunk=chunk,
                  prefix_cache=pc)
    rep.attach_params(params)
    rng = np.random.default_rng(23)
    system = rng.integers(0, cfg.vocab, 2 * chunk, dtype=np.int32)

    def admit(i):
        tail = rng.integers(0, cfg.vocab, 3 + (i % 4), dtype=np.int32)
        t0 = time.perf_counter()
        rep.admit(Request(f"px{i}", np.concatenate([system, tail]),
                          max_new_tokens=2))
        dt = (time.perf_counter() - t0) * 1e6
        rep.evict(f"px{i}")
        return dt

    # compile the shared chunk program AND the export/insert path (a
    # disjoint throwaway prompt, so nothing it publishes can ever hit)
    # outside the timed admits
    rep.admit(Request("pxwarm",
                      np.full(2 * chunk + 3, cfg.vocab - 1, np.int32),
                      max_new_tokens=2))
    rep.evict("pxwarm")
    cold_us = admit(0)       # computes + publishes the 2 shared chunks
    warm_us = float(np.mean([admit(i) for i in range(1, sessions)]))
    hit_rate = pc.hits / max(pc.hits + pc.misses, 1)
    # prefill forward cost ~ 2 FLOPs per parameter per token position
    saved_flops = 2 * model.param_count() * pc.tokens_saved
    emit("serve_prefix_warm_admit", warm_us,
         f"cold={cold_us:.0f}us, hit_rate={hit_rate:.2f}")
    return {"sessions": sessions, "chunk": chunk,
            "system_prompt_tokens": int(2 * chunk),
            "cold_admit_us": round(cold_us, 1),
            "warm_admit_us": round(warm_us, 1),
            "admit_speedup": round(cold_us / warm_us, 2),
            "prefix_hits": pc.hits, "prefix_misses": pc.misses,
            "hit_rate": round(hit_rate, 4),
            "tokens_saved": pc.tokens_saved,
            "saved_prefill_flops": int(saved_flops)}


def bench_prefix_affinity(cfg, model, params, *, sessions=8) -> dict:
    """Prefix-cache-aware admission on a 2-node cluster: sessions
    sharing a system prompt should land on the replica that already
    holds its prefix chunks whenever the replica_set gives submit a
    choice — the affinity-hit count is the placement wins."""
    from repro.runtime import Membership
    from repro.serve import Request, ServeCluster

    m = Membership(t_q=60.0, now=lambda: 0.0)
    for i in range(2):
        m.request_join(f"10.9.0.{i}", 7000 + i)
    cluster = ServeCluster(m, model, params, slots=max(sessions, 8),
                           max_len=64, replication=2)
    rng = np.random.default_rng(41)
    system = rng.integers(0, cfg.vocab, 20, dtype=np.int32)
    for i in range(sessions):
        cluster.submit(Request(f"af{i}", system.copy(), max_new_tokens=2))
    hits = cluster.prefix_affinity_hits
    owners = {rec.owner for rec in cluster.sessions.values()}
    emit("serve_prefix_affinity", 0.0,
         f"{hits}/{sessions - 1} steerable admits kept warm "
         f"({len(owners)} owner(s))")
    return {"sessions": sessions, "prefix_affinity_hits": hits,
            "distinct_owners": len(owners)}


def run(full: bool = False, out: str = "BENCH_serve.json") -> dict:
    ensure_tuned()
    cfg, model, params = _setup()
    slots = 16 if full else 8
    actives = [1, 2, 4, 8] + ([16] if full else [])
    reps = 50 if full else 15
    decode = bench_decode_scaling(cfg, model, params, slots=slots,
                                  max_len=64, actives=actives, reps=reps)
    variants = {
        v: bench_migration(cfg, model, params, slots=slots, max_len=64,
                           sessions=12 if full else 8,
                           nodes=5 if full else 4, variant=v)
        for v in ("whole", "chunked", "handoff")
    }
    prefix = bench_prefix_cache(cfg, model, params, max_len=64,
                                sessions=10 if full else 8)
    prefix.update(bench_prefix_affinity(cfg, model, params,
                                        sessions=10 if full else 8))
    concurrent = bench_concurrent_prefill(cfg, model, params, slots=slots,
                                          max_len=64, active=4, reps=reps)
    try:
        from .bench_tp import collect as collect_tp
    except ImportError:
        from bench_tp import collect as collect_tp
    tp = collect_tp(full=full)          # 8-host-device subprocess sweep
    for r in tp["sweep"]:
        emit(f"serve_tp{r['tp']}_decode", r["round_us"],
             f"kv/dev={r['per_device_kv_bytes']}B "
             f"coll/round={r['collective_bytes_per_round']}B")
    prov = provenance()
    payload = {"benchmark": "serve", "model": cfg.name,
               "mode": prov["mode"], "provenance": prov,
               "decode": decode,
               "migration": variants["handoff"],   # the default serve path
               "migration_variants": variants,
               "prefix_cache": prefix,
               "concurrent_prefill": concurrent,
               "tp": tp}
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(full=args.full and not args.quick, out=args.out)


if __name__ == "__main__":
    main()
