"""Serve-plane benchmark: continuous-batching decode throughput and
churn migration latency.

Two measurements, emitted to BENCH_serve.json:

  * **decode scaling** — aggregate decode tokens/s as the number of
    active slots grows on one replica.  The vectorized slot engine steps
    every active slot per jitted round, so the round time is ~flat and
    throughput must scale with the active count (the acceptance check:
    NOT gated by the longest session).
  * **migration latency** — wall time for the membership-event handler
    to re-home every affected session (owner_diff -> evict ->
    re-prefill on the replica_set successor) when a loaded replica is
    killed mid-decode.

Usage: PYTHONPATH=src python benchmarks/bench_serve.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

try:
    from .common import emit
except ImportError:                # standalone: python benchmarks/bench_serve.py
    from common import emit


def _setup(dtype="float32"):
    import jax
    from repro.configs import get_smoke_config
    from repro.models import Model

    cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype=dtype)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, count, seed=0):
    # prompt lengths cycle over a tiny set so prefill jit-compiles once
    # per length, not once per session
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (4, 8, 12)[i % 3], dtype=np.int32)
            for i in range(count)]


def bench_decode_scaling(cfg, model, params, *, slots, max_len,
                         actives, reps) -> list:
    from repro.serve import Replica, Request

    rows = []
    for active in actives:
        rep = Replica(model, slots=slots, max_len=max_len)
        rep.attach_params(params)
        for i, p in enumerate(_prompts(cfg, active)):
            rep.admit(Request(f"b{i}", p, max_new_tokens=max_len))
        rep.decode_round()                       # warmup: jit trace
        t0 = time.perf_counter()
        for _ in range(reps):
            rep.decode_round()
        dt = time.perf_counter() - t0
        tokens_per_s = active * reps / dt
        round_us = dt / reps * 1e6
        rows.append({"active_slots": active,
                     "tokens_per_s": round(tokens_per_s, 1),
                     "round_us": round(round_us, 1)})
        emit(f"serve_decode_slots{active}", round_us,
             f"{tokens_per_s:.0f} tok/s")
    return rows


def bench_migration(cfg, model, params, *, slots, max_len,
                    sessions, nodes) -> dict:
    from repro.runtime import Membership
    from repro.serve import Request, ServeCluster

    m = Membership(t_q=60.0, now=lambda: 0.0)
    for i in range(nodes):
        m.request_join(f"10.8.0.{i}", 7000 + i)
    cluster = ServeCluster(m, model, params, slots=slots, max_len=max_len)
    for i, p in enumerate(_prompts(cfg, sessions, seed=3)):
        cluster.submit(Request(f"m{i}", p, max_new_tokens=max_len - 16))
    cluster.step()                               # warm every replica's jit
    by_owner: dict = {}
    for rec in cluster.sessions.values():
        by_owner.setdefault(rec.owner, []).append(rec)
    victim = max(by_owner, key=lambda o: len(by_owner[o]))
    n_victim = len(by_owner[victim])
    t0 = time.perf_counter()
    m.fail(victim)                               # handler migrates inline
    dt = time.perf_counter() - t0
    moved = cluster.migrated_sessions
    per_session_ms = dt / max(moved, 1) * 1e3
    emit("serve_migration_event", dt * 1e6,
         f"{moved} sessions, {per_session_ms:.1f} ms/session")
    return {"nodes": nodes, "sessions": sessions,
            "victim_sessions": n_victim, "sessions_moved": moved,
            "event_latency_s": round(dt, 4),
            "per_session_ms": round(per_session_ms, 2)}


def run(full: bool = False, out: str = "BENCH_serve.json") -> dict:
    cfg, model, params = _setup()
    slots = 16 if full else 8
    actives = [1, 2, 4, 8] + ([16] if full else [])
    reps = 50 if full else 15
    decode = bench_decode_scaling(cfg, model, params, slots=slots,
                                  max_len=64, actives=actives, reps=reps)
    migration = bench_migration(cfg, model, params, slots=slots, max_len=64,
                                sessions=12 if full else 8,
                                nodes=5 if full else 4)
    payload = {"benchmark": "serve", "model": cfg.name,
               "decode": decode, "migration": migration}
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(full=args.full and not args.quick, out=args.out)


if __name__ == "__main__":
    main()
