"""Serve-plane benchmark: continuous-batching decode throughput and
churn migration latency.

Three measurements, emitted to BENCH_serve.json:

  * **decode scaling** — aggregate decode tokens/s as the number of
    active slots grows on one replica.  The vectorized slot engine steps
    every active slot per jitted round, so the round time is ~flat and
    throughput must scale with the active count (the acceptance check:
    NOT gated by the longest session).
  * **migration latency** — wall time from the membership event to every
    affected session being fully re-homed.  Re-prefills run as
    fixed-shape CHUNKS overlapped with decode rounds (one jit trace for
    all prompt lengths, instead of a per-length retrace stalling the
    event handler), so the event handler itself returns in µs and the
    per-session cost is the drain time.
  * **concurrent prefill** — decode-round throughput while a chunked
    prefill advances in the background vs idle; the overlap is only a
    win if decode degradation stays small.

Usage: PYTHONPATH=src python benchmarks/bench_serve.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

try:
    from .common import emit, ensure_tuned, provenance, time_best_of
except ImportError:                # standalone: python benchmarks/bench_serve.py
    from common import emit, ensure_tuned, provenance, time_best_of


def _setup(dtype="float32"):
    import jax
    from repro.configs import get_smoke_config
    from repro.models import Model

    cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype=dtype)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, count, seed=0):
    # prompt lengths cycle over a tiny set so prefill jit-compiles once
    # per length, not once per session
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (4, 8, 12)[i % 3], dtype=np.int32)
            for i in range(count)]


def bench_decode_scaling(cfg, model, params, *, slots, max_len,
                         actives, reps) -> list:
    from repro.serve import Replica, Request

    rows = []
    for active in actives:
        rep = Replica(model, slots=slots, max_len=max_len)
        rep.attach_params(params)
        for i, p in enumerate(_prompts(cfg, active)):
            rep.admit(Request(f"b{i}", p, max_new_tokens=max_len))
        # decode_round returns host-side tokens, so it is already synced
        round_us = time_best_of(rep.decode_round, reps=reps, warmup=1,
                                block=False)
        tokens_per_s = active / (round_us / 1e6)
        rows.append({"active_slots": active,
                     "tokens_per_s": round(tokens_per_s, 1),
                     "round_us": round(round_us, 1)})
        emit(f"serve_decode_slots{active}", round_us,
             f"{tokens_per_s:.0f} tok/s")
    return rows


def bench_concurrent_prefill(cfg, model, params, *, slots, max_len,
                             active, reps, chunk=16, duty=6) -> dict:
    """SUSTAINED decode throughput while chunked prefills advance in the
    background vs idle.  Mirrors the serve loop's stall-free schedule: a
    chunk advances only every ``duty``-th round, so the steady-state
    decode hit is ~chunk_cost/(duty*round_cost) instead of doubling
    every round.  Mean over whole duty windows (best-of would only ever
    sample the light rounds)."""
    from repro.serve import Replica, Request

    rep = Replica(model, slots=slots, max_len=max_len, prefill_chunk=chunk)
    rep.attach_params(params)
    for i, p in enumerate(_prompts(cfg, active)):
        rep.admit(Request(f"c{i}", p, max_new_tokens=max_len))

    rng = np.random.default_rng(17)
    prompt = rng.integers(0, cfg.vocab, 3 * chunk, dtype=np.int32)
    seq = [0]

    def busy_round(i: int):
        if rep.num_pending == 0:        # completed: recycle the slot
            sid = f"pf{seq[0]}"
            if sid in rep.sessions:
                rep.evict(sid)
            seq[0] += 1
            rep.begin_admit(Request(f"pf{seq[0]}", prompt, 4))
        if i % duty == 0:
            rep.advance_prefills()
        return rep.decode_round()

    rounds = max(reps, 5 * duty)        # whole duty windows
    # warm through TWO full prefill recycles: completion bumps the
    # active count across a decode bucket, so both bucket traces (and
    # the chunk trace) must be compiled before the timed window
    i = 0
    while seq[0] < 3:
        busy_round(i)
        i += 1
    # best of 3 paired windows: a scheduler hiccup can inflate one
    # ~30 ms window, not all three (same reasoning as the CI gates)
    idle_us = busy_us = None
    degradation = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(rounds):
            rep.decode_round()
        iu = (time.perf_counter() - t0) / rounds * 1e6
        t0 = time.perf_counter()
        for i in range(rounds):
            busy_round(i)
        bu = (time.perf_counter() - t0) / rounds * 1e6
        if bu / iu - 1.0 < degradation:
            idle_us, busy_us, degradation = iu, bu, bu / iu - 1.0
    emit("serve_decode_during_prefill", busy_us,
         f"idle={idle_us:.1f}us, +{degradation * 100:.1f}%")
    return {"active_slots": active, "prefill_chunk": chunk,
            "prefill_duty": duty, "rounds": rounds,
            "idle_round_us": round(idle_us, 1),
            "busy_round_us": round(busy_us, 1),
            "decode_degradation": round(degradation, 4)}


def bench_migration(cfg, model, params, *, slots, max_len,
                    sessions, nodes, prefill_chunk=16) -> dict:
    from repro.runtime import Membership
    from repro.serve import Request, ServeCluster

    m = Membership(t_q=60.0, now=lambda: 0.0)
    for i in range(nodes):
        m.request_join(f"10.8.0.{i}", 7000 + i)
    cluster = ServeCluster(m, model, params, slots=slots, max_len=max_len,
                           prefill_chunk=prefill_chunk)
    for i, p in enumerate(_prompts(cfg, sessions, seed=3)):
        cluster.submit(Request(f"m{i}", p, max_new_tokens=max_len - 16))
    cluster.step()                               # warm every replica's jit
    if prefill_chunk:
        # warm the (shared, fixed-shape) chunk trace so the timed event
        # measures the steady-state path, not one-time compilation
        rep = next(iter(cluster.replicas.values()))
        rep._run_chunks(np.zeros(3, np.int32),
                        model.init_cache(1, max_len))
    by_owner: dict = {}
    for rec in cluster.sessions.values():
        by_owner.setdefault(rec.owner, []).append(rec)
    victim = max(by_owner, key=lambda o: len(by_owner[o]))
    n_victim = len(by_owner[victim])
    t0 = time.perf_counter()
    m.fail(victim)               # handler only INITIATES re-homes now:
    event_s = time.perf_counter() - t0
    steps = 0                    # chunks drain overlapped with decode
    while cluster.pending_migrations:
        cluster.step()
        steps += 1
        assert steps < 256, "overlapped re-prefills failed to drain"
    dt = time.perf_counter() - t0
    moved = cluster.migrated_sessions
    per_session_ms = dt / max(moved, 1) * 1e3
    emit("serve_migration_event", dt * 1e6,
         f"{moved} sessions, {per_session_ms:.1f} ms/session, "
         f"event={event_s * 1e6:.0f}us")
    return {"nodes": nodes, "sessions": sessions,
            "victim_sessions": n_victim, "sessions_moved": moved,
            "prefill_chunk": prefill_chunk,
            "event_latency_s": round(event_s, 6),
            "drain_steps": steps,
            "rehome_latency_s": round(dt, 4),
            "per_session_ms": round(per_session_ms, 2)}


def run(full: bool = False, out: str = "BENCH_serve.json") -> dict:
    ensure_tuned()
    cfg, model, params = _setup()
    slots = 16 if full else 8
    actives = [1, 2, 4, 8] + ([16] if full else [])
    reps = 50 if full else 15
    decode = bench_decode_scaling(cfg, model, params, slots=slots,
                                  max_len=64, actives=actives, reps=reps)
    migration = bench_migration(cfg, model, params, slots=slots, max_len=64,
                                sessions=12 if full else 8,
                                nodes=5 if full else 4)
    concurrent = bench_concurrent_prefill(cfg, model, params, slots=slots,
                                          max_len=64, active=4, reps=reps)
    prov = provenance()
    payload = {"benchmark": "serve", "model": cfg.name,
               "mode": prov["mode"], "provenance": prov,
               "decode": decode, "migration": migration,
               "concurrent_prefill": concurrent}
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(full=args.full and not args.quick, out=args.out)


if __name__ == "__main__":
    main()
