"""Placement-policy tradeoff bench (DESIGN.md §13): request latency vs
maintenance traffic, ``RingSuccessor`` vs ``LatencyAware``.

Simulates the serve plane's request/churn loop at the RingState level —
no model, no DES event queue: sessions are admitted from random origin
nodes, the policy picks the serving member of each session's replica
set, and per-request round-trips are sampled from ``GeoDelay`` around
the SAME per-region-pair medians the policy ranks by.  Churn batches
(event rate 2n/S_avg from the shared ``ChurnConfig``, the §VII
methodology) drive ``owner_diff``-based re-ranking of affected sessions
and ``BlockStore.sync`` repair — the maintenance-bytes axis.

Both policies in a cell consume the IDENTICAL event/request stream (one
numpy RNG, policy code never touches it), so every delta in the output
is the policy's doing.  Two environments:

  * ``lan`` — ``Topology.single_region()`` (§VII-C/D, 0.14 ms RTT):
    LatencyAware degenerates to ring order; the null test.
  * ``wan`` — ``Topology.multi_dc(4)`` (§VII-B PlanetLab regime,
    ~18-95 ms one-way between DCs): the headline cell.  CI gates that at
    n=10^4 LatencyAware's p99 strictly dominates RingSuccessor's while
    the maintenance-bytes ratio stays within the committed band (gates
    compare ratios across policies in ONE run, never absolute ms across
    runners).

Emits BENCH_placement.json.
"""
from __future__ import annotations

import argparse
import json
import random
from typing import List

import numpy as np

try:
    from .common import emit, header
except ImportError:                                    # pragma: no cover
    from common import emit, header

from repro.core.churn import ChurnConfig
from repro.core.edra import Event
from repro.core.ringstate import RingState
from repro.dht.data import BlockStore
from repro.dht.des import GeoDelay
from repro.runtime.placement import (LatencyAware, PlacementPolicy,
                                     RingSuccessor, Topology)

R = 2                        # replica-set width (ServeCluster default)
SESSIONS = 512               # tracked sessions per cell
BLOCK_BYTES = 1 << 14        # one 16 KiB KV slab per session, placed at
KV_MIGRATION_BYTES = 1 << 14  # ... its key; moving a session costs the same


def _rand_ids(rng: np.random.Generator, k: int) -> np.ndarray:
    x = rng.integers(0, 2**64, size=2 * k + 16, dtype=np.uint64)
    x = np.unique(x)[:k]
    assert x.size == k
    return x


def simulate(n: int, policy: PlacementPolicy, topo: Topology,
             cfg: ChurnConfig, *, waves: int, requests_per_wave: int) -> dict:
    """One (env, n, policy) cell.  Same ``cfg.seed`` => bit-identical
    event and request streams across policies (the RNG call sequence is
    policy-independent; ranking is deterministic and RNG-free)."""
    rng = np.random.default_rng(cfg.seed)
    drng = random.Random(cfg.seed + 1)
    delay = GeoDelay(topo)
    state = RingState(_rand_ids(rng, n))
    state.track_owner_diffs()
    store = BlockStore(state, replication=R, policy=policy)

    # admission: each session gets an origin node and a ring key; its KV
    # block is placed AT the key, so session and block share a replica
    # set (the serve plane's co-location invariant)
    skeys = rng.integers(0, 2**64, size=SESSIONS, dtype=np.uint64)
    ids = state.active_ids()
    origins = ids[rng.integers(0, ids.size, size=SESSIONS)]
    payload = bytes(BLOCK_BYTES)
    owners = np.empty(SESSIONS, np.uint64)
    for i in range(SESSIONS):
        group = policy.replica_group(state, int(skeys[i]), R,
                                     origin=int(origins[i]))
        owners[i] = group[0]
        store.put(f"kv/{i}", payload, at=int(skeys[i]))

    # churn: §VII event rate 2n/S_avg over the metered window, spread
    # evenly across the waves (joins and leaves in equal measure)
    total_events = 2.0 * n / cfg.s_avg * cfg.duration
    batch = max(2, int(round(total_events / waves)))
    lat_ms: List[float] = []
    migration_bytes = 0
    migrations = 0
    for _ in range(waves):
        pick = rng.integers(0, SESSIONS, size=requests_per_wave)
        for s in pick:
            o, w = int(origins[s]), int(owners[s])
            rtt = (delay.sample_pair(drng, o, w)
                   + delay.sample_pair(drng, w, o))
            lat_ms.append(rtt * 1e3)
        v0 = state.active_version
        live = state.active_ids()
        leave = np.unique(live[rng.integers(0, live.size, size=batch // 2)])
        join = _rand_ids(rng, batch - batch // 2)
        evs = [Event(subject_id=int(p), kind="leave") for p in leave]
        evs += [Event(subject_id=int(p), kind="join") for p in join]
        state.apply_events(evs)
        store.sync()                        # O(affected) block repair
        diff = state.owner_diff(v0)
        # owner_diff-driven re-rank, exactly the serve plane's rule: only
        # affected (or orphaned) sessions are re-ranked, and a session
        # stays put unless the policy's first pick moved off its holder
        gone = ~np.isin(owners, state.active_ids())
        for s in np.nonzero(diff.affected(skeys) | gone)[0]:
            prefer = None if gone[s] else int(owners[s])
            group = policy.replica_group(state, int(skeys[s]), R,
                                         origin=int(origins[s]),
                                         prefer=prefer)
            if group[0] != owners[s]:
                owners[s] = group[0]
                migration_bytes += KV_MIGRATION_BYTES
                migrations += 1

    lat = np.asarray(lat_ms)
    return {
        "n": n, "policy": policy.name, "events_per_wave": batch,
        "requests": int(lat.size),
        "p50_ms": round(float(np.percentile(lat, 50)), 4),
        "p99_ms": round(float(np.percentile(lat, 99)), 4),
        "migrations": migrations,
        "migration_bytes": migration_bytes,
        "repair_bytes": store.repair_bytes,
        "maintenance_bytes": store.repair_bytes + migration_bytes,
    }


def run(full: bool = False, *, out: str = "BENCH_placement.json",
        sizes=None) -> List[dict]:
    if sizes is None:
        sizes = (10**3, 10**4, 10**5) if full else (10**3, 10**4)
    waves = 20 if full else 10
    rpw = 400 if full else 200
    envs = [("lan", Topology.single_region()),
            ("wan", Topology.multi_dc(4))]
    results = []
    for env, topo in envs:
        policies = [RingSuccessor(),
                    LatencyAware(topo, affinity_ms=5.0, tie_ms=0.5)]
        for n in sizes:
            cfg = ChurnConfig(n=n, s_avg=3600.0, duration=1800.0, seed=0)
            for pol in policies:
                row = simulate(n, pol, topo, cfg,
                               waves=waves, requests_per_wave=rpw)
                row["env"] = env
                results.append(row)
                emit(f"placement_{env}_n{n}_{pol.name}",
                     row["p99_ms"] * 1e3,
                     f"p50={row['p50_ms']}ms "
                     f"maint={row['maintenance_bytes']}B")
    payload = {
        "bench": "placement",
        "config": {"replication": R, "sessions": SESSIONS,
                   "block_bytes": BLOCK_BYTES, "s_avg": 3600.0,
                   "duration": 1800.0, "waves": waves,
                   "requests_per_wave": rpw, "seed": 0},
        "results": results,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--sizes", default=None,
                    help="comma-separated ring sizes, e.g. 1000,10000")
    ap.add_argument("--out", default="BENCH_placement.json")
    args = ap.parse_args()
    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else None)
    header()
    run(full=args.full, out=args.out, sizes=sizes)


if __name__ == "__main__":
    main()
