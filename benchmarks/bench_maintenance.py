"""Maintenance-traffic benchmark: the §VII D1HT-vs-1h-Calot comparison
at the paper's Internet scale (Figs 3-4), on the vectorized churn plane.

For ring sizes n in {10^3 .. 10^6} runs the full churn measurement
window (continuous join/leave/crash churn, Gnutella-session dynamics)
through ``repro.core.jax_sim.simulate_churn`` for BOTH protocols and
records:

  * per-peer mean and system-wide sum maintenance bandwidth (bit/s),
    against the analytical models (Eqs IV.5-IV.7 / Eq VII.1),
  * the one-hop-lookup fraction (claim C1 under churn),
  * simulated events/s (wall-clock throughput of the plane — the
    ``edra_tree`` kernel hot path; the CI regression gate watches the
    n=10^5 / n=10^4 throughput ratio, which cancels runner speed).

Emits BENCH_maintenance.json (cwd by default) so future PRs can track
both the paper reproduction (D1HT < Calot ordering, model agreement)
and the simulation plane's throughput.

Usage: PYTHONPATH=src python benchmarks/bench_maintenance.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.churn import ChurnConfig
from repro.core.jax_sim import simulate_churn


def _run_one(n: int, proto: str, duration: float, warmup: float,
             seed: int, interpret) -> dict:
    cfg = ChurnConfig(n=n, s_avg=174 * 60, protocol=proto,
                      duration=duration, warmup=warmup, seed=seed)
    t0 = time.perf_counter()
    r = simulate_churn(cfg, interpret=interpret)
    wall = time.perf_counter() - t0
    return {
        "mean_out_bps": round(r.mean_out_bps, 1),
        "sum_out_kbps": round(r.sum_out_bps / 1000.0, 1),
        "one_hop_fraction": round(r.one_hop_fraction, 5),
        "analytical_bps": round(r.analytical_bps, 1),
        "ratio_sim_over_model": round(
            r.mean_out_bps / max(r.analytical_bps, 1e-9), 3),
        "mean_ack_s": round(r.mean_ack_s, 3),
        "events": r.events,
        "wall_s": round(wall, 2),
        "events_per_s": round(r.events / max(wall, 1e-9), 1),
    }


def run(full: bool = False, *, out: str = "BENCH_maintenance.json",
        sizes=None, duration: float = None, warmup: float = None,
        seed: int = 1, interpret=None) -> list:
    """Harness entry point (benchmarks.run registers this).

    ``full`` uses the paper's 30-min metered window on the 10^3..10^6
    sweep; quick mode shrinks the window and sizes for the CI smoke.
    The regression gate re-runs ``--sizes 10000 100000`` at FULL window
    settings so its numbers are comparable with the committed JSON.
    """
    if sizes is None:
        sizes = (10**3, 10**4, 10**5, 10**6) if full else (10**3, 10**4)
    duration = duration if duration is not None else (1800.0 if full else 300.0)
    warmup = warmup if warmup is not None else (300.0 if full else 60.0)
    results = []
    for n in sizes:
        row = {"n": n, "s_avg_min": 174, "duration_s": duration}
        for proto in ("d1ht", "calot"):
            row[proto] = _run_one(n, proto, duration, warmup, seed,
                                  interpret)
        row["calot_over_d1ht"] = round(
            row["calot"]["mean_out_bps"]
            / max(row["d1ht"]["mean_out_bps"], 1e-9), 2)
        results.append(row)
        print(f"n={n:>8}  d1ht={row['d1ht']['mean_out_bps']:>9} bps "
              f"(model {row['d1ht']['analytical_bps']})  "
              f"calot={row['calot']['mean_out_bps']:>10} bps "
              f"(model {row['calot']['analytical_bps']})  "
              f"calot/d1ht={row['calot_over_d1ht']:>5}x  "
              f"onehop={row['d1ht']['one_hop_fraction']}  "
              f"sim={row['d1ht']['events_per_s']} ev/s", flush=True)

    try:
        from .common import provenance
    except ImportError:
        from common import provenance
    prov = provenance(interpret)
    payload = {
        "benchmark": "maintenance",
        "window": "full-window" if full else "quick",
        "mode": prov["mode"],
        "provenance": prov,
        "results": results,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_maintenance.json")
    ap.add_argument("--quick", action="store_true",
                    help="short window + small sizes (CI smoke)")
    ap.add_argument("--sizes", type=int, nargs="+", default=None,
                    help="ring sizes to sweep (default: 1e3..1e6 full)")
    ap.add_argument("--no-interpret", action="store_true",
                    help="run the compiled Pallas kernel (real TPU only)")
    args = ap.parse_args()
    run(full=not args.quick, out=args.out,
        sizes=tuple(args.sizes) if args.sizes else None,
        interpret=False if args.no_interpret else None)


if __name__ == "__main__":
    main()
