"""Fig. 8: Quarantine overhead reduction vs system size (T_q = 10 min)."""
from repro.core import analysis as A

from .common import emit, timed


def run(full: bool = False) -> None:
    for label, s_min, vol in [("kad", 169, 0.24), ("gnutella", 174, 0.31)]:
        for n in (10**4, 10**5, 10**6, 10**7):
            with timed() as t:
                red = A.quarantine_reduction(n, s_min * 60, vol)
            emit(f"fig8/{label}/n={n:.0e}", t["us"],
                 f"reduction={red*100:.1f}% (paper asymptote "
                 f"{vol*100:.0f}%)")
