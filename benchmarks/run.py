"""Benchmark harness — one module per paper table/figure + roofline +
the system hot paths (ring lookup, serve plane).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig7,serve]

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py);
``ring_lookup``, ``serve``, ``maintenance``, ``latency`` and
``placement`` additionally emit BENCH_ring_lookup.json /
BENCH_serve.json / BENCH_maintenance.json / BENCH_latency.json /
BENCH_placement.json so future PRs can track the hot paths.
"""
from __future__ import annotations

import argparse

from . import (bench_latency, bench_maintenance, bench_placement,
               bench_ring_lookup, bench_serve, bench_tp, fig3_planetlab_bw,
               fig4_hpc_bw, fig5_latency, fig7_analytical, fig8_quarantine,
               roofline, table_validation)
from .common import header

ALL = {
    "fig3": fig3_planetlab_bw.run,
    "fig4": fig4_hpc_bw.run,
    "fig5": fig5_latency.run,
    "fig7": fig7_analytical.run,
    "fig8": fig8_quarantine.run,
    "validation": table_validation.run,
    "roofline": roofline.run,
    "ring_lookup": bench_ring_lookup.run,
    "serve": bench_serve.run,
    "tp": bench_tp.run,
    "maintenance": bench_maintenance.run,
    "latency": bench_latency.run,
    "placement": bench_placement.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slower DES runs)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(ALL))
    args = ap.parse_args()
    names = list(ALL) if not args.only else args.only.split(",")
    header()
    for name in names:
        ALL[name](full=args.full)


if __name__ == "__main__":
    main()
