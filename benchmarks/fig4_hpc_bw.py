"""Fig. 4: HPC datacenter (LAN) bandwidth under two churn rates
(S_avg = 174 min and 60 min)."""
from repro.dht import ChurnConfig, LanDelay, run_churn

from .common import emit, timed


def run(full: bool = False) -> None:
    sizes = [512, 1024, 2048, 4000] if full else [256, 512]
    dur = 1200 if full else 600
    for mins in (174, 60):
        for proto in ("d1ht", "calot"):
            for n in sizes:
                with timed() as t:
                    r = run_churn(ChurnConfig(
                        n=n, s_avg=mins * 60, duration=dur, warmup=120,
                        protocol=proto, delay=LanDelay(), seed=24))
                emit(f"fig4/{mins}min/{proto}/n={n}", t["us"],
                     f"sum_out={r.sum_out_bps/1e3:.1f}kbps "
                     f"per_peer={r.mean_out_bps:.1f}bps "
                     f"model={r.analytical_bps:.1f}bps "
                     f"sim/model={r.mean_out_bps/r.analytical_bps:.2f} "
                     f"one_hop={r.one_hop_fraction*100:.2f}%")
