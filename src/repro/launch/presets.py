"""Baseline parallelism presets per (arch family × shape mode).

The mesh SHAPE is fixed by the assignment ((16,16) / (2,16,16)); what a
framework chooses is how logical axes map onto it.  Baselines:

  * dense/ssm/hybrid/encdec/vlm TRAIN  -> pure DP + 2-axis FSDP
        batch over (pod,data,model); weight d_model rows over both axes.
        A 3-35B dense model on 256 chips is compute-starved under TP=16
        (activation all-reduce ~4s vs 0.6s matmul — measured, see
        EXPERIMENTS.md §Perf), so DP+FSDP is the right default.
  * MoE TRAIN                          -> EP/TP over 'model', DP over
        (pod,data), FSDP weight shard over 'data', grad-accumulation
        microbatches to fit the wider residual stream.
  * PREFILL                            -> DP over 'data', TP over 'model'
        (latency-oriented: small global batch cannot fill 256-way DP).
  * DECODE                             -> DP over 'data', TP over 'model',
        KV-cache sequence dim sharded over 'model'.
  * long_500k (batch=1)                -> sequence parallelism: KV/state
        over 'data', heads over 'model', batch on 'pod' only.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

from repro.configs.base import ModelConfig, ShapeConfig

DP_FSDP = {
    "batch": ("pod", "data", "model"),
    "heads": None, "kv_heads": None, "ff": None, "experts": None,
    "vocab": None, "embed": ("data", "model"), "act_embed": None,
}

MOE_TRAIN = {
    "batch": ("pod", "data"),
    "heads": "model", "kv_heads": "model", "ff": "model",
    "experts": "model", "vocab": "model", "embed": "data",
    # TP-shard the residual stream: layer-boundary all-gather/reduce-scatter
    # instead of 16x replicated scan carries (38GB -> 2.4GB on the 236Bs)
    "act_embed": "model",
}

SERVE_TP = {
    "batch": ("pod", "data"),
    "heads": "model", "kv_heads": "model", "ff": "model",
    "experts": "model", "vocab": "model",
    # weights 2-axis sharded: a 236B MoE in bf16 is 472GB — TP-only would
    # leave 29.5GB/chip of weights.  Dense weights gather FSDP-style over
    # "data"; MoE expert FFs shard their hidden dim over "data" instead,
    # so decode reduces small expert OUTPUTS over data (~MBs) rather than
    # gathering 100s of MB of expert weights per layer (§Perf-C).
    "embed": "data",
    # the KV cache seq dim shards over 'model' (32k x large-batch caches)
    "act_embed": None, "kv_seq": "model",
}

DECODE_TP = dict(SERVE_TP)

LONG_SP = {
    "batch": ("pod",),
    "heads": "model", "kv_heads": "model", "ff": "model",
    "experts": "model", "vocab": "model", "embed": None,
    "act_embed": None, "kv_seq": "data",
}


def preset(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Dict[str, Any], int]:
    """-> (logical-axis rules, microbatches)."""
    if shape.name == "long_500k":
        return dict(LONG_SP), 1
    if shape.mode == "train":
        if cfg.moe_experts:
            return dict(MOE_TRAIN), 1
        return dict(DP_FSDP), 1
    if shape.mode == "prefill":
        return dict(SERVE_TP), 1
    return dict(DECODE_TP), 1
