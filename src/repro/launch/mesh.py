"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  The dry-run entry
point sets XLA_FLAGS=--xla_force_host_platform_device_count=512 before
any jax import; everything else sees the real device count.
"""
from __future__ import annotations

from typing import List, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh


def _auto_kw(n: int) -> dict:
    """axis_types=Auto on jax versions that have it, {} otherwise (jax
    0.4.x meshes are implicitly auto — passing the kwarg would crash)."""
    at = getattr(jax.sharding, "AxisType", None)
    return {} if at is None else {"axis_types": (at.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_kw(len(axes)))


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Mesh over whatever devices exist (CPU tests: usually (1,1)).

    ``model_axis`` must divide the device count exactly: the old path
    floored ``data`` to 1 and let ``jax.make_mesh`` fail later with an
    opaque device-count mismatch (or silently built a mesh smaller than
    the host when the floor happened to fit)."""
    n = len(jax.devices())
    if model_axis < 1 or n % model_axis:
        raise ValueError(
            f"model_axis={model_axis} must be >= 1 and divide the "
            f"{n} available device(s) exactly; pick a divisor of {n} "
            f"(or re-launch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count=<multiple of "
            f"{model_axis}>)")
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"),
                         **_auto_kw(2))


def replica_groups(mesh: Union[Mesh, Sequence, None], tp: int,
                   *, axis: str = "model") -> List[Mesh]:
    """Carve a device pool into per-replica tensor-parallel sub-meshes.

    Each group is a 1-D Mesh of ``tp`` consecutive devices over a single
    ``axis`` ("model") — the unit a ring node maps to in the serve plane
    (node = replica group, not device).  ``mesh`` may be a Mesh (its
    devices are taken in row-major order, so a group's devices are
    ICI-adjacent along the fastest-varying axis), an explicit device
    sequence, or None for every host device."""
    if mesh is None:
        devices = list(jax.devices())
    elif isinstance(mesh, Mesh):
        devices = list(mesh.devices.reshape(-1))
    else:
        devices = list(mesh)
    n = len(devices)
    if tp < 1 or n % tp:
        raise ValueError(
            f"tp={tp} must be >= 1 and divide the {n} pooled device(s) "
            f"exactly — a partial group cannot hold a full weight shard "
            f"set")
    return [Mesh(np.array(devices[i:i + tp]), (axis,), **_auto_kw(1))
            for i in range(0, n, tp)]


HARDWARE = {
    # TPU v5e per chip
    "peak_bf16_flops": 197e12,       # FLOP/s
    "hbm_bandwidth": 819e9,          # B/s
    "ici_link_bandwidth": 50e9,      # B/s per link
    "hbm_bytes": 16 * 1024**3,
}
