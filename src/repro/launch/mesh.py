"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  The dry-run entry
point sets XLA_FLAGS=--xla_force_host_platform_device_count=512 before
any jax import; everything else sees the real device count.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Mesh over whatever devices exist (CPU tests: usually (1,1))."""
    n = len(jax.devices())
    data = max(1, n // model_axis)
    return jax.make_mesh((data, model_axis), ("data", "model"),
                         axis_types=_auto(2))


HARDWARE = {
    # TPU v5e per chip
    "peak_bf16_flops": 197e12,       # FLOP/s
    "hbm_bandwidth": 819e9,          # B/s
    "ici_link_bandwidth": 50e9,      # B/s per link
    "hbm_bytes": 16 * 1024**3,
}
