"""Loop-aware static cost analysis over compiled HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
undercounts scan-over-layers programs by ~num_layers x.  This module
re-derives the three roofline inputs directly from the HLO:

  * matmul FLOPs   — every ``dot`` (2 * prod(result dims) * prod(lhs
                     contracting dims)), multiplied through enclosing
                     while-loop trip counts (extracted from the loop
                     condition's compare-against-constant);
  * HBM bytes      — operand + result bytes at fusion/op boundaries
                     (a fusion's internals stay in registers/VMEM; its
                     boundary IS the HBM traffic model), loop-scaled;
  * collective bytes — per collective op kind, loop-scaled.

This is a structural model of the compiled program, not a simulation:
it is exactly what the §Roofline terms need and is validated against
closed-form FLOP counts in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
               "s4": 1, "u4": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->", re.M)
_OP_START = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_TYPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_KIND = re.compile(r"\s*([\w\-]+)\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALLS = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONSTANT = re.compile(r"constant\((\d+)\)")
_TRIP_HINT = re.compile(r'known_trip_count[^0-9]*(\d+)')


def _shape_bytes(dtype: str, dims: Optional[str]) -> int:
    if dtype not in DTYPE_BYTES:
        return 0
    n = DTYPE_BYTES[dtype]
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_elems(dims: Optional[str]) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Op:
    name: str
    kind: str
    dtype: Optional[str]
    dims: Optional[str]
    is_tuple: bool
    tuple_type: str
    operands: List[str]
    attrs: str
    raw: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, Tuple[Optional[str], Optional[str]]] = \
        field(default_factory=dict)   # op name -> (dtype, dims)


def _parse_op_line(line: str) -> Optional[Op]:
    m = _OP_START.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    dtype = dims = None
    tuple_type = ""
    is_tuple = rest.startswith("(")
    if is_tuple:
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        tuple_type = rest[:end]
        rest = rest[end:]
    else:
        tm = _TYPE.match(rest)
        if tm:
            dtype, dims = tm.group(1), tm.group(2)
            rest = rest[tm.end():]
        elif rest.startswith("token[]"):
            rest = rest[7:]
    km = _KIND.match(rest)
    if not km:
        return None
    kind = km.group(1)
    # operand list runs to the matching close paren (no nested parens occur
    # in operand lists except constant literals, which have no commas+%).
    args_start = km.end()
    depth = 1
    i = args_start
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    operand_str = rest[args_start:i - 1]
    attrs = rest[i:]
    operands = []
    for piece in _split_top_level(operand_str):
        piece = piece.strip()
        if piece.startswith("%"):
            operands.append(piece[1:])
        else:
            sm = re.match(r"[a-z0-9]+\[[0-9,]*\][^ ]*\s+%?([\w\.\-]+)", piece)
            if sm:
                operands.append(sm.group(1))
    return Op(name, kind, dtype, dims, is_tuple, tuple_type, operands,
              attrs, line)


def _split_top_level(s: str) -> List[str]:
    """Split an operand list on commas that sit outside []/{}/() — shape
    dims (``f32[128,256]``) and layouts (``{1,0}``) contain commas too."""
    parts: List[str] = []
    depth = 0
    start = 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append(s[start:i])
            start = i + 1
    parts.append(s[start:])
    return parts


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if line and not line[0].isspace() and "->" in line and "{" in line:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        op = _parse_op_line(line)
        if op is None:
            continue
        cur.ops.append(op)
        if not op.is_tuple:
            cur.shapes[op.name] = (op.dtype, op.dims)
    return comps


# HBM-traffic model per op kind (fusion boundaries = HBM roundtrips):
#   full:   operands + result cross HBM
#   result: only the result (+indices) moves (slicing ops read a window)
#   update: dynamic-update-slice/scatter touch ~2x the update operand
_BYTES_FULL = {"fusion", "dot", "convolution", "reduce", "sort",
               "concatenate", "pad", "select-and-scatter", "cholesky",
               "triangular-solve"} | set(COLLECTIVES) | {
                   c + "-start" for c in COLLECTIVES}
_BYTES_RESULT = {"dynamic-slice", "gather", "slice", "broadcast", "iota",
                 "copy", "transpose"}
_BYTES_UPDATE = {"dynamic-update-slice", "scatter"}


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    {o: v * k for o, v in self.coll.items()})

    def add(self, other: "Cost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for o, v in other.coll.items():
            self.coll[o] = self.coll.get(o, 0.0) + v


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[str, Cost] = {}
        entry = None
        # the ENTRY line loses its marker in parse; detect via text
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
        self.entry = m.group(1) if m else next(iter(self.comps), None)

    # -- trip count ----------------------------------------------------------
    def trip_count(self, while_op: "Op", cond_name: str) -> int:
        hint = _TRIP_HINT.search(while_op.raw)
        if hint:
            return int(hint.group(1))
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        consts = [int(m.group(1)) for op in comp.ops
                  for m in [_CONSTANT.search(op.raw)] if m]
        return max(consts) if consts else 1

    # -- per-op flops ------------------------------------------------------------
    def _dot_flops(self, comp: Computation, op: Op) -> float:
        out = _shape_elems(op.dims)
        m = _CONTRACT.search(op.attrs)
        k = 1
        if m and op.operands:
            lhs = comp.shapes.get(op.operands[0])
            if lhs and lhs[1]:
                lhs_dims = [int(d) for d in lhs[1].split(",")]
                for idx in (m.group(1).split(",") if m.group(1) else []):
                    i = int(idx)
                    if i < len(lhs_dims):
                        k *= lhs_dims[i]
        return 2.0 * out * k

    # -- computation cost (memoized, loop-aware) -----------------------------------
    def cost_of(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        self._memo[name] = total  # break cycles defensively
        if comp is None:
            return total
        for op in comp.ops:
            if op.kind == "dot":
                total.flops += self._dot_flops(comp, op)
            base_kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base_kind in COLLECTIVES:
                b = _shape_bytes(op.dtype, op.dims) if not op.is_tuple else \
                    self._tuple_bytes(op)
                total.coll[base_kind] = total.coll.get(base_kind, 0.0) + b
            if op.kind in _BYTES_FULL:
                b = (_shape_bytes(op.dtype, op.dims)
                     if not op.is_tuple else self._tuple_bytes(op))
                sliced = (self._sliced_params(op)
                          if op.kind == "fusion" else {})
                # in-place pattern: a fusion that updates a buffer (scan
                # carry / KV-cache dynamic-update-slice) has one operand of
                # identical shape+dtype to its result — XLA aliases it, so
                # only the updated window actually moves.  Discount one
                # same-shaped operand AND the result down to zero (the DUS
                # update itself is charged via its own small operands).
                aliased = False
                result_sig = (op.dtype, op.dims) if not op.is_tuple else None
                for i, o in enumerate(op.operands):
                    if i in sliced:
                        b += sliced[i]      # window read, not the full buffer
                        continue
                    sh = comp.shapes.get(o)
                    if sh:
                        if (op.kind == "fusion" and not aliased
                                and result_sig is not None
                                and sh == result_sig):
                            aliased = True
                            b -= _shape_bytes(*result_sig)  # result is in-place
                            continue
                        b += _shape_bytes(sh[0], sh[1])
                total.bytes += max(b, 0)
            elif op.kind in _BYTES_RESULT:
                total.bytes += (_shape_bytes(op.dtype, op.dims) * 2
                                if not op.is_tuple else
                                self._tuple_bytes(op) * 2)
            elif op.kind in _BYTES_UPDATE and len(op.operands) >= 2:
                sh = comp.shapes.get(op.operands[1])
                if sh:
                    total.bytes += 2 * _shape_bytes(sh[0], sh[1])
            if op.kind == "while":
                bm = _BODY.search(op.attrs)
                cm = _COND.search(op.attrs)
                if bm:
                    trips = self.trip_count(op, cm.group(1) if cm else "")
                    total.add(self.cost_of(bm.group(1)).scaled(trips))
            elif op.kind in ("fusion", "call", "custom-call", "map",
                             "conditional", "reduce", "sort", "scatter",
                             "select-and-scatter", "reduce-window"):
                for sub in _CALLS.findall(op.attrs):
                    if sub in self.comps and sub != name:
                        total.add(self.cost_of(sub))
        return total

    def _sliced_params(self, op: Op) -> Dict[int, int]:
        """For a fusion op: parameter indices that are only read through a
        dynamic-slice/gather/slice inside the fused computation, mapped to
        the bytes of the sliced window (the actual HBM read)."""
        m = _CALLS.search(op.attrs)
        if not m:
            return {}
        sub = self.comps.get(m.group(1))
        if sub is None:
            return {}
        # parameter name -> index
        pidx: Dict[str, int] = {}
        for o in sub.ops:
            if o.kind == "parameter":
                try:
                    pidx[o.name] = int(o.raw.rsplit("parameter(", 1)[1]
                                       .split(")")[0])
                except (IndexError, ValueError):
                    pass
        reads: Dict[int, int] = {}
        direct: set = set()
        for o in sub.ops:
            for j, operand in enumerate(o.operands):
                if operand not in pidx:
                    continue
                idx = pidx[operand]
                if o.kind in ("dynamic-slice", "gather", "slice") and j == 0:
                    reads[idx] = reads.get(idx, 0) + _shape_bytes(
                        o.dtype, o.dims)
                else:
                    direct.add(idx)
        return {i: b for i, b in reads.items() if i not in direct}

    def _tuple_bytes(self, op: Op) -> int:
        total = 0
        for m in _SHAPE.finditer(op.tuple_type):
            total += _shape_bytes(m.group(1), m.group(2))
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> Dict[str, object]:
    model = HloCostModel(hlo_text)
    c = model.entry_cost()
    return {
        "matmul_flops": c.flops,
        "hbm_bytes": c.bytes,
        "collective_bytes_by_op": dict(c.coll),
        "collective_bytes": sum(c.coll.values()),
    }


def fn_cost(fn, *args, static_argnames=None, **kwargs) -> Dict[str, object]:
    """Compile ``fn(*args, **kwargs)`` on the current backend and run the
    loop-aware analyzer over its optimized HLO.  The structural twin of a
    measured benchmark row: bytes/FLOPs of the program the device will
    actually execute (fusion boundaries included), so rooflines can put
    an arithmetic-intensity estimate NEXT TO the measured throughput."""
    import jax

    jitted = jax.jit(fn, static_argnames=static_argnames)
    compiled = jitted.lower(*args, **kwargs).compile()
    out = analyze(compiled.as_text())
    out["arithmetic_intensity"] = \
        out["matmul_flops"] / max(float(out["hbm_bytes"]), 1.0)
    return out
