"""Input ShapeDtypeStructs / dummy batches for every (arch × shape) cell.

``input_specs`` is the dry-run contract: weak-type-correct, shardable
stand-ins with NO device allocation.  ``dummy_batch`` materializes small
concrete batches for smoke tests and examples.

Modality frontends are stubs per the assignment: whisper gets precomputed
frame embeddings, internvl2 gets precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _tok(b: int, s: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    if shape.mode == "train":
        if cfg.family == "encdec":
            return {"frames": jax.ShapeDtypeStruct((b, cfg.audio_frames, d),
                                                   dtype),
                    "tokens": _tok(b, s), "labels": _tok(b, s)}
        if cfg.family == "vlm":
            st = s - cfg.vision_tokens
            return {"image_embeds": jax.ShapeDtypeStruct(
                        (b, cfg.vision_tokens, d), dtype),
                    "tokens": _tok(b, st), "labels": _tok(b, st)}
        return {"tokens": _tok(b, s), "labels": _tok(b, s)}
    if shape.mode == "prefill":
        if cfg.family == "encdec":
            return {"frames": jax.ShapeDtypeStruct((b, cfg.audio_frames, d),
                                                   dtype),
                    "tokens": _tok(b, s)}
        if cfg.family == "vlm":
            return {"image_embeds": jax.ShapeDtypeStruct(
                        (b, cfg.vision_tokens, d), dtype),
                    "tokens": _tok(b, s - cfg.vision_tokens)}
        return {"tokens": _tok(b, s)}
    # decode: one new token against a cache of length seq_len
    return {"tokens": _tok(b, 1)}


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, tuple]:
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
    return out


def dummy_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0
                ) -> Dict[str, jax.Array]:
    rng = np.random.default_rng(seed)
    out = {}
    for k, spec in input_specs(cfg, shape).items():
        if spec.dtype == jnp.int32:
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab, size=spec.shape, dtype=np.int32))
        else:
            out[k] = jnp.asarray(
                rng.standard_normal(spec.shape, dtype=np.float32) * 0.02,
                dtype=spec.dtype)
    return out
