import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the
# device count on first backend init). Everything below is ordinary code.
# (No `from __future__ import annotations` here for the same reason: the
# os.environ lines must be the first statements in the file.)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, with NO device allocation (ShapeDtypeStruct
stand-ins only):
    * compiled.memory_analysis()  -> bytes per device (proves it fits)
    * compiled.cost_analysis()    -> HLO FLOPs / bytes for §Roofline
    * collective bytes parsed from the compiled HLO text
and appends a JSON record to ``results/dryrun.json``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
        --shape train_4k --mesh single          # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.registry import ARCH_IDS, shape_cells, skipped_cells
from repro.launch import hlo_cost, presets
from repro.launch.inputs import batch_pspecs, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.optim import adamw
from repro.sharding import specs as sh
from repro.train.step import TrainConfig, make_train_step

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results")

COLLECTIVE_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    totals: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        if dtype == "token" or dtype not in DTYPE_BYTES:
            continue
        size = DTYPE_BYTES[dtype]
        if dims:
            for d in dims.split(","):
                size *= int(d)
        totals[op] = totals.get(op, 0.0) + size
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": totals, "counts_by_op": counts,
            "total_bytes": sum(totals.values())}


def f32_twin_bytes(hlo_text: str, floor: int = 64 * 2**20) -> int:
    """CPU-XLA artifact estimator: the CPU backend upcasts bf16 weights to
    f32 (no native bf16 ALU) and hoists the converted copies out of loops.
    A real TPU (native bf16 MXU) never materializes them.  We flag every
    f32 tensor that is a dim-exact twin of a bf16 tensor in the module and
    exceeds ``floor`` bytes — the sum bounds the artifact inflation of
    memory_analysis() (one live copy each)."""
    bf16_dims = set()
    f32_sizes = {}
    for m in re.finditer(r"(bf16|f32)\[([0-9,]+)\]", hlo_text):
        dims = m.group(2)
        if m.group(1) == "bf16":
            bf16_dims.add(dims)
        else:
            n = 4
            for d in dims.split(","):
                n *= int(d)
            f32_sizes[dims] = n
    return sum(n for dims, n in f32_sizes.items()
               if dims in bf16_dims and n >= floor)


def _resolve_pspecs(tree):
    """Logical-axis tuples -> PartitionSpec, rule-resolved for the mesh."""
    return jax.tree.map(lambda axes: sh.logical_spec(*axes), tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def _shardings(mesh, pspec_tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _fit_spec(mesh, spec: P, shape) -> P:
    """Drop mesh axes that do not divide the tensor dim (whisper's 12
    heads or odd vocab on a 16-way axis would otherwise fail to shard)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if (size and shape[i] % size == 0) else None)
    return P(*out)


def _fitted_shardings(mesh, pspec_tree, abstract_tree):
    specs = jax.tree.map(lambda p: p, pspec_tree,
                         is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda p, a: NamedSharding(mesh, _fit_spec(mesh, p, a.shape)),
        specs, abstract_tree, is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               attn_impl: str = "full", microbatches: Optional[int] = None,
               extra_rules: Optional[Dict[str, Any]] = None,
               config_overrides: Optional[Dict[str, Any]] = None):
    cfg = get_config(arch)
    if config_overrides:
        cfg = cfg.with_overrides(**config_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules, preset_mb = presets.preset(cfg, shape)
    if extra_rules:
        rules.update(extra_rules)
    if microbatches is None:
        microbatches = preset_mb
    sh.set_mesh(mesh, rules)
    model = Model(cfg, attn_impl=attn_impl)

    aparams = model.abstract_params()
    param_sh = _fitted_shardings(mesh, _resolve_pspecs(model.param_pspecs()),
                                 aparams)
    ispecs = input_specs(cfg, shape)
    batch_sh = _fitted_shardings(mesh, _resolve_pspecs(batch_pspecs(cfg, shape)),
                                 ispecs)

    if shape.mode == "train":
        tcfg = TrainConfig(opt=adamw.OptConfig(moment_dtype=cfg.opt_dtype),
                           microbatches=microbatches)
        step = make_train_step(model, tcfg)
        astate = adamw.init_state(aparams, tcfg.opt)
        opt_sh = {"m": param_sh, "v": param_sh,
                  "step": NamedSharding(mesh, P())}
        fn = jax.jit(step,
                     in_shardings=(param_sh, opt_sh, batch_sh),
                     out_shardings=(param_sh, opt_sh, None),
                     donate_argnums=(0, 1))
        with jax.set_mesh(mesh):
            lowered = fn.lower(aparams, astate, ispecs)
    elif shape.mode == "prefill":
        acache = model.cache_shapes(shape.global_batch, shape.seq_len)
        cache_sh = _fitted_shardings(mesh, _resolve_pspecs(model.cache_pspecs()),
                                     acache)

        def prefill(params, batch, cache):
            return model.prefill(params, batch, cache)

        fn = jax.jit(prefill,
                     in_shardings=(param_sh, batch_sh, cache_sh),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(2,))
        with jax.set_mesh(mesh):
            lowered = fn.lower(aparams, ispecs, acache)
    else:  # decode
        acache = model.cache_shapes(shape.global_batch, shape.seq_len)
        cache_sh = _fitted_shardings(mesh, _resolve_pspecs(model.cache_pspecs()),
                                     acache)

        def serve_step(params, cache, tokens, index):
            return model.decode_step(params, cache, tokens, index)

        idx = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(serve_step,
                     in_shardings=(param_sh, cache_sh, batch_sh["tokens"],
                                   NamedSharding(mesh, P())),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(1,))
        with jax.set_mesh(mesh):
            lowered = fn.lower(aparams, acache, ispecs["tokens"], idx)
    return lowered, mesh, cfg, shape


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             attn_impl: str = "full", microbatches: Optional[int] = None,
             extra_rules: Optional[Dict[str, Any]] = None,
             config_overrides: Optional[Dict[str, Any]] = None,
             tag: str = "baseline") -> Dict[str, Any]:
    t0 = time.time()
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "attn_impl": attn_impl, "microbatches": microbatches, "tag": tag,
    }
    try:
        lowered, mesh, cfg, shape = lower_cell(
            arch, shape_name, multi_pod=multi_pod, attn_impl=attn_impl,
            microbatches=microbatches, extra_rules=extra_rules,
            config_overrides=config_overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo_text = compiled.as_text()
        coll = collective_bytes(hlo_text)
        loop_aware = hlo_cost.analyze(hlo_text)
        n_dev = mesh.devices.size
        rec.update({
            "ok": True,
            "devices": n_dev,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "matmul_flops_per_device": loop_aware["matmul_flops"],
            "hbm_bytes_per_device": loop_aware["hbm_bytes"],
            "collective_bytes_per_device": loop_aware["collective_bytes"],
            "collective_bytes_by_op": loop_aware["collective_bytes_by_op"],
            "artifact_f32_upcast_bytes": f32_twin_bytes(hlo_text),
            "peak_memory_per_device": getattr(
                mem, "temp_size_in_bytes", 0) + getattr(
                mem, "argument_size_in_bytes", 0) + getattr(
                mem, "output_size_in_bytes", 0) - getattr(
                mem, "alias_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "collectives": coll,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "tokens": shape.global_batch * (shape.seq_len
                                            if shape.mode != "decode" else 1),
            "mode": shape.mode,
        })
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    finally:
        sh.set_mesh(None)
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def append_result(rec: Dict[str, Any], path: Optional[str] = None) -> None:
    path = path or os.path.join(os.path.abspath(RESULTS), "dryrun.jsonl")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attn-impl", default="full", choices=["full", "tri"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in shape_cells(a):
                cells.append((a, s.name))
            for s in skipped_cells(a):
                append_result({"arch": a, "shape": s, "ok": None,
                               "skipped": "requires sub-quadratic attention "
                               "(pure full-attention arch)"}, args.out)
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape required unless --all")
        cells = [(args.arch, args.shape)]

    for arch, shape_name in cells:
        for mp in meshes:
            rec = run_cell(arch, shape_name, multi_pod=mp,
                           attn_impl=args.attn_impl,
                           microbatches=args.microbatches, tag=args.tag)
            append_result(rec, args.out)
            status = "OK " if rec.get("ok") else "FAIL"
            print(f"[{status}] {arch:24s} {shape_name:12s} "
                  f"{rec.get('mesh')} compile={rec.get('compile_s', '-')}s "
                  f"flops={rec.get('flops', 0):.3e} "
                  f"mem/dev={rec.get('peak_memory_per_device', 0)/2**30:.2f}GiB"
                  if rec.get("ok") else
                  f"[{status}] {arch} {shape_name}: {rec.get('error')}",
                  flush=True)


if __name__ == "__main__":
    main()
