"""Sharded checkpointing (orbax-free: npz shards + JSON manifest).

Layout:
    <dir>/step_<N>/
        manifest.json        {step, tree structure, shard index, digests}
        shard_<i>.npz        flattened leaves, split into ~512MB shards

Restart-safety: writes go to ``step_<N>.tmp`` and are atomically renamed;
``latest_step`` only ever sees complete checkpoints.  Integrity: each
shard carries a crc32 recorded in the manifest, verified on restore.
The restore path re-shards to whatever mesh is active (values are loaded
to host then device_put with the target sharding), which is what elastic
re-meshing after a membership change needs.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SHARD_BYTES = 512 * 1024 * 1024


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": [], "shards": []}
    shard: Dict[str, np.ndarray] = {}
    shard_bytes = 0
    shard_idx = 0

    def flush():
        nonlocal shard, shard_bytes, shard_idx
        if not shard:
            return
        path = os.path.join(tmp, f"shard_{shard_idx}.npz")
        np.savez(path, **shard)
        crc = 0
        with open(path, "rb") as f:
            while True:
                b = f.read(1 << 20)
                if not b:
                    break
                crc = zlib.crc32(b, crc)
        manifest["shards"].append({"file": f"shard_{shard_idx}.npz",
                                   "crc32": crc})
        shard, shard_bytes = {}, 0
        shard_idx += 1

    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i}"
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in logical_dtype:
            # numpy cannot serialize ml_dtypes.bfloat16 — store bit pattern
            arr = arr.view(np.uint16)
        manifest["leaves"].append(
            {"path": name, "key": key, "shard": shard_idx,
             "dtype": logical_dtype, "shape": list(arr.shape)})
        shard[key] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= SHARD_BYTES:
            flush()
    flush()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_", 1)[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Any = None) -> Any:
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    for sh in manifest["shards"]:
        fpath = os.path.join(path, sh["file"])
        crc = 0
        with open(fpath, "rb") as f:
            while True:
                b = f.read(1 << 20)
                if not b:
                    break
                crc = zlib.crc32(b, crc)
        if crc != sh["crc32"]:
            raise IOError(f"checkpoint shard corrupt: {fpath}")
    arrays_by_key: Dict[str, np.ndarray] = {}
    loaded = [np.load(os.path.join(path, sh["file"]))
              for sh in manifest["shards"]]
    for entry in manifest["leaves"]:
        arr = loaded[entry["shard"]][entry["key"]]
        if "bfloat16" in entry["dtype"] and arr.dtype == np.uint16:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        arrays_by_key[entry["key"]] = arr

    flat_t, treedef = jax.tree_util.tree_flatten(target_tree)
    flat_s = (jax.tree_util.tree_flatten(shardings)[0]
              if shardings is not None else [None] * len(flat_t))
    if len(manifest["leaves"]) != len(flat_t):
        raise ValueError("checkpoint/target tree structure mismatch")
    out = []
    for entry, tgt, shd in zip(manifest["leaves"], flat_t, flat_s):
        arr = arrays_by_key[entry["key"]]
        if list(arr.shape) != list(tgt.shape):
            raise ValueError(
                f"shape mismatch for {entry['path']}: "
                f"{arr.shape} vs {tgt.shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=tgt.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
