"""EDRA tuning equations (paper §III, §IV-C, §IV-D).

Every symbol follows the paper:

    n       system size (number of peers)
    S_avg   average session length (seconds)
    r       event rate (joins+leaves per second)        -- Eq III.1
    rho     ceil(log2(n)) -- number of message TTL levels
    Theta   event-buffering interval length (seconds)   -- Eq IV.2 / IV.3
    f       max acceptable fraction of routing failures (default 1%)
    T_avg   upper bound on the average acknowledge time -- Eq IV.1
    E       max number of events a peer may buffer      -- Eq IV.4

The tuning theorem is the paper's enabling insight: because every peer
learns about *every* event (it is a single-hop DHT), each peer can locally
estimate r and n and evaluate these closed forms with no coordination.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

DEFAULT_F = 0.01  # paper: "f is typically 1%"


def rho(n: int) -> int:
    """rho = ceil(log2(n)) (Rule 1)."""
    if n < 2:
        return 1
    return max(1, math.ceil(math.log2(n)))


def event_rate(n: float, s_avg: float) -> float:
    """Eq III.1: r = 2*n/S_avg (one join + one leave per session)."""
    return 2.0 * n / s_avg


def t_avg(theta: float, n: int, delta_avg: float) -> float:
    """Eq IV.1: upper bound on average acknowledge time.

    T_avg = 2*Theta (failure detection, Rule 5 worst case)
          + rho*(Theta + 2*delta_avg)/4 (per-hop buffering + delay).
    """
    return 2.0 * theta + rho(n) * (theta + 2.0 * delta_avg) / 4.0


def theta_exact(n: int, s_avg: float, f: float = DEFAULT_F,
                delta_avg: float = 0.0) -> float:
    """Eq IV.2: Theta = (2*f*S_avg - 2*rho*delta_avg)/(8 + rho).

    Derived from T_avg * r / n <= f with Eqs III.1 and IV.1.
    """
    p = rho(n)
    return max(0.0, (2.0 * f * s_avg - 2.0 * p * delta_avg) / (8.0 + p))


def theta(n: int, s_avg: float, f: float = DEFAULT_F) -> float:
    """Eq IV.3: Theta = 4*f*S_avg/(16 + 3*rho).

    The paper's practical form, assuming delta_avg = Theta/4 (an
    overestimate of measured Internet delays).
    """
    return 4.0 * f * s_avg / (16.0 + 3.0 * rho(n))


def max_buffered_events(n: int, f: float = DEFAULT_F) -> float:
    """Eq IV.4: E = 8*f*n/(16 + 3*rho) events.

    Robustness cap against event bursts; derived from Eq IV.3 with
    r = E/Theta (peers observe similar event rates).
    """
    return 8.0 * f * n / (16.0 + 3.0 * rho(n))


@dataclass(frozen=True)
class EdraParams:
    """Resolved protocol parameters for a (n, S_avg, f) operating point."""

    n: int
    s_avg: float
    f: float
    rho: int
    theta: float
    r: float
    t_detect: float  # paper §IV-C: T_detect = 2*Theta (worst case, failures)
    t_avg: float
    max_events: float

    @classmethod
    def derive(cls, n: int, s_avg: float, f: float = DEFAULT_F) -> "EdraParams":
        th = theta(n, s_avg, f)
        return cls(
            n=n,
            s_avg=s_avg,
            f=f,
            rho=rho(n),
            theta=th,
            r=event_rate(n, s_avg),
            t_detect=2.0 * th,
            t_avg=t_avg(th, n, delta_avg=th / 4.0),
            max_events=max_buffered_events(n, f),
        )

    def retune(self, observed_n: int, observed_r: float) -> "EdraParams":
        """Self-organization: re-derive Theta from locally observed n and r.

        Eq III.1 inverted gives the implied S_avg; every peer can do this
        independently because it sees all events (paper §IV-D).
        """
        s_avg = 2.0 * observed_n / max(observed_r, 1e-12)
        return EdraParams.derive(observed_n, s_avg, self.f)


# Session lengths measured by the studies the paper cites (§VIII).
SESSION_LENGTHS_MIN = {
    "datacenter-stress": 60,   # "more dynamic scenario" used in §VII
    "kad": 169,                # Steiner et al. [50]
    "gnutella": 174,           # Saroiu et al. [49]
    "bittorrent": 780,         # Andrade et al. [2]
}
