"""RingState — the single device-resident routing-table subsystem.

Every layer that needs key -> owner resolution (the serving router, the
runtime placement, the DES peers through the ``RoutingTable`` facade, and
the Pallas ``ring_lookup`` kernel) shares ONE representation of the D1HT
full routing table (paper §III–IV): a sorted array of full 64-bit peer
IDs held in preallocated, capacity-doubling numpy buffers, versioned so
downstream caches (in particular the on-device hi/lo uint32 word-split
table fed to the kernel) refresh exactly when membership changed and
never otherwise.

Design points (DESIGN.md §2–§4):

  * **Incremental, batched deltas.**  ``apply_events`` consumes EDRA
    join/leave events and merges them into the sorted table with
    O(k log n) searches plus one O(n + k) vectorized placement — never a
    full re-sort/rebuild, matching EDRA's per-Theta-interval event
    batches (Rules 1–4).
  * **Version monotonicity.**  ``version`` strictly increases on every
    mutation batch; consumers key caches on it.
  * **Quarantine mask** (paper §V): peers can be present in the state but
    excluded from ownership while in quarantine, so a quarantined spot
    node is tracked without ever owning keys/sessions.
  * **Device residency.**  ``device_table()`` uploads the active table as
    uint32 (hi, lo) word pairs padded to a power-of-two capacity; the
    live length travels as data, so the jitted kernel recompiles only
    when capacity doubles, not on churn.  ``upload_count`` counts actual
    uploads — the serve-path acceptance tests assert it stays at 1 across
    unchanged-membership request batches.
  * **Two-level bucket index** (DESIGN.md §7): above ``_BUCKET_MIN_N``
    peers, lookups run through a radix-partitioned (B, BW) bucket table
    — top-``R``-bits directory, one bounded row per query — so per-key
    kernel work is O(BW), not O(n).  The directory is maintained
    incrementally next to the sorted table; ``device_bucket_table()``
    re-ships only the rows a membership batch dirtied (scatter update),
    making device maintenance traffic O(touched buckets) per EDRA batch
    instead of O(n).  Views the radix cannot partition (adversarially
    clustered ids) fall back to the flat-scan kernel, which stays the
    correctness oracle.
  * **Successor-list replicas** (Leslie, *Reliable Data Storage in
    Distributed Hash Tables*): ``replica_set(key, r)`` is the r-way
    successor-list view used for replicated placement.

Framework note: numpy only at module level; jax + the Pallas kernel are
imported lazily inside the device-path methods so the pure-Python users
(DES peers, protocol simulators) never pull in jax.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

_MIN_CAPACITY = 64
_MIN_DEVICE_CAPACITY = 2048   # one kernel table tile (kernel.BT)
_WORD = np.uint64(32)
_LO_MASK = np.uint64(0xFFFFFFFF)
_DIFF_HISTORY = 128           # retained ownership-diff batches

# -- two-level bucket index (DESIGN.md §7) ----------------------------------
_BUCKET_ROW = 128             # row width; must equal ring_lookup kernel.BW
_BUCKET_TARGET = 32           # mean ids per bucket the directory aims for
_BUCKET_MIN_N = 2048          # below this the flat scan wins (one BT tile)
_MAX_R_BONUS = 2              # extra directory doublings before fallback


@dataclass(frozen=True)
class OwnerDiff:
    """Key ranges whose owner changed between two active-view versions.

    ``arcs`` is a (A, 2) uint64 array of clockwise half-open ring arcs
    (lo, hi]: a key k lies in an arc iff 0 < (k - lo) mod 2^64 <=
    (hi - lo) mod 2^64.  ``arcs is None`` means the diff could not be
    bounded (history evicted, or a view passed through <= 1 active peer)
    and EVERY key must be treated as affected — consumers fall back to a
    full re-resolve, never to silent staleness.
    """

    old_version: int
    new_version: int
    arcs: Optional[np.ndarray]

    @property
    def full(self) -> bool:
        return self.arcs is None

    def affected(self, keys) -> np.ndarray:
        """(Q,) uint64 key IDs -> (Q,) bool: owner changed across the diff."""
        keys = np.asarray(keys, np.uint64)
        if self.arcs is None:
            return np.ones(keys.shape, bool)
        if not self.arcs.size:
            return np.zeros(keys.shape, bool)
        lo = self.arcs[:, 0][None, :]
        hi = self.arcs[:, 1][None, :]
        d_k = keys[:, None] - lo           # uint64 arithmetic wraps the ring
        d_hi = hi - lo
        return ((d_k != np.uint64(0)) & (d_k <= d_hi)).any(axis=1)


def _as_u64(ids: Iterable[int]) -> np.ndarray:
    if isinstance(ids, np.ndarray):
        return ids.astype(np.uint64, copy=False)
    return np.fromiter((int(i) for i in ids), dtype=np.uint64)


@dataclass(frozen=True)
class ReplicaView:
    """Candidate metadata for one key's replica set — what a placement
    policy (``repro.runtime.placement.PlacementPolicy``) ranks.

    ``ids`` is the r-way successor list in RING order (owner first): a
    policy may reorder it but never change the SET — the successor list
    is the canonical, independently re-derivable location of the key's
    replicas (readers and repair must be able to find them without
    consulting the writer's policy).  ``ring_rank`` maps a candidate
    back to its successor-list position (0 = primary), the tie-breaker
    that keeps any rank-only policy deterministic; ``arc_dist`` is each
    candidate's clockwise ring distance from the key (how "far" past
    the owner the candidate sits — churn-sensitivity metadata: lower
    arc_dist candidates lose the key to fewer distinct joiner arcs).
    """

    key: int
    ids: Tuple[int, ...]
    version: int                  # active-view version the view was cut at
    n_active: int                 # active peers backing it (r is clamped)
    arc_dist: Tuple[int, ...]

    def ring_rank(self, node: int) -> int:
        """Successor-list position of ``node`` (ValueError if absent)."""
        return self.ids.index(node)


class RingState:
    """Versioned, incrementally-maintained full routing table."""

    def __init__(self, ids: Iterable[int] = (), *,
                 capacity: int = _MIN_CAPACITY):
        init = np.unique(_as_u64(ids))
        cap = max(capacity, _MIN_CAPACITY)
        while cap < init.size:
            cap *= 2
        self._ids = np.zeros(cap, np.uint64)       # sorted live ids in [:_n]
        self._quar = np.zeros(cap, bool)           # aligned quarantine mask
        self._ids[:init.size] = init
        self._n = int(init.size)
        self.version = 1
        self.active_version = 1    # bumps only when the ACTIVE view changes
        self.upload_count = 0
        self._active_cache: Tuple[int, Optional[np.ndarray]] = (0, None)
        self._dev_version = 0
        self._dev: Optional[tuple] = None
        self._dev_capacity = 0
        # two-level bucket index (armed lazily by the first device lookup
        # so pure-Python users never pay directory maintenance)
        self._bkt_enabled = False
        self._bkt_valid = False
        self._bkt_cap = 0              # pow2 >= n driving the sizing
        self._bkt_bits = 0             # R: directory has 2^R buckets
        self._bkt_edges: Optional[np.ndarray] = None
        self._bkt_occ: Optional[np.ndarray] = None     # (B,) int32
        self._bkt_pad: Optional[np.ndarray] = None     # (B,) uint64
        self._bkt_starts: Optional[np.ndarray] = None  # (B,) int64
        self._bkt_dirty: Optional[np.ndarray] = None   # (B,) bool
        self._bkt_dev: Optional[tuple] = None
        self._bkt_dev_bits = -1
        # upload accounting (flat + bucket paths; bench observability)
        self.upload_bytes = 0
        self.full_uploads = 0
        self.delta_uploads = 0
        # ownership-diff log: (active_version, arcs|None) per mutation
        # batch that moved the active view; None marks an unbounded batch.
        # Recording is opt-in (track_owner_diffs / first owner_diff call)
        # so the EDRA delta-apply hot path pays nothing without consumers.
        self._arc_log: deque = deque()
        self._diff_enabled = False
        self._diff_floor = self.active_version   # oldest answerable version

    # -- capacity management --------------------------------------------------
    @property
    def capacity(self) -> int:
        return self._ids.size

    def _ensure_capacity(self, need: int) -> None:
        cap = self._ids.size
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        ids = np.zeros(cap, np.uint64)
        quar = np.zeros(cap, bool)
        ids[:self._n] = self._ids[:self._n]
        quar[:self._n] = self._quar[:self._n]
        self._ids, self._quar = ids, quar

    def _bump(self, active: bool = True) -> None:
        """Record a mutation.  ``active=False`` marks changes that leave
        the ownership view intact (e.g. tracking a new quarantined peer)
        so the device table and active-view caches are NOT invalidated."""
        self.version += 1
        if active:
            self.active_version += 1

    # -- ownership diffs -------------------------------------------------------
    def track_owner_diffs(self) -> None:
        """Start logging ownership-change arcs.  Diff consumers (the
        serve plane) enable this up front; ``owner_diff`` also enables it
        on first call (answering that first call conservatively)."""
        if not self._diff_enabled:
            self._diff_enabled = True
            self._diff_floor = self.active_version
            self._arc_log.clear()

    @staticmethod
    def _sorted_diff(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """a \\ b for sorted-unique uint64 arrays without setdiff1d's
        re-sorts (this sits on the EDRA delta-apply hot path)."""
        if not b.size:
            return a.copy()
        i = np.minimum(np.searchsorted(b, a), b.size - 1)
        return a[b[i] != a]

    def _record_arcs(self, old_act: np.ndarray) -> None:
        """Log the ring arcs whose owner moved in the batch that just
        bumped ``active_version`` (old_act = active view before it).

        A peer p entering the active view claims (pred_new(p), p]; a peer
        leaving it releases (pred_old(p), p] to its successor.  The union
        of those arcs is exactly the set of keys whose owner changed in
        this batch.  Views passing through <= 1 active peer have no
        well-defined predecessor arcs and are logged as unbounded."""
        if not self._diff_enabled:
            return
        new_act = self.active_ids()
        if old_act.size <= 1 or new_act.size <= 1:
            arcs: Optional[np.ndarray] = None
        else:
            added = self._sorted_diff(new_act, old_act)
            removed = self._sorted_diff(old_act, new_act)
            segs = []
            if added.size:
                i = np.searchsorted(new_act, added)
                segs.append(np.stack(
                    [new_act[(i - 1) % new_act.size], added], axis=1))
            if removed.size:
                i = np.searchsorted(old_act, removed)
                segs.append(np.stack(
                    [old_act[(i - 1) % old_act.size], removed], axis=1))
            arcs = np.concatenate(segs, axis=0) if segs \
                else np.zeros((0, 2), np.uint64)
        self._arc_log.append((self.active_version, arcs))
        while len(self._arc_log) > _DIFF_HISTORY:
            self._diff_floor, _ = self._arc_log.popleft()

    def owner_diff(self, old_version: int,
                   new_version: Optional[int] = None) -> OwnerDiff:
        """Which key ranges changed owners between two active-view
        versions (default: now)?  Consumers holding per-key state (the
        serve plane's sessions) re-resolve ONLY keys inside the returned
        arcs instead of re-routing everything on every membership batch.
        A diff older than the retained history is returned as full."""
        if new_version is None:
            new_version = self.active_version
        if old_version > new_version:
            raise ValueError(f"old_version {old_version} is newer than "
                             f"new_version {new_version}")
        self.track_owner_diffs()   # idempotent; arms recording from here
        if old_version < self._diff_floor:
            return OwnerDiff(old_version, new_version, None)
        segs = []
        for ver, arcs in self._arc_log:
            if old_version < ver <= new_version:
                if arcs is None:
                    return OwnerDiff(old_version, new_version, None)
                segs.append(arcs)
        merged = np.concatenate(segs, axis=0) if segs \
            else np.zeros((0, 2), np.uint64)
        return OwnerDiff(old_version, new_version, merged)

    # -- views ----------------------------------------------------------------
    def __len__(self) -> int:
        """Number of *active* (non-quarantined) peers."""
        return int(self.active_ids().size)

    @property
    def total(self) -> int:
        """All tracked peers, quarantined included."""
        return self._n

    def all_ids(self) -> np.ndarray:
        """Sorted uint64 view of every tracked peer (read-only)."""
        v = self._ids[:self._n]
        v.flags.writeable = False
        return v

    def active_ids(self) -> np.ndarray:
        """Sorted uint64 array of ownership-eligible peers (cached)."""
        ver, arr = self._active_cache
        if ver == self.active_version and arr is not None:
            return arr
        live = self._ids[:self._n]
        arr = live[~self._quar[:self._n]] if self._quar[:self._n].any() \
            else live.copy()
        arr.flags.writeable = False
        self._active_cache = (self.active_version, arr)
        return arr

    def active_ids_list(self) -> List[int]:
        return [int(x) for x in self.active_ids()]

    def __iter__(self) -> Iterator[int]:
        return iter(self.active_ids_list())

    def __contains__(self, pid: int) -> bool:
        act = self.active_ids()
        i = int(np.searchsorted(act, np.uint64(pid)))
        return i < act.size and int(act[i]) == int(pid)

    def is_quarantined(self, pid: int) -> bool:
        i = int(np.searchsorted(self._ids[:self._n], np.uint64(pid)))
        return i < self._n and int(self._ids[i]) == int(pid) \
            and bool(self._quar[i])

    def __repr__(self) -> str:
        return (f"RingState(n={len(self)}, total={self._n}, "
                f"version={self.version}, capacity={self.capacity})")

    # -- mutation -------------------------------------------------------------
    def add(self, pid: int, *, quarantined: bool = False) -> bool:
        """Insert one peer (or update its quarantine flag). True if the
        active view changed."""
        pid = int(pid)
        old_act = self.active_ids()
        i = int(np.searchsorted(self._ids[:self._n], np.uint64(pid)))
        if i < self._n and int(self._ids[i]) == pid:
            if bool(self._quar[i]) == quarantined:
                return False
            self._quar[i] = quarantined
            self._bump()
            self._record_arcs(old_act)
            self._bucket_note([pid])
            return True
        self._insert_block(np.asarray([pid], np.uint64),
                           np.asarray([quarantined], bool))
        self._bump(active=not quarantined)
        if not quarantined:
            self._record_arcs(old_act)
            self._bucket_note([pid])
        return not quarantined

    def remove(self, pid: int) -> bool:
        pid = int(pid)
        old_act = self.active_ids()
        i = int(np.searchsorted(self._ids[:self._n], np.uint64(pid)))
        if i >= self._n or int(self._ids[i]) != pid:
            return False
        was_active = not bool(self._quar[i])
        self._ids[i:self._n - 1] = self._ids[i + 1:self._n]
        self._quar[i:self._n - 1] = self._quar[i + 1:self._n]
        self._n -= 1
        self._bump(active=was_active)
        if was_active:
            self._record_arcs(old_act)
            self._bucket_note([pid])
        return True

    def set_quarantined(self, pid: int, flag: bool) -> bool:
        """Flip the ownership-exclusion mask for a tracked peer."""
        old_act = self.active_ids()
        i = int(np.searchsorted(self._ids[:self._n], np.uint64(pid)))
        if i >= self._n or int(self._ids[i]) != int(pid):
            return False
        if bool(self._quar[i]) == flag:
            return False
        self._quar[i] = flag
        self._bump()
        self._record_arcs(old_act)
        self._bucket_note([int(pid)])
        return True

    def apply_events(self, events: Sequence) -> int:
        """Batched EDRA delta: one merge for a whole Theta-interval flush.

        ``events`` is any sequence of objects with ``subject_id`` and
        ``kind`` in {"join", "leave"} (repro.core.edra.Event).  Later
        events win over earlier ones for the same subject (a join + leave
        in one batch nets out).  Returns the number of table slots that
        changed; bumps ``version`` iff non-zero.
        """
        last: dict = {}
        for ev in events:
            last[int(ev.subject_id)] = ev.kind
        joins = np.array(sorted(p for p, k in last.items() if k == "join"),
                         np.uint64)
        leaves = np.array(sorted(p for p, k in last.items() if k != "join"),
                          np.uint64)
        old_act = self.active_ids()
        changed = active_changed = 0
        if leaves.size:
            removed, removed_active = self._remove_block(leaves)
            changed += removed
            active_changed += removed_active
        if joins.size:
            merged = self._merge_block(joins)  # inserts/unmasks: all active
            changed += merged
            active_changed += merged
        if changed:
            self._bump(active=active_changed > 0)
            if active_changed:
                self._record_arcs(old_act)
                self._bucket_note(np.concatenate([joins, leaves]))
        return changed

    def _merge_block(self, new_ids: np.ndarray) -> int:
        """Insert sorted unique ``new_ids`` not already present:
        O(k log n) membership searches + one O(n + k) placement.  A join
        for a peer already tracked under quarantine clears its mask (an
        explicit EDRA join event = admission, paper §V)."""
        live = self._ids[:self._n]
        pos = np.searchsorted(live, new_ids)
        present = (pos < self._n) & (live[np.minimum(pos, self._n - 1)]
                                     == new_ids) if self._n else \
            np.zeros(new_ids.shape, bool)
        changed = 0
        if present.any():
            at = pos[present]
            unmasked = self._quar[:self._n][at]
            self._quar[at[unmasked]] = False
            changed += int(unmasked.sum())
        fresh = new_ids[~present]
        if fresh.size:
            self._insert_block(fresh, np.zeros(fresh.size, bool))
            changed += int(fresh.size)
        return changed

    def _insert_block(self, fresh: np.ndarray, quar: np.ndarray) -> None:
        """Vectorized multi-insert into the capacity buffer (fresh is
        sorted, unique, disjoint from the live table)."""
        n, k = self._n, int(fresh.size)
        self._ensure_capacity(n + k)
        old_ids = self._ids[:n].copy()
        old_quar = self._quar[:n].copy()
        pos = np.searchsorted(old_ids, fresh)
        dst_new = pos + np.arange(k)           # final slots of new entries
        mask = np.ones(n + k, bool)
        mask[dst_new] = False
        self._ids[:n + k][mask] = old_ids
        self._ids[dst_new] = fresh
        self._quar[:n + k][mask] = old_quar
        self._quar[dst_new] = quar
        self._n = n + k

    def _remove_block(self, gone: np.ndarray) -> Tuple[int, int]:
        """Returns (slots removed, of which were active).  Absent ids are
        matched elementwise — a miss whose bisect position lands on some
        *other* departing id must not double-count it."""
        if not self._n:
            return 0, 0
        live = self._ids[:self._n]
        pos = np.searchsorted(live, gone)
        ok = pos < self._n
        hit = pos[ok][live[pos[ok]] == gone[ok]]
        if not hit.size:
            return 0, 0
        keep = np.ones(self._n, bool)
        keep[hit] = False
        active_hits = int((~self._quar[:self._n][hit]).sum())
        m = int(keep.sum())
        self._ids[:m] = live[keep]
        self._quar[:m] = self._quar[:self._n][keep]
        self._n = m
        return int(hit.size), active_hits

    # -- ring navigation (active view) ---------------------------------------
    def successor_index(self, x: int) -> int:
        act = self.active_ids()
        if not act.size:
            raise LookupError("empty routing table")
        return int(np.searchsorted(act, np.uint64(int(x)))) % act.size

    def successor_of(self, x: int) -> int:
        act = self.active_ids()
        return int(act[self.successor_index(x)])

    def predecessor_of(self, x: int) -> int:
        act = self.active_ids()
        if not act.size:
            raise LookupError("empty routing table")
        i = int(np.searchsorted(act, np.uint64(int(x))))
        return int(act[(i - 1) % act.size])

    def succ(self, p: int, i: int = 1) -> int:
        """succ(p, i): the i-th successor of peer p (paper §IV)."""
        act = self.active_ids()
        j = int(np.searchsorted(act, np.uint64(int(p))))
        if j >= act.size or int(act[j]) != int(p):
            raise LookupError(f"peer {p} not in table")
        return int(act[(j + i) % act.size])

    def stretch(self, p: int, k: int) -> List[int]:
        """stretch(p,k) = {succ(p,i) | 0 <= i <= k} (paper §IV)."""
        n = len(self)
        return [self.succ(p, i) for i in range(min(k, n - 1) + 1)]

    def replica_set(self, key, r: int) -> List[int]:
        """Successor-list view: the r distinct active peers starting at the
        key's owner, clockwise with wrap-around — the r-way replica group
        in the sense of Leslie's reliable-DHT-storage scheme."""
        act = self.active_ids()
        if not act.size:
            raise LookupError("empty routing table")
        from .ring import key_id  # local: ring imports this module at top
        x = key if isinstance(key, int) else key_id(key)
        start = self.successor_index(x)
        r = min(r, act.size)
        idx = (start + np.arange(r)) % act.size
        return [int(v) for v in act[idx]]

    def replica_view(self, key, r: int) -> ReplicaView:
        """``replica_set`` plus candidate metadata (ring ranks, arc
        distances, view version) — the input a placement policy ranks.
        The id ORDER is exactly ``replica_set``'s, so a consumer that
        takes ``view.ids`` unranked behaves bit-identically to the
        legacy successor-list loops."""
        act = self.active_ids()
        if not act.size:
            raise LookupError("empty routing table")
        from .ring import key_id
        x = key if isinstance(key, int) else key_id(key)
        ids = self.replica_set(x, r)
        dist = tuple((int(i) - x) & 0xFFFFFFFFFFFFFFFF  # wraps the ring
                     for i in ids)
        return ReplicaView(key=int(x), ids=tuple(ids),
                           version=self.active_version,
                           n_active=int(act.size), arc_dist=dist)

    def replica_sets(self, keys, r: int) -> np.ndarray:
        """Vectorized ``replica_set`` over a key batch: (Q,) uint64 key
        IDs -> (Q, min(r, n)) uint64 replica groups, owner first.  The
        data plane's re-replication sweep resolves every affected
        block's new placement in one call instead of Q bisects."""
        act = self.active_ids()
        if not act.size:
            raise LookupError("empty routing table")
        keys = np.asarray(keys, np.uint64)
        r = min(r, act.size)
        start = np.searchsorted(act, keys) % act.size
        idx = (start[:, None] + np.arange(r)[None, :]) % act.size
        return act[idx]

    def owner(self, key) -> int:
        from .ring import key_id
        x = key if isinstance(key, int) else key_id(key)
        return self.successor_of(x)

    # -- two-level bucket index (DESIGN.md §7) ---------------------------------
    @staticmethod
    def _bits_for(cap: int) -> int:
        """Directory size for a table capacity: 2^R buckets targeting
        ``_BUCKET_TARGET`` ids each, clamped so the (B, BW) matrix fits
        the backend's fast-memory budget."""
        from repro.kernels.backend import bucket_budget_bytes
        b = max(64, cap // _BUCKET_TARGET)
        while b > 64 and b * _BUCKET_ROW * 8 > bucket_budget_bytes():
            b //= 2
        return b.bit_length() - 1

    def _enable_buckets(self) -> None:
        if self._bkt_enabled:
            return
        self._bkt_enabled = True
        cap = max(self._bkt_cap, _MIN_DEVICE_CAPACITY)
        while cap < len(self):
            cap *= 2
        self._bkt_cap = cap
        self._set_bits(self._bits_for(cap))

    def _set_bits(self, bits: int) -> None:
        """(Re)size the directory; every row becomes dirty (the device
        arrays change shape, so the next sync is a full rebuild — the
        bucketized analogue of a capacity-doubling recompile)."""
        nb = 1 << bits
        self._bkt_bits = bits
        self._bkt_edges = np.arange(nb, dtype=np.uint64) \
            << np.uint64(64 - bits)
        self._bkt_occ = np.full(nb, -1, np.int32)
        self._bkt_pad = np.zeros(nb, np.uint64)
        self._bkt_starts = np.zeros(nb, np.int64)
        self._bkt_dirty = np.ones(nb, bool)
        self._refresh_directory(None)

    def _bucket_note(self, touched) -> None:
        """Per mutation batch that moved the active view: grow/refresh
        the directory and accumulate dirty rows.  No-op until the first
        device lookup arms the index."""
        if not self._bkt_enabled:
            return
        n = len(self)
        if n > self._bkt_cap:
            cap = self._bkt_cap
            while cap < n:
                cap *= 2
            self._bkt_cap = cap
            bits = self._bits_for(cap)
            if bits != self._bkt_bits:
                self._set_bits(bits)
                return
        self._refresh_directory(touched)

    def _refresh_directory(self, touched) -> None:
        """Vectorized O(B log n) directory recompute: per-bucket starts,
        occupancy, and successor pad ids.  Dirty rows = rows whose
        occupancy or pad changed, plus the rows of explicitly touched
        ids (an id swap inside one bucket keeps occ AND pad constant but
        still rewrites row content)."""
        act = self.active_ids()
        n = int(act.size)
        if n == 0:
            self._bkt_valid = False
            self._bkt_dirty[:] = True
            return
        starts = np.searchsorted(act, self._bkt_edges).astype(np.int64)
        ends = np.append(starts[1:], n)
        occ = (ends - starts).astype(np.int32)
        if int(occ.max()) >= _BUCKET_ROW:   # no slack slot left for pad
            if self._escalate(act):
                return
            # clustering the radix cannot split (e.g. ids differing only
            # in low bits past R): flat scan takes over until it clears
            self._bkt_valid = False
            self._bkt_dirty[:] = True
            self._bkt_occ, self._bkt_starts = occ, starts
            self._bkt_pad = act[ends % n]
            return
        pad = act[ends % n]
        dirty = (occ != self._bkt_occ) | (pad != self._bkt_pad)
        if touched is not None and len(touched):
            rows = (np.asarray(touched, np.uint64)
                    >> np.uint64(64 - self._bkt_bits)).astype(np.int64)
            dirty[rows] = True
        self._bkt_dirty |= dirty
        self._bkt_occ, self._bkt_pad, self._bkt_starts = occ, pad, starts
        self._bkt_valid = True

    def _escalate(self, act: np.ndarray) -> bool:
        """Overflowing bucket: try a finer radix (more directory bits)
        within the memory budget before giving up on the index."""
        from repro.kernels.backend import bucket_budget_bytes
        bits = self._bkt_bits
        max_bits = self._bits_for(self._bkt_cap) + _MAX_R_BONUS
        while bits < max_bits:
            bits += 1
            if (1 << bits) * _BUCKET_ROW * 8 > bucket_budget_bytes():
                return False
            edges = np.arange(1 << bits, dtype=np.uint64) \
                << np.uint64(64 - bits)
            occ = np.diff(np.append(np.searchsorted(act, edges), act.size))
            if int(occ.max()) < _BUCKET_ROW:
                self._set_bits(bits)
                return True
        return False

    def _build_rows(self, rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(hi, lo) uint32 row blocks for the given bucket indices: live
        entries first, successor pad id in every slack slot."""
        act = self.active_ids()
        starts = self._bkt_starts[rows]
        occ = self._bkt_occ[rows].astype(np.int64)
        pad = self._bkt_pad[rows]
        j = np.arange(_BUCKET_ROW, dtype=np.int64)[None, :]
        idx = np.minimum(starts[:, None] + j, act.size - 1)
        vals = np.where(j < occ[:, None], act[idx], pad[:, None])
        return ((vals >> _WORD).astype(np.uint32),
                (vals & _LO_MASK).astype(np.uint32))

    def device_bucket_table(self):
        """(bkt_hi, bkt_lo, occ) jnp arrays for the bucketized kernel,
        or None while the radix cannot represent the view (empty table /
        unsplittable clustering) — callers fall back to the flat scan.

        Delta protocol: after the first full materialization, a sync
        ships ONLY the rows membership batches dirtied since the last
        sync, as one scatter-update per array — device maintenance
        traffic is O(touched buckets) per EDRA batch, never O(n)."""
        self._enable_buckets()
        if not self._bkt_valid:
            return None
        if self._bkt_dev is not None and self._bkt_dev_bits == self._bkt_bits \
                and not self._bkt_dirty.any():
            return self._bkt_dev
        import jax.numpy as jnp  # lazy: keep pure-python users jax-free

        nb = 1 << self._bkt_bits
        if self._bkt_dev is None or self._bkt_dev_bits != self._bkt_bits:
            hi, lo = self._build_rows(np.arange(nb))
            self._bkt_dev = (jnp.asarray(hi), jnp.asarray(lo),
                             jnp.asarray(self._bkt_occ))
            self._bkt_dev_bits = self._bkt_bits
            self.full_uploads += 1
            self.upload_bytes += nb * (_BUCKET_ROW * 8 + 4)
        else:
            rows = np.nonzero(self._bkt_dirty)[0]
            hi, lo = self._build_rows(rows)
            bhi, blo, occ = self._bkt_dev
            at = jnp.asarray(rows.astype(np.int32))
            self._bkt_dev = (bhi.at[at].set(jnp.asarray(hi)),
                             blo.at[at].set(jnp.asarray(lo)),
                             occ.at[at].set(jnp.asarray(self._bkt_occ[rows])))
            self.delta_uploads += 1
            self.upload_bytes += int(rows.size) * (_BUCKET_ROW * 8 + 4)
        self.upload_count += 1
        self._bkt_dirty[:] = False
        return self._bkt_dev

    def bucket_stats(self) -> dict:
        """Observability for the two-level index (bench + tests)."""
        if not self._bkt_enabled or self._bkt_occ is None:
            return {"enabled": False}
        occ = self._bkt_occ
        nb = 1 << self._bkt_bits
        return {
            "enabled": True,
            "valid": bool(self._bkt_valid),
            "buckets": nb,
            "row_width": _BUCKET_ROW,
            "max_occupancy": int(occ.max()) if occ.size else 0,
            "mean_occupancy": float(occ.mean()) if occ.size else 0.0,
            "directory_bytes": nb * 4,
            "matrix_bytes": nb * _BUCKET_ROW * 8,
        }

    # -- device-resident table -------------------------------------------------
    @property
    def device_capacity(self) -> int:
        """Padded on-device table length (0 until first upload)."""
        return self._dev_capacity

    def device_table(self):
        """(table_hi, table_lo, n) jnp arrays for the ring_lookup64 kernel.

        Rebuilt (and re-uploaded) only when the *active* view moved since
        the last call (quarantine-only tracking changes don't count);
        capacity-padded so churn only changes the *data*, never the
        shapes the jitted kernel specialized on.
        """
        if self._dev is not None and self._dev_version == self.active_version:
            return self._dev
        import jax.numpy as jnp  # lazy: keep pure-python users jax-free

        act = self.active_ids()
        n = int(act.size)
        cap = max(self._dev_capacity, _MIN_DEVICE_CAPACITY)
        while cap < n:
            cap *= 2
        hi = np.zeros(cap, np.uint32)
        lo = np.zeros(cap, np.uint32)
        hi[:n] = (act >> _WORD).astype(np.uint32)
        lo[:n] = (act & _LO_MASK).astype(np.uint32)
        self._dev = (jnp.asarray(hi), jnp.asarray(lo),
                     jnp.asarray([n], jnp.int32))
        self._dev_capacity = cap
        self._dev_version = self.active_version
        self.upload_count += 1
        self.full_uploads += 1             # the flat table has no delta
        self.upload_bytes += cap * 8 + 4   # path: every sync re-ships it
        return self._dev

    def lookup(self, keys: np.ndarray, *, use_pallas: bool = True,
               interpret: Optional[bool] = None,
               use_buckets: Optional[bool] = None) -> np.ndarray:
        """Batched on-device successor lookup: (Q,) uint64 key IDs ->
        (Q,) uint64 owner peer IDs.

        Dispatch (DESIGN.md §7): tables of ``_BUCKET_MIN_N`` peers or
        more resolve through the two-level bucket index (O(row) per
        key); smaller tables — and views the radix cannot partition —
        use the flat compare-and-count scan.  ``use_buckets`` pins the
        preference (True still falls back when the index is invalid);
        ``interpret=None`` autodetects the backend: compiled on real
        TPUs, interpreter mode elsewhere."""
        import jax.numpy as jnp

        act = self.active_ids()
        if not act.size:
            raise LookupError("empty routing table")
        keys = np.asarray(keys, np.uint64)
        khi = jnp.asarray((keys >> _WORD).astype(np.uint32))
        klo = jnp.asarray((keys & _LO_MASK).astype(np.uint32))
        if use_buckets is None:
            use_buckets = act.size >= _BUCKET_MIN_N
        if use_buckets:
            dev = self.device_bucket_table()
            if dev is not None:
                from repro.kernels.ring_lookup.ops import ring_lookup_bucketed
                ohi, olo = ring_lookup_bucketed(khi, klo, *dev,
                                                use_pallas=use_pallas,
                                                interpret=interpret)
                return (np.asarray(ohi).astype(np.uint64) << _WORD) \
                    | np.asarray(olo).astype(np.uint64)
        from repro.kernels.ring_lookup.ops import ring_lookup64
        thi, tlo, n = self.device_table()
        idx = np.asarray(ring_lookup64(khi, klo, thi, tlo, n,
                                       use_pallas=use_pallas,
                                       interpret=interpret))
        return act[idx]

    def lookup_keys(self, keys: Sequence[str], *, namespace: str = "") -> np.ndarray:
        """Hash string keys onto the ring and resolve owners on-device."""
        from .ring import hash_id
        ids = np.fromiter(
            (hash_id(f"{namespace}{k}") for k in keys), np.uint64, len(keys))
        return self.lookup(ids)
