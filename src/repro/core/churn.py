"""Shared churn-experiment shapes (paper §VII methodology).

``ChurnConfig`` / ``ChurnResult`` / ``SessionDist`` are the SINGLE
definition of a §VII churn run, consumed by both simulation planes:

  * the message-level DES oracle (``repro.dht.experiment.run_churn``),
  * the vectorized plane (``repro.core.jax_sim.simulate_churn``) that
    reproduces the same measurement at n up to 10^6-10^7.

Keeping the shapes here (framework-free, no dht/jax imports) lets the
twin tests drive both planes from ONE config and compare their
``ChurnResult``s field by field (DESIGN.md §8).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, Optional


# ---------------------------------------------------------------------------
# Session-length distributions (§V: P2P sessions are heavy-tailed)
# ---------------------------------------------------------------------------

class SessionDist:
    """Exponential by default; ``volatile_fraction`` mixes in short
    (< t_q) sessions to model the heavy tail head (24% KAD / 31% Gnutella
    sessions under 10 min)."""

    def __init__(self, s_avg: float, volatile_fraction: float = 0.0,
                 t_q: float = 600.0):
        self.s_avg = s_avg
        self.vol = volatile_fraction
        self.t_q = t_q
        if volatile_fraction > 0.0:
            short_mean = t_q / 2.0
            self.long_mean = (s_avg - volatile_fraction * short_mean) / (
                1.0 - volatile_fraction)
        else:
            self.long_mean = s_avg

    def sample(self, rng: random.Random) -> float:
        if self.vol > 0.0 and rng.random() < self.vol:
            return rng.uniform(0.0, self.t_q)
        return rng.expovariate(1.0 / self.long_mean)

    def sample_array(self, rng, size: int):
        """Vectorized twin of ``sample`` for a numpy Generator."""
        import numpy as np
        long = rng.exponential(self.long_mean, size=size)
        if self.vol <= 0.0:
            return long
        short = rng.uniform(0.0, self.t_q, size=size)
        return np.where(rng.random(size) < self.vol, short, long)


# ---------------------------------------------------------------------------
# Experiment config / result
# ---------------------------------------------------------------------------

@dataclass
class ChurnConfig:
    n: int
    s_avg: float                  # seconds
    protocol: str = "d1ht"        # "d1ht" | "calot"
    duration: float = 1800.0      # metered window (paper: 30 min)
    warmup: float = 300.0
    delay: Optional[object] = None  # repro.dht.des.DelayModel (duck-typed)
    seed: int = 0
    rejoin_delay: float = 180.0   # paper: rejoin in 3 minutes, same ID
    crash_fraction: float = 0.5   # paper: half the leaves are SIGKILL
    lookup_samples: int = 4000
    quarantine_tq: Optional[float] = None
    volatile_fraction: float = 0.0
    f: float = 0.01


@dataclass
class ChurnResult:
    cfg: ChurnConfig
    params: object                # repro.core.tuning.EdraParams
    events: int
    one_hop_fraction: float
    sum_out_bps: float            # Σ over peers (Figs 3-4 plot the sum)
    mean_out_bps: float
    analytical_bps: float         # per-peer model prediction
    quarantine_admitted: int = 0
    quarantine_skipped: int = 0
    mean_ack_s: float = 0.0       # vectorized plane only (0.0 from the DES)
    p99_ack_s: float = 0.0

    @property
    def stale_fraction(self) -> float:
        """Expected fraction of routing-table entries a random lookup
        finds stale (1 - one-hop fraction) — the f' the request-latency
        plane consumes, measured rather than assumed (paper §IV-D ties
        lookup retries to exactly this staleness)."""
        return max(0.0, 1.0 - self.one_hop_fraction)

    def summary(self) -> Dict[str, float]:
        return {
            "n": self.cfg.n,
            "protocol": self.cfg.protocol,
            "events": self.events,
            "one_hop_fraction": round(self.one_hop_fraction, 5),
            "mean_out_bps": round(self.mean_out_bps, 1),
            "sum_out_kbps": round(self.sum_out_bps / 1000.0, 1),
            "analytical_bps": round(self.analytical_bps, 1),
            "ratio_sim_over_model": round(
                self.mean_out_bps / max(self.analytical_bps, 1e-9), 3),
        }


def delay_mean_seconds(delay: Optional[object]) -> float:
    """Mean one-way delay of a DelayModel without importing repro.dht.

    Duck-typed on the two models the DES defines: ``LanDelay`` exposes
    ``mean`` (shifted exponential whose total mean IS ``mean``);
    ``WanDelay`` exposes ``mu``/``sigma`` (lognormal, mean =
    exp(mu + sigma^2/2)).  ``None`` means the DES default (LAN)."""
    if delay is None:
        return 70e-6
    if hasattr(delay, "mean"):
        return float(delay.mean)
    if hasattr(delay, "mu") and hasattr(delay, "sigma"):
        return float(math.exp(delay.mu + delay.sigma ** 2 / 2.0))
    raise TypeError(f"cannot derive a mean delay from {delay!r}")
