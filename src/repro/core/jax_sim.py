"""Vectorized EDRA simulator (pure JAX).

Simulates event dissemination over a D1HT ring at protocol granularity —
per-event, per-peer acknowledge times following the *exact* EDRA tree
(binomial offsets, per-hop interval flushes, message delays, Rule-8
truncation) — without materializing individual messages.  Used to:

  * measure the one-hop-lookup fraction under churn (paper claim C1),
  * measure per-peer maintenance bandwidth and cross-validate the
    analytical model, Eqs IV.5-IV.7 (claim C5),
  * measure acknowledge-time statistics against the Theorem-1 bound.

Two entry points (DESIGN.md §8):

  * ``simulate(SimConfig)`` — the original fixed-n plane: dense (E, n)
    event-by-peer matrices, exact per-peer metering, 10^4..10^5 peers.
  * ``simulate_churn(ChurnConfig)`` — the §VII reproduction at the
    paper's Internet scale (n up to 10^6-10^7): continuous
    join/leave/crash churn with Quarantine admission (the same
    ``ChurnConfig`` the message-level DES consumes), D1HT vs 1h-Calot
    head-to-head, per-peer maintenance bandwidth + one-hop metering
    matching the DES's §VII-A accounting.  The (E, n) matrix is
    replaced by sampled (event, observer) pairs whose acknowledge
    times come from the ``kernels.edra_tree`` Pallas kernel (ancestor-
    chain walk, O(log n) per pair), so the measurement window at
    n = 10^6 is a few tens of millions of pair evaluations instead of
    10^11 matrix cells.

The protocol-faithful message-level implementation lives in repro.dht
(discrete-event simulator); it stays the oracle the vectorized planes
are twin-checked against at overlapping n (tests/test_jax_sim.py).

Model notes
-----------
* Peers have asynchronous Theta intervals (random phases).
* A peer that acknowledges an event at time t forwards it at its next
  interval boundary; all children of that flush share the flush instant
  and draw independent network delays (exponential with mean delta_avg).
* Failures (half of leaves, as in §VII-A) are detected after
  U(Theta, 2*Theta) — one missed TTL-0 message plus the probe (Rule 5);
  joins and voluntary leaves are announced immediately.
* A routing-table entry is stale from the instant the event happens until
  the observing peer acknowledges it; a random-target lookup fails with
  probability (#stale entries)/n (paper §IV-D).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .churn import ChurnConfig, ChurnResult, SessionDist, delay_mean_seconds
from .tuning import EdraParams
from .analysis import (M_BITS, V_A, V_C, V_H, V_M, calot_bandwidth,
                       d1ht_bandwidth)


@dataclass(frozen=True)
class SimConfig:
    n: int                      # ring size (held constant; leave+rejoin churn)
    s_avg: float                # average session length, seconds
    duration: float = 1800.0    # measurement window, seconds (paper: 30 min)
    f: float = 0.01
    delta_avg: float = 0.050    # mean one-way message delay, seconds
    failure_fraction: float = 0.5   # of leaves detected via Rule 5 (§VII-A)
    lookups: int = 4096         # lookup samples for the one-hop fraction
    seed: int = 0


@dataclass
class SimResult:
    params: EdraParams
    num_events: int
    one_hop_fraction: float
    mean_ack_time: float
    p99_ack_time: float
    theorem1_bound: float       # rho*Theta/2 + detection & delay allowances
    mean_out_bps: float
    p95_out_bps: float
    analytical_bps: float
    per_peer_out_bps: np.ndarray

    def summary(self) -> Dict[str, float]:
        return {
            "n": self.params.n,
            "theta_s": self.params.theta,
            "events": self.num_events,
            "one_hop_fraction": self.one_hop_fraction,
            "mean_ack_s": self.mean_ack_time,
            "p99_ack_s": self.p99_ack_time,
            "t_avg_bound_s": self.theorem1_bound,
            "mean_out_bps": self.mean_out_bps,
            "p95_out_bps": self.p95_out_bps,
            "analytical_bps": self.analytical_bps,
        }


def _popcount(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.population_count(x.astype(jnp.uint32)).astype(jnp.int32)


def _trailing_zeros(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.int32)
    lsb = jnp.bitwise_and(x, -x)
    return _popcount((lsb - 1).astype(jnp.uint32))


@partial(jax.jit, static_argnames=("n", "rho", "num_events", "num_lookups",
                                   "num_intervals"))
def _simulate_core(key, *, n: int, rho: int, num_events: int, num_lookups: int,
                   num_intervals: int, theta: float, duration: float,
                   delta_avg: float, failure_fraction: float):
    k_ev, k_rep, k_fail, k_phase, k_delay, k_det, k_lt, k_lo = jax.random.split(key, 8)

    # --- events ------------------------------------------------------------
    t_event = jnp.sort(jax.random.uniform(k_ev, (num_events,), maxval=duration))
    reporter = jax.random.randint(k_rep, (num_events,), 0, n)  # ring index of P
    is_failure = jax.random.uniform(k_fail, (num_events,)) < failure_fraction
    detect_extra = jnp.where(
        is_failure,
        theta + jax.random.uniform(k_det, (num_events,)) * theta,  # U(Θ, 2Θ)
        0.0,
    )
    t_detect = t_event + detect_extra

    # --- per-peer interval phases -------------------------------------------
    phase = jax.random.uniform(k_phase, (n,)) * theta

    def next_flush(t, ph):
        """First interval boundary of a peer with phase ph strictly after t."""
        return ph + jnp.ceil((t - ph) / theta + 1e-9) * theta

    # --- exact tree propagation ---------------------------------------------
    # offsets[e, j] = clockwise offset of peer j from event e's reporter
    peers = jnp.arange(n, dtype=jnp.int32)
    offsets = (peers[None, :] - reporter[:, None]) % n          # (E, n)
    ttl = jnp.where(offsets == 0, rho, _trailing_zeros(offsets))
    depth = _popcount(offsets)
    parent = jnp.bitwise_and(offsets, offsets - 1)              # (E, n) offsets
    parent_peer = (parent + reporter[:, None]) % n              # ring index

    delays = jax.random.exponential(k_delay, (num_events, n)) * delta_avg

    # iterate depth levels: ack[d] = flush(ack[parent]) + delay
    ack0 = jnp.where(offsets == 0, t_detect[:, None], jnp.inf)

    def level(ack, d):
        # columns of ``ack`` are ring indices; the tree parent of the peer
        # in column j sits at ring index parent_peer[e, j]
        parent_ack = jnp.take_along_axis(ack, parent_peer, axis=1)
        parent_phase = phase[parent_peer]
        t = next_flush(parent_ack, parent_phase) + delays
        ack = jnp.where((depth == d) & (offsets != 0), t, ack)
        return ack, None

    ack, _ = jax.lax.scan(level, ack0, jnp.arange(1, rho + 1))
    ack_rel = ack - t_event[:, None]                            # ack latency

    # --- one-hop lookup fraction --------------------------------------------
    t_lookup = jax.random.uniform(k_lt, (num_lookups,), maxval=duration)
    origin = jax.random.randint(k_lo, (num_lookups,), 0, n)
    ack_at_origin = ack[:, :]  # (E, n)
    # stale[e, l] = event e happened before lookup l but origin not yet acked
    ev_before = t_event[:, None] <= t_lookup[None, :]
    not_acked = jnp.take_along_axis(
        ack_at_origin, origin[None, :].astype(jnp.int32), axis=1
    ) > t_lookup[None, :]
    stale_counts = jnp.sum(ev_before & not_acked, axis=0)       # per lookup
    one_hop = 1.0 - jnp.mean(stale_counts / n)

    # --- maintenance traffic --------------------------------------------------
    # message M(l>=1) sent by peer j at interval k iff it acked an event with
    # TTL >= l+1 during k (Rules 3-4).  TTL-0 messages are always sent.
    k_idx = jnp.clip(
        jnp.floor((ack - phase[None, :]) / theta).astype(jnp.int32),
        0, num_intervals - 1,
    )
    in_window = ack < duration

    flat_jk = (peers[None, :] * num_intervals + k_idx).astype(jnp.int32)  # (E,n)

    def msgs_for_level(l):
        mark = jnp.zeros((n * num_intervals,), dtype=jnp.bool_)
        sel = (ttl >= l + 1) & in_window
        mark = mark.at[jnp.where(sel, flat_jk, 0)].max(sel)
        mark = mark.reshape(n, num_intervals)
        return jnp.sum(mark, axis=1)                             # per-peer count

    sent_per_l = jax.vmap(msgs_for_level)(jnp.arange(1, rho))    # (rho-1, n)
    ttl0_msgs = jnp.full((n,), jnp.floor(duration / theta).astype(jnp.int32))
    msgs_sent = ttl0_msgs + jnp.sum(sent_per_l, axis=0)

    # receivers: M(l) from j arrives at j + 2^l (ring): received == sent shifted
    def recv_for_level(l, sent):
        return jnp.roll(sent, 1 << l)

    recv_per_l = jax.vmap(recv_for_level)(jnp.arange(1, rho), sent_per_l)
    msgs_recv = jnp.roll(ttl0_msgs, 1) + jnp.sum(recv_per_l, axis=0)

    # payload: event acked with TTL=t is re-sent in messages l < t whose
    # target offset + 2^l stays inside the ring (Rule 8).
    l_range = jnp.arange(rho)[None, None, :]                     # (1,1,rho)
    sends = (l_range < ttl[:, :, None]) & \
            ((offsets[:, :, None] + (1 << l_range)) < n) & in_window[:, :, None]
    payload_bits = M_BITS * jnp.sum(sends, axis=(0, 2))          # per peer

    out_bits = msgs_sent * V_M + msgs_recv * V_A + payload_bits
    out_bps = out_bits / duration

    return one_hop, ack_rel, out_bps, jnp.sum(in_window)


def simulate(cfg: SimConfig) -> SimResult:
    params = EdraParams.derive(cfg.n, cfg.s_avg, cfg.f)
    num_events = max(1, int(round(params.r * cfg.duration)))
    if num_events * cfg.n > 6e7:
        raise ValueError(
            f"sim too large: events({num_events}) x n({cfg.n}) — shrink duration")
    num_intervals = int(np.ceil(cfg.duration / params.theta)) + 2

    key = jax.random.PRNGKey(cfg.seed)
    one_hop, ack_rel, out_bps, _ = _simulate_core(
        key, n=cfg.n, rho=params.rho, num_events=num_events,
        num_lookups=cfg.lookups, num_intervals=num_intervals,
        theta=params.theta, duration=cfg.duration,
        delta_avg=cfg.delta_avg, failure_fraction=cfg.failure_fraction)

    ack_np = np.asarray(ack_rel)
    finite = ack_np[np.isfinite(ack_np)]
    out_np = np.asarray(out_bps)
    from .analysis import d1ht_bandwidth
    return SimResult(
        params=params,
        num_events=num_events,
        one_hop_fraction=float(one_hop),
        mean_ack_time=float(finite.mean()),
        p99_ack_time=float(np.percentile(finite, 99)),
        theorem1_bound=params.t_avg,
        mean_out_bps=float(out_np.mean()),
        p95_out_bps=float(np.percentile(out_np, 95)),
        analytical_bps=d1ht_bandwidth(cfg.n, cfg.s_avg, cfg.f),
        per_peer_out_bps=out_np,
    )


# ---------------------------------------------------------------------------
# Vectorized churn plane (DESIGN.md §8): the §VII experiment at 10^6 peers
# ---------------------------------------------------------------------------

_CALOT_HEARTBEAT = 15.0      # four per minute (§VII-A)
_CALOT_PROBE_TIMEOUT = 5.0   # dht.calot_node probe confirmation window


def _churn_event_stream(cfg: ChurnConfig, rng):
    """Continuous join/leave/crash churn as per-peer renewal processes.

    Mirrors dht.experiment.run_churn's driver: per-peer sessions from
    the §V volatile-fraction mix, half the leaves are crashes, leavers
    rejoin after ``rejoin_delay`` with the same ID, and — when
    ``quarantine_tq`` is set — a rejoin whose sampled session is shorter
    than T_q is never admitted (no events at all, retry after the
    session, §V) while admitted peers enter T_q late with the remainder
    of their session.  Vectorized over peers round by round (each round
    advances every still-active peer one alive/off cycle).

    Returns (t, kind, crash) sorted by time — kind +1 join / -1 leave,
    t the instant the ground-truth ring changes — plus quarantine
    admission counters.
    """
    horizon = cfg.warmup + cfg.duration
    sessions = SessionDist(cfg.s_avg, cfg.volatile_fraction,
                           cfg.quarantine_tq or 600.0)
    t_parts, k_parts, c_parts = [], [], []
    q_admit = q_skip = 0
    start = np.zeros(cfg.n)
    sess = sessions.sample_array(rng, cfg.n)   # initial population: no gate
    active = np.ones(cfg.n, bool)
    while active.any():
        idx = np.nonzero(active)[0]
        t_leave = start[idx] + np.maximum(sess[idx], 1.0)
        keep = t_leave <= horizon
        idx, t_leave = idx[keep], t_leave[keep]
        active[:] = False
        if not idx.size:
            break
        crash = rng.random(idx.size) < cfg.crash_fraction
        t_parts.append(t_leave)
        k_parts.append(np.full(idx.size, -1, np.int8))
        c_parts.append(crash)

        t_re = t_leave + cfg.rejoin_delay
        s_new = sessions.sample_array(rng, idx.size)
        if cfg.quarantine_tq is not None:
            tq = cfg.quarantine_tq
            while True:
                retry = (s_new <= tq) & (t_re <= horizon)
                if not retry.any():
                    break
                q_skip += int(retry.sum())
                t_re = np.where(retry, t_re + s_new + cfg.rejoin_delay, t_re)
                s_new = np.where(retry, sessions.sample_array(rng, idx.size),
                                 s_new)
            t_join = t_re + tq
            admit = (s_new > tq) & (t_join <= horizon)
            q_admit += int(admit.sum())
            s_next = np.maximum(s_new - tq, 1.0)
        else:
            t_join = t_re
            admit = t_join <= horizon
            s_next = s_new
        j = idx[admit]
        t_parts.append(t_join[admit])
        k_parts.append(np.full(j.size, 1, np.int8))
        c_parts.append(np.zeros(j.size, bool))
        start[j] = t_join[admit]
        sess[j] = s_next[admit]
        active[j] = True

    t = np.concatenate(t_parts) if t_parts else np.zeros(0)
    kind = np.concatenate(k_parts) if k_parts else np.zeros(0, np.int8)
    crash = np.concatenate(c_parts) if c_parts else np.zeros(0, bool)
    order = np.argsort(t, kind="stable")
    return t[order], kind[order], crash[order], q_admit, q_skip


def _mean_live(n0: int, t: np.ndarray, kind: np.ndarray,
               w0: float, w1: float) -> float:
    """Time-averaged live-peer count over [w0, w1] from the event stream."""
    n_after = n0 + np.cumsum(kind, dtype=np.int64)
    inside = (t > w0) & (t < w1)
    ti = t[inside]
    ni = n_after[inside]
    i0 = int(np.searchsorted(t, w0, side="right"))
    n_at_w0 = int(n_after[i0 - 1]) if i0 > 0 else n0
    edges = np.concatenate([[w0], ti, [w1]])
    vals = np.concatenate([[n_at_w0], ni])
    return float(np.sum(vals * np.diff(edges)) / max(w1 - w0, 1e-9))


def _distinct_interval_counts(slot: np.ndarray, k_idx: np.ndarray,
                              num_intervals: int, m: int) -> np.ndarray:
    """Per-slot count of distinct interval indices (Rules 3-4 message
    dedup: one M(l) per interval regardless of how many events it
    carries).  slot/k_idx: (S,) int arrays of selected pairs."""
    if not slot.size:
        return np.zeros(m, np.int64)
    flat = np.unique(slot.astype(np.int64) * num_intervals + k_idx)
    return np.bincount(flat // num_intervals, minlength=m)


def simulate_churn(cfg: ChurnConfig, *, meter_peers: Optional[int] = None,
                   pair_budget: int = 24_000_000, chunk: int = 1 << 21,
                   use_pallas: bool = True,
                   interpret: Optional[bool] = None) -> ChurnResult:
    """§VII churn measurement on the vectorized plane (D1HT or 1h-Calot).

    Consumes the SAME ``ChurnConfig`` as the message-level DES
    (dht.experiment.run_churn) and produces the same ``ChurnResult``
    shape, so the two planes are interchangeable — the DES stays the
    per-message oracle at n <= ~10^3, this plane carries the
    measurement to the paper's "millions of users" regime (Figs 3-4).

    Metering matches the DES's §VII-A accounting: per-peer outbound
    bits = maintenance-message headers sent (one M(l) per Theta
    interval that acknowledged an event with TTL > l, M(0) always) +
    acks for messages received + Rule-8-truncated event payloads;
    lookups and routing-table transfers excluded.  Per-peer quantities
    are measured on ``meter_peers`` sampled observers (default: sized
    so event x observer pairs stay under ``pair_budget``); acknowledge
    times come from the ``kernels.edra_tree`` kernel.
    """
    from repro.kernels.edra_tree.ops import edra_tree

    rng = np.random.default_rng(cfg.seed)
    params = EdraParams.derive(cfg.n, cfg.s_avg, cfg.f)
    theta = params.theta
    delta_avg = delay_mean_seconds(cfg.delay)
    calot = cfg.protocol == "calot"
    w0, w1 = cfg.warmup, cfg.warmup + cfg.duration

    t, kind, crash, q_admit, q_skip = _churn_event_stream(cfg, rng)
    n_after = np.maximum(cfg.n + np.cumsum(kind, dtype=np.int64), 2)
    nbar = _mean_live(cfg.n, t, kind, w0, w1)

    # events whose dissemination can overlap the metered window: the ack
    # tail spans detection (<= 2 Theta) + rho buffered hops
    tail = (params.rho + 2) * theta + 20.0 * delta_avg + 1.0
    if calot:
        tail = 2.5 * _CALOT_HEARTBEAT + _CALOT_PROBE_TIMEOUT \
            + (params.rho + 2) * 3.0 * delta_avg + 1.0
    sel = (t >= w0 - tail) & (t <= w1)
    t_ev = t[sel]
    crash_ev = crash[sel]
    n_ev = n_after[sel].astype(np.uint32)
    e = int(t_ev.size)
    events_in_window = int(np.sum((t >= w0) & (t <= w1)))

    if calot:
        detect = t_ev + np.where(
            crash_ev,
            1.5 * _CALOT_HEARTBEAT + rng.uniform(0, _CALOT_HEARTBEAT, e)
            + _CALOT_PROBE_TIMEOUT,
            0.0)
    else:
        detect = t_ev + np.where(
            crash_ev, theta + rng.uniform(0, theta, e), 0.0)   # U(Θ, 2Θ)

    m = meter_peers or int(np.clip(pair_budget // max(e, 1), 16, 1024))
    analytical = (calot_bandwidth(cfg.n, cfg.s_avg) if calot else
                  d1ht_bandwidth(cfg.n, cfg.s_avg, cfg.f))

    # Eq IV.4 early interval close: every peer acks every event, so its
    # buffer fills at the global event rate; an interval also ends when
    # the buffer reaches E (dht.d1ht_node._early_close_check).  The
    # effective interval length feeds the message accounting below and
    # the kernel's per-hop flush model.
    fill_rate = t.size / max(cfg.warmup + cfg.duration, 1.0)
    e_cap = float(max(2.0, np.ceil(params.max_events)))
    if calot or fill_rate <= 0.0:
        theta_eff = theta
    else:
        fills = rng.gamma(e_cap, 1.0 / fill_rate, 8192)
        theta_eff = float(np.minimum(theta, fills).mean())
    if e == 0:
        return ChurnResult(
            cfg=cfg, params=params, events=0, one_hop_fraction=1.0,
            sum_out_bps=0.0, mean_out_bps=0.0, analytical_bps=analytical,
            quarantine_admitted=q_admit, quarantine_skipped=q_skip)

    # (E, M) pairs: uniform observer offsets per event (reporters are
    # uniform on the ring, so fixed metered peers see uniform offsets)
    reporter = (rng.random(e) * n_ev).astype(np.uint32)
    offsets = (rng.random((e, m)) * n_ev[:, None]).astype(np.uint32)
    ekey = rng.integers(0, 2**32, size=e, dtype=np.uint64).astype(np.uint32)
    levels = max(1, int(np.ceil(np.log2(max(cfg.n, 2)))))

    p = e * m
    flat = {
        "offset": offsets.reshape(p),
        "n": np.broadcast_to(n_ev[:, None], (e, m)).reshape(p),
        "reporter": np.broadcast_to(reporter[:, None], (e, m)).reshape(p),
        "t0": np.broadcast_to(detect[:, None].astype(np.float32),
                              (e, m)).reshape(p),
        "ekey": np.broadcast_to(ekey[:, None], (e, m)).reshape(p),
    }
    csize = min(chunk, (p + 2047) // 2048 * 2048)
    ack = np.empty(p, np.float32)
    ttl = np.empty(p, np.int32)
    sends = np.empty(p, np.int32)
    kernel_theta = 0.0 if calot else theta   # Calot forwards unbuffered
    for lo in range(0, p, csize):
        hi = min(lo + csize, p)
        pad = csize - (hi - lo)
        args = [np.pad(flat[k][lo:hi], (0, pad), constant_values=v)
                for k, v in (("offset", 0), ("n", 1), ("reporter", 0),
                             ("t0", 0), ("ekey", 0))]
        a, tt, _d, _par, sn = edra_tree(
            *(jnp.asarray(x) for x in args),
            levels=levels, theta=kernel_theta, delta_avg=delta_avg,
            seed=cfg.seed, fill_rate=0.0 if calot else fill_rate,
            e_cap=e_cap, use_pallas=use_pallas, interpret=interpret)
        ack[lo:hi] = np.asarray(a)[:hi - lo]
        ttl[lo:hi] = np.asarray(tt)[:hi - lo]
        sends[lo:hi] = np.asarray(sn)[:hi - lo]

    ack = ack.reshape(e, m)
    ttl = ttl.reshape(e, m)
    sends = sends.reshape(e, m)
    in_win = (ack >= w0) & (ack < w1)

    # -- one-hop fraction: expected stale routing entries at a random
    #    lookup instant = sum over (event, observer) staleness overlap
    stale = np.clip(np.minimum(ack, w1) - np.maximum(t_ev[:, None], w0),
                    0.0, None)
    mean_stale_entries = float(stale.sum()) / m / cfg.duration
    one_hop = 1.0 - mean_stale_entries / max(nbar, 1.0)

    ack_rel = (ack - t_ev[:, None])[in_win]
    mean_ack = float(ack_rel.mean()) if ack_rel.size else 0.0
    p99_ack = float(np.percentile(ack_rel, 99)) if ack_rel.size else 0.0

    # -- per-peer maintenance traffic (§VII-A accounting) ------------------
    if calot:
        # one fixed-size message per event per tree edge + acks on every
        # reception + 4 unacked heartbeats/min (Eq VII.1 measured)
        out_bits = (sends * in_win).sum(axis=0).astype(np.float64) * V_C \
            + in_win.sum(axis=0) * V_A \
            + np.floor(cfg.duration / _CALOT_HEARTBEAT) * V_H
    else:
        num_intervals = int(np.ceil(cfg.duration / theta_eff)) + 2
        phase = rng.uniform(0.0, theta_eff, m)
        k_idx = np.clip(np.floor((ack - w0 - phase[None, :]) / theta_eff)
                        .astype(np.int64), 0, num_intervals - 1)
        slot = np.broadcast_to(np.arange(m)[None, :], (e, m))
        ttl0 = np.floor(cfg.duration / theta_eff)
        sent_levels = np.zeros(m, np.int64)
        off2 = offsets.astype(np.int64)
        n2 = n_ev[:, None].astype(np.int64)
        for l in range(1, params.rho):
            lv = in_win & (ttl > l) & ((off2 + (1 << l)) < n2)
            sent_levels += _distinct_interval_counts(
                slot[lv], k_idx[lv], num_intervals, m)
        msgs_sent = ttl0 + sent_levels
        # receptions: by ring symmetry the M(l) stream a peer receives is
        # the one the peer 2^l counterclockwise sends — another uniform
        # sample; decorrelate by rolling the metered sample
        msgs_recv = ttl0 + np.roll(sent_levels, 1)
        payload = (sends * in_win).sum(axis=0).astype(np.float64) * M_BITS
        out_bits = msgs_sent * V_M + msgs_recv * V_A + payload

    mean_out_bps = float(out_bits.mean()) / cfg.duration * (nbar / cfg.n)
    return ChurnResult(
        cfg=cfg, params=params, events=events_in_window,
        one_hop_fraction=float(one_hop),
        sum_out_bps=mean_out_bps * cfg.n, mean_out_bps=mean_out_bps,
        analytical_bps=analytical,
        quarantine_admitted=q_admit, quarantine_skipped=q_skip,
        mean_ack_s=mean_ack, p99_ack_s=p99_ack)
