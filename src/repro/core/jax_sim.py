"""Vectorized EDRA simulator (pure JAX).

Simulates event dissemination over a D1HT ring at protocol granularity —
per-event, per-peer acknowledge times following the *exact* EDRA tree
(binomial offsets, per-hop interval flushes, message delays, Rule-8
truncation) — without materializing individual messages.  Used to:

  * measure the one-hop-lookup fraction under churn (paper claim C1),
  * measure per-peer maintenance bandwidth and cross-validate the
    analytical model, Eqs IV.5-IV.7 (claim C5),
  * measure acknowledge-time statistics against the Theorem-1 bound.

The protocol-faithful message-level implementation lives in repro.dht
(discrete-event simulator); this module trades per-message fidelity for
scale (10^4..10^5 peers in seconds on CPU).

Model notes
-----------
* Peers have asynchronous Theta intervals (random phases).
* A peer that acknowledges an event at time t forwards it at its next
  interval boundary; all children of that flush share the flush instant
  and draw independent network delays (exponential with mean delta_avg).
* Failures (half of leaves, as in §VII-A) are detected after
  U(Theta, 2*Theta) — one missed TTL-0 message plus the probe (Rule 5);
  joins and voluntary leaves are announced immediately.
* A routing-table entry is stale from the instant the event happens until
  the observing peer acknowledges it; a random-target lookup fails with
  probability (#stale entries)/n (paper §IV-D).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .tuning import EdraParams
from .analysis import M_BITS, V_A, V_M


@dataclass(frozen=True)
class SimConfig:
    n: int                      # ring size (held constant; leave+rejoin churn)
    s_avg: float                # average session length, seconds
    duration: float = 1800.0    # measurement window, seconds (paper: 30 min)
    f: float = 0.01
    delta_avg: float = 0.050    # mean one-way message delay, seconds
    failure_fraction: float = 0.5   # of leaves detected via Rule 5 (§VII-A)
    lookups: int = 4096         # lookup samples for the one-hop fraction
    seed: int = 0


@dataclass
class SimResult:
    params: EdraParams
    num_events: int
    one_hop_fraction: float
    mean_ack_time: float
    p99_ack_time: float
    theorem1_bound: float       # rho*Theta/2 + detection & delay allowances
    mean_out_bps: float
    p95_out_bps: float
    analytical_bps: float
    per_peer_out_bps: np.ndarray

    def summary(self) -> Dict[str, float]:
        return {
            "n": self.params.n,
            "theta_s": self.params.theta,
            "events": self.num_events,
            "one_hop_fraction": self.one_hop_fraction,
            "mean_ack_s": self.mean_ack_time,
            "p99_ack_s": self.p99_ack_time,
            "t_avg_bound_s": self.theorem1_bound,
            "mean_out_bps": self.mean_out_bps,
            "p95_out_bps": self.p95_out_bps,
            "analytical_bps": self.analytical_bps,
        }


def _popcount(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.population_count(x.astype(jnp.uint32)).astype(jnp.int32)


def _trailing_zeros(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.int32)
    lsb = jnp.bitwise_and(x, -x)
    return _popcount((lsb - 1).astype(jnp.uint32))


@partial(jax.jit, static_argnames=("n", "rho", "num_events", "num_lookups",
                                   "num_intervals"))
def _simulate_core(key, *, n: int, rho: int, num_events: int, num_lookups: int,
                   num_intervals: int, theta: float, duration: float,
                   delta_avg: float, failure_fraction: float):
    k_ev, k_rep, k_fail, k_phase, k_delay, k_det, k_lt, k_lo = jax.random.split(key, 8)

    # --- events ------------------------------------------------------------
    t_event = jnp.sort(jax.random.uniform(k_ev, (num_events,), maxval=duration))
    reporter = jax.random.randint(k_rep, (num_events,), 0, n)  # ring index of P
    is_failure = jax.random.uniform(k_fail, (num_events,)) < failure_fraction
    detect_extra = jnp.where(
        is_failure,
        theta + jax.random.uniform(k_det, (num_events,)) * theta,  # U(Θ, 2Θ)
        0.0,
    )
    t_detect = t_event + detect_extra

    # --- per-peer interval phases -------------------------------------------
    phase = jax.random.uniform(k_phase, (n,)) * theta

    def next_flush(t, ph):
        """First interval boundary of a peer with phase ph strictly after t."""
        return ph + jnp.ceil((t - ph) / theta + 1e-9) * theta

    # --- exact tree propagation ---------------------------------------------
    # offsets[e, j] = clockwise offset of peer j from event e's reporter
    peers = jnp.arange(n, dtype=jnp.int32)
    offsets = (peers[None, :] - reporter[:, None]) % n          # (E, n)
    ttl = jnp.where(offsets == 0, rho, _trailing_zeros(offsets))
    depth = _popcount(offsets)
    parent = jnp.bitwise_and(offsets, offsets - 1)              # (E, n) offsets
    parent_peer = (parent + reporter[:, None]) % n              # ring index

    delays = jax.random.exponential(k_delay, (num_events, n)) * delta_avg

    # iterate depth levels: ack[d] = flush(ack[parent]) + delay
    ack0 = jnp.where(offsets == 0, t_detect[:, None], jnp.inf)

    def level(ack, d):
        # columns of ``ack`` are ring indices; the tree parent of the peer
        # in column j sits at ring index parent_peer[e, j]
        parent_ack = jnp.take_along_axis(ack, parent_peer, axis=1)
        parent_phase = phase[parent_peer]
        t = next_flush(parent_ack, parent_phase) + delays
        ack = jnp.where((depth == d) & (offsets != 0), t, ack)
        return ack, None

    ack, _ = jax.lax.scan(level, ack0, jnp.arange(1, rho + 1))
    ack_rel = ack - t_event[:, None]                            # ack latency

    # --- one-hop lookup fraction --------------------------------------------
    t_lookup = jax.random.uniform(k_lt, (num_lookups,), maxval=duration)
    origin = jax.random.randint(k_lo, (num_lookups,), 0, n)
    ack_at_origin = ack[:, :]  # (E, n)
    # stale[e, l] = event e happened before lookup l but origin not yet acked
    ev_before = t_event[:, None] <= t_lookup[None, :]
    not_acked = jnp.take_along_axis(
        ack_at_origin, origin[None, :].astype(jnp.int32), axis=1
    ) > t_lookup[None, :]
    stale_counts = jnp.sum(ev_before & not_acked, axis=0)       # per lookup
    one_hop = 1.0 - jnp.mean(stale_counts / n)

    # --- maintenance traffic --------------------------------------------------
    # message M(l>=1) sent by peer j at interval k iff it acked an event with
    # TTL >= l+1 during k (Rules 3-4).  TTL-0 messages are always sent.
    k_idx = jnp.clip(
        jnp.floor((ack - phase[None, :]) / theta).astype(jnp.int32),
        0, num_intervals - 1,
    )
    in_window = ack < duration

    flat_jk = (peers[None, :] * num_intervals + k_idx).astype(jnp.int32)  # (E,n)

    def msgs_for_level(l):
        mark = jnp.zeros((n * num_intervals,), dtype=jnp.bool_)
        sel = (ttl >= l + 1) & in_window
        mark = mark.at[jnp.where(sel, flat_jk, 0)].max(sel)
        mark = mark.reshape(n, num_intervals)
        return jnp.sum(mark, axis=1)                             # per-peer count

    sent_per_l = jax.vmap(msgs_for_level)(jnp.arange(1, rho))    # (rho-1, n)
    ttl0_msgs = jnp.full((n,), jnp.floor(duration / theta).astype(jnp.int32))
    msgs_sent = ttl0_msgs + jnp.sum(sent_per_l, axis=0)

    # receivers: M(l) from j arrives at j + 2^l (ring): received == sent shifted
    def recv_for_level(l, sent):
        return jnp.roll(sent, 1 << l)

    recv_per_l = jax.vmap(recv_for_level)(jnp.arange(1, rho), sent_per_l)
    msgs_recv = jnp.roll(ttl0_msgs, 1) + jnp.sum(recv_per_l, axis=0)

    # payload: event acked with TTL=t is re-sent in messages l < t whose
    # target offset + 2^l stays inside the ring (Rule 8).
    l_range = jnp.arange(rho)[None, None, :]                     # (1,1,rho)
    sends = (l_range < ttl[:, :, None]) & \
            ((offsets[:, :, None] + (1 << l_range)) < n) & in_window[:, :, None]
    payload_bits = M_BITS * jnp.sum(sends, axis=(0, 2))          # per peer

    out_bits = msgs_sent * V_M + msgs_recv * V_A + payload_bits
    out_bps = out_bits / duration

    return one_hop, ack_rel, out_bps, jnp.sum(in_window)


def simulate(cfg: SimConfig) -> SimResult:
    params = EdraParams.derive(cfg.n, cfg.s_avg, cfg.f)
    num_events = max(1, int(round(params.r * cfg.duration)))
    if num_events * cfg.n > 6e7:
        raise ValueError(
            f"sim too large: events({num_events}) x n({cfg.n}) — shrink duration")
    num_intervals = int(np.ceil(cfg.duration / params.theta)) + 2

    key = jax.random.PRNGKey(cfg.seed)
    one_hop, ack_rel, out_bps, _ = _simulate_core(
        key, n=cfg.n, rho=params.rho, num_events=num_events,
        num_lookups=cfg.lookups, num_intervals=num_intervals,
        theta=params.theta, duration=cfg.duration,
        delta_avg=cfg.delta_avg, failure_fraction=cfg.failure_fraction)

    ack_np = np.asarray(ack_rel)
    finite = ack_np[np.isfinite(ack_np)]
    out_np = np.asarray(out_bps)
    from .analysis import d1ht_bandwidth
    return SimResult(
        params=params,
        num_events=num_events,
        one_hop_fraction=float(one_hop),
        mean_ack_time=float(finite.mean()),
        p99_ack_time=float(np.percentile(finite, 99)),
        theorem1_bound=params.t_avg,
        mean_out_bps=float(out_np.mean()),
        p95_out_bps=float(np.percentile(out_np, 95)),
        analytical_bps=d1ht_bandwidth(cfg.n, cfg.s_avg, cfg.f),
        per_peer_out_bps=out_np,
    )
