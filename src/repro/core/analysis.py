"""Analytical maintenance-traffic models (paper §IV-E/F, §VII, §VIII).

Implements, with the paper's message formats (Fig. 2):

  * D1HT      — Eqs IV.5-IV.7 (per-peer, incoming == outgoing)
  * 1h-Calot  — Eq VII.1 (also valid for 1HS [44] and SFDHT [24], §II)
  * OneHop    — reconstruction of Fonseca et al. [17] with optimal
                topological parameters (the assumption the paper makes)
  * Quarantine — §V / §VIII overhead-reduction model

Wire constants (Fig. 2, bits, including 28-byte IPv4+UDP headers):
  v_m = 320  D1HT/OneHop maintenance message fixed part (40 bytes)
  v_c = 384  1h-Calot maintenance message (48 bytes, one event each)
  v_a = 288  acknowledgment (36 bytes)
  v_h = 288  heartbeat (36 bytes)
  m   = 32   bits per event (IPv4, default port; 48 with port number)

Note on Eq VII.1: the paper prints ``4*n*v_h/60`` for the heartbeat term;
dimensional analysis and the paper's own Fig. 7 values (1h-Calot slightly
above 140 kbps at n=1e6 with KAD dynamics) require the per-peer reading
``4*v_h/60`` (each peer sends four *unacknowledged* heartbeats per
minute).  We implement the per-peer term (see DESIGN.md §2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

import numpy as np

from .tuning import DEFAULT_F, event_rate, rho, theta

V_M = 320   # D1HT/OneHop maintenance header bits
V_C = 384   # 1h-Calot maintenance message bits (single event)
V_A = 288   # ack bits
V_H = 288   # heartbeat bits
M_BITS = 32  # bits per event (default port)


# ---------------------------------------------------------------------------
# D1HT (Eqs IV.5 - IV.7)
# ---------------------------------------------------------------------------

def p_msg(l: int, n: int, r: float, th: float, p: int | None = None) -> float:
    """Eq IV.6: P(l) = 1 - (1 - 2*r*Theta/n)^(2^(rho-l-1))."""
    p = rho(n) if p is None else p
    k = 2.0 ** (p - l - 1)
    base = max(0.0, 1.0 - 2.0 * r * th / n)
    return 1.0 - base ** k


def n_msgs(n: int, r: float, th: float) -> float:
    """Eq IV.7: average number of maintenance messages per Theta interval."""
    p = rho(n)
    return 1.0 + sum(p_msg(l, n, r, th, p) for l in range(1, p))


def d1ht_bandwidth(n: int, s_avg: float, f: float = DEFAULT_F,
                   v_m: int = V_M, v_a: int = V_A, m: int = M_BITS) -> float:
    """Eq IV.5 per-peer maintenance traffic, bit/s (out == in).

    (N_msgs * (v_m + v_a) + r * m * Theta) / Theta
    """
    th = theta(n, s_avg, f)
    r = event_rate(n, s_avg)
    return (n_msgs(n, r, th) * (v_m + v_a) + r * m * th) / th


def d1ht_bandwidth_components(n: int, s_avg: float, f: float = DEFAULT_F) -> Dict[str, float]:
    th = theta(n, s_avg, f)
    r = event_rate(n, s_avg)
    nm = n_msgs(n, r, th)
    return {
        "theta_s": th,
        "rho": rho(n),
        "event_rate_per_s": r,
        "n_msgs_per_interval": nm,
        "header_bps": nm * (V_M + V_A) / th,
        "payload_bps": r * M_BITS,
        "total_bps": nm * (V_M + V_A) / th + r * M_BITS,
    }


# ---------------------------------------------------------------------------
# 1h-Calot (Eq VII.1; per-peer heartbeat reading — see module docstring)
# ---------------------------------------------------------------------------

def calot_bandwidth(n: int, s_avg: float, v_c: int = V_C, v_a: int = V_A,
                    v_h: int = V_H, heartbeats_per_min: float = 4.0) -> float:
    """Per-peer 1h-Calot maintenance traffic, bit/s.

    Each event reaches every peer in its own (un-aggregated) message and
    is acked: each peer therefore forwards r messages/s and sends r acks/s
    (2n messages per event system-wide), plus 4 unacked heartbeats/min.
    """
    r = event_rate(n, s_avg)
    return r * (v_c + v_a) + heartbeats_per_min * v_h / 60.0


# ---------------------------------------------------------------------------
# OneHop (reconstruction of [17] with optimal topology parameters)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OneHopPoint:
    n: int
    s_avg: float
    f: float
    k_slices: int
    u_units: int
    unit_size: float
    t_big: float
    t_wait: float
    t_small: float
    slice_leader_bps: float
    unit_leader_bps: float
    ordinary_bps: float


def onehop_bandwidth(n: int, s_avg: float, f: float = DEFAULT_F,
                     v_m: int = V_M, v_a: int = V_A, m: int = M_BITS) -> OneHopPoint:
    """OneHop [17] per-role maintenance traffic (bit/s).

    Three-level hierarchy: k slices, u units per slice, units of
    n/(k*u) nodes.  Event flow: detector -> slice leader; slice leaders
    exchange batches every t_big; slice leader -> its u unit leaders every
    t_wait; unit leaders piggyback on keep-alives (period t_small) that
    ordinary nodes exchange with ring neighbours, so an event crosses half
    a unit in ~unit_size*t_small/8 on average (random node sits 0..size/2
    hops from the leader; each hop waits ~t_small/2).

    Topology follows the OneHop design point (k = 5*sqrt(n) slices, u = 5
    units/slice, 1 s keep-alives, 5 s unit dissemination) — the "optimal
    topological parameters" the D1HT paper grants OneHop — with t_big
    stretched to the same staleness budget D1HT uses (§IV-D):

        t_big/2 + t_wait/2 + traverse  <=  f*S_avg/2.

    Slice-leader failures are not charged (paper §VIII assumption).
    """
    r = event_rate(n, s_avg)
    k = max(2, int(math.ceil(5.0 * math.sqrt(n))))
    u = 5
    unit_size = max(1.0, n / (k * u))
    t_small = 1.0
    t_wait = 5.0
    traverse = unit_size * t_small / 8.0
    budget = f * s_avg / 2.0
    # OneHop's published design point aggregates for ~30 s at slice leaders;
    # shrink only if the staleness budget demands it (never below t_wait).
    t_big = max(t_wait, min(30.0, 2.0 * (budget - t_wait / 2.0 - traverse)))
    # slice-leader out: batches to k-1 other leaders (its slice's share of
    # events each) + aggregated batches to its u unit leaders + acks.
    inter = (k - 1) * (v_m + v_a + (r / k) * t_big * m) / t_big
    intra = u * (v_m + v_a + r * t_wait * m) / t_wait
    sl = inter + intra
    # unit leader pushes every event to both ring neighbours via keep-alives
    ul = 2.0 * (v_m + v_a) / t_small + 2.0 * r * m
    # ordinary node forwards each event once along the chain + keep-alives
    ordinary = (v_m + v_a) / t_small + r * m
    return OneHopPoint(n, s_avg, f, k, u, unit_size,
                       t_big, t_wait, t_small, sl, ul, ordinary)


# ---------------------------------------------------------------------------
# Quarantine (§V, §VIII)
# ---------------------------------------------------------------------------

def quarantine_bandwidth(n: int, s_avg: float, volatile_fraction: float,
                         f: float = DEFAULT_F) -> float:
    """Per-peer D1HT traffic with Quarantine (bit/s).

    Sessions shorter than T_q (a ``volatile_fraction`` of all sessions —
    24% for KAD, 31% for Gnutella at T_q=10 min) never enter the ring:
    their joins/leaves are not reported.  The ring holds q = (1-vol)*n
    peers and sees event rate q*r (Fig. 8 captions: q=0.76n / q=0.69n).
    """
    q = 1.0 - volatile_fraction
    n_eff = max(2, int(round(q * n)))
    return d1ht_bandwidth(n_eff, s_avg, f)


def quarantine_reduction(n: int, s_avg: float, volatile_fraction: float,
                         f: float = DEFAULT_F) -> float:
    """Fractional overhead reduction brought by Quarantine (Fig. 8)."""
    base = d1ht_bandwidth(n, s_avg, f)
    quar = quarantine_bandwidth(n, s_avg, volatile_fraction, f)
    return 1.0 - quar / base


# ---------------------------------------------------------------------------
# Convenience sweep used by benchmarks/fig7_analytical.py
# ---------------------------------------------------------------------------

def sweep(n_values, s_avg_minutes, f: float = DEFAULT_F) -> Dict[str, np.ndarray]:
    s = s_avg_minutes * 60.0
    d1 = np.array([d1ht_bandwidth(int(n), s, f) for n in n_values])
    ca = np.array([calot_bandwidth(int(n), s) for n in n_values])
    oh = [onehop_bandwidth(int(n), s, f) for n in n_values]
    return {
        "n": np.asarray(n_values, dtype=np.int64),
        "d1ht_bps": d1,
        "calot_bps": ca,
        "onehop_slice_leader_bps": np.array([o.slice_leader_bps for o in oh]),
        "onehop_unit_leader_bps": np.array([o.unit_leader_bps for o in oh]),
        "onehop_ordinary_bps": np.array([o.ordinary_bps for o in oh]),
    }
