"""Quarantine mechanism (paper §V).

A joining peer is not immediately inserted into the ring: the peers it
contacted (the set S) wait for a Quarantine period T_q before transferring
keys + routing table.  While quarantined, the peer forwards lookups to
*gateway* peers chosen from S (nearest / best provisioned), paying one
extra (nearby) hop.  Volatile peers — sessions shorter than T_q — never
generate join/leave events, cutting maintenance traffic by the volatile
fraction (24% KAD / 31% Gnutella at T_q = 10 min, §VIII).

In the ML runtime this is the admission policy for preemptible/spot
nodes: a node is not handed shards / DP ranks / expert replicas until it
survives T_q (see repro.runtime.membership).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DEFAULT_T_Q = 600.0  # 10 minutes — the paper's "convenient value"

# Fractions of sessions shorter than 10 min, from the studies cited in
# §VIII: 31% of Gnutella sessions [12], 24% of KAD sessions [50].
VOLATILE_FRACTION = {"kad": 0.24, "gnutella": 0.31}


@dataclass
class QuarantineEntry:
    peer_id: int
    addr: Tuple[str, int]
    joined_at: float
    gateways: List[int] = field(default_factory=list)


@dataclass
class QuarantineManager:
    """Tracks quarantined peers and admission decisions.

    ``t_q`` may be fixed or adapted: the paper suggests raising T_q when
    the observed event rate exceeds what the system comfortably handles
    (flash-crowd damping) — implemented by ``on_event_rate``.
    """

    t_q: float = DEFAULT_T_Q
    max_event_rate: Optional[float] = None  # events/s that triggers damping
    damping: float = 2.0                    # T_q multiplier under overload
    base_t_q: float = field(init=False)
    pending: Dict[int, QuarantineEntry] = field(default_factory=dict)
    admitted: int = 0
    rejected_volatile: int = 0

    def __post_init__(self) -> None:
        self.base_t_q = self.t_q

    def enqueue(self, peer_id: int, addr: Tuple[str, int], now: float,
                gateways: List[int]) -> QuarantineEntry:
        e = QuarantineEntry(peer_id, addr, now, list(gateways))
        self.pending[peer_id] = e
        return e

    def withdraw(self, peer_id: int) -> bool:
        """Peer left before T_q elapsed: no event was ever reported."""
        if peer_id in self.pending:
            del self.pending[peer_id]
            self.rejected_volatile += 1
            return True
        return False

    def due(self, now: float) -> List[QuarantineEntry]:
        """Peers whose quarantine has elapsed; they join the ring now
        (their join event is reported from this moment, §V)."""
        out = [e for e in self.pending.values() if now - e.joined_at >= self.t_q]
        for e in out:
            del self.pending[e.peer_id]
            self.admitted += 1
        return out

    def gateway_for(self, peer_id: int) -> Optional[int]:
        e = self.pending.get(peer_id)
        return e.gateways[0] if e and e.gateways else None

    def on_event_rate(self, observed_rate: float) -> None:
        """Flash-crowd damping (§V last paragraph)."""
        if self.max_event_rate is None:
            return
        if observed_rate > self.max_event_rate:
            self.t_q = self.base_t_q * self.damping
        else:
            self.t_q = self.base_t_q


def survival_fraction_heavy_tailed(t_q: float, s_avg: float,
                                   shape: float = 1.5) -> float:
    """Fraction of sessions outliving T_q under a Pareto(shape) session
    distribution with mean s_avg (P2P session lengths are heavy-tailed,
    §V [12][49][50]).  Used when no measured volatile fraction is given.
    """
    if shape <= 1.0:
        raise ValueError("Pareto shape must exceed 1 for a finite mean")
    x_m = s_avg * (shape - 1.0) / shape
    if t_q <= x_m:
        return 1.0
    return (x_m / t_q) ** shape
