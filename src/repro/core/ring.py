"""Consistent-hashing identifier ring (paper §III).

Peers and keys live on the same identifier ring [0 : N], N >> n.  Key IDs
are hashes of key values; peer IDs are hashes of peer IP addresses
(paper uses SHA-1; we expose the hash as a pluggable function and default
to SHA-1 truncated to ``ID_BITS`` bits).

This module is deliberately framework-free (pure Python + numpy) so it can
back both the protocol simulators and the JAX serving/runtime layers.
"""
from __future__ import annotations

import bisect
import hashlib
from dataclasses import dataclass, field
from typing import Iterable, Iterator, List, Optional, Sequence

ID_BITS = 64  # 2**64 ring; plenty for 10^7 peers and keeps IDs in uint64.
RING_SIZE = 1 << ID_BITS


def hash_id(value: bytes | str) -> int:
    """SHA-1 of ``value`` truncated to ID_BITS bits (paper §III, [37])."""
    if isinstance(value, str):
        value = value.encode("utf-8")
    digest = hashlib.sha1(value).digest()
    return int.from_bytes(digest[: ID_BITS // 8], "big")


def peer_id(ip: str, port: int = 0) -> int:
    """Peer ID = hash of its address (paper hashes the IP address)."""
    return hash_id(f"{ip}:{port}" if port else ip)


def key_id(key: bytes | str) -> int:
    return hash_id(key)


def ring_distance(a: int, b: int) -> int:
    """Clockwise distance from a to b on the ring."""
    return (b - a) % RING_SIZE


def in_interval(x: int, lo: int, hi: int, *, inclusive_hi: bool = True) -> bool:
    """True iff x ∈ (lo, hi] (or (lo, hi)) walking clockwise on the ring."""
    d_x = ring_distance(lo, x)
    d_hi = ring_distance(lo, hi)
    if d_x == 0:
        return False
    return d_x <= d_hi if inclusive_hi else d_x < d_hi


@dataclass
class RoutingTable:
    """A full routing table: the sorted set of all known peer IDs.

    Single-hop lookup = find the *successor* of the key ID (the first peer
    clockwise from the key), exactly as in Chord/D1HT.  Stored as a sorted
    list for O(log n) bisect lookups; the Pallas ``ring_lookup`` kernel
    implements the same search vectorized for request batches.
    """

    ids: List[int] = field(default_factory=list)

    def __post_init__(self) -> None:
        self.ids = sorted(set(self.ids))

    # -- membership -------------------------------------------------------
    def add(self, pid: int) -> bool:
        i = bisect.bisect_left(self.ids, pid)
        if i < len(self.ids) and self.ids[i] == pid:
            return False
        self.ids.insert(i, pid)
        return True

    def remove(self, pid: int) -> bool:
        i = bisect.bisect_left(self.ids, pid)
        if i < len(self.ids) and self.ids[i] == pid:
            del self.ids[i]
            return True
        return False

    def __contains__(self, pid: int) -> bool:
        i = bisect.bisect_left(self.ids, pid)
        return i < len(self.ids) and self.ids[i] == pid

    def __len__(self) -> int:
        return len(self.ids)

    def __iter__(self) -> Iterator[int]:
        return iter(self.ids)

    # -- ring navigation ---------------------------------------------------
    def successor_of(self, x: int) -> int:
        """First peer clockwise from x (the owner of key x)."""
        if not self.ids:
            raise LookupError("empty routing table")
        i = bisect.bisect_left(self.ids, x)
        return self.ids[i % len(self.ids)]

    def predecessor_of(self, x: int) -> int:
        if not self.ids:
            raise LookupError("empty routing table")
        i = bisect.bisect_left(self.ids, x)
        return self.ids[(i - 1) % len(self.ids)]

    def succ(self, p: int, i: int = 1) -> int:
        """succ(p, i): the i-th successor of peer p (paper §IV). succ(p,0)=p."""
        j = bisect.bisect_left(self.ids, p)
        if j >= len(self.ids) or self.ids[j] != p:
            raise LookupError(f"peer {p} not in table")
        return self.ids[(j + i) % len(self.ids)]

    def pred(self, p: int, i: int = 1) -> int:
        return self.succ(p, -i)

    def stretch(self, p: int, k: int) -> List[int]:
        """stretch(p,k) = {succ(p,i) | 0 <= i <= k} (paper §IV)."""
        n = len(self.ids)
        return [self.succ(p, i) for i in range(min(k, n - 1) + 1)]

    def owner(self, key: bytes | str) -> int:
        return self.successor_of(key_id(key))


def build_ring(num_peers: int, *, seed: int = 0) -> RoutingTable:
    """Deterministic ring of ``num_peers`` synthetic peers (10.x.x.x IPs)."""
    ids = []
    i = 0
    seen = set()
    while len(ids) < num_peers:
        ip = f"10.{(seed + i) >> 16 & 255}.{(seed + i) >> 8 & 255}.{(seed + i) & 255}"
        pid = peer_id(ip, port=1000 + ((seed + i) >> 24))
        if pid not in seen:
            seen.add(pid)
            ids.append(pid)
        i += 1
    return RoutingTable(ids)
