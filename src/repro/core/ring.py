"""Consistent-hashing identifier ring (paper §III).

Peers and keys live on the same identifier ring [0 : N], N >> n.  Key IDs
are hashes of key values; peer IDs are hashes of peer IP addresses
(paper uses SHA-1; we expose the hash as a pluggable function and default
to SHA-1 truncated to ``ID_BITS`` bits).

This module is deliberately framework-free (pure Python + numpy) so it can
back both the protocol simulators and the JAX serving/runtime layers.

``RoutingTable`` is a thin compatibility facade over the shared
``RingState`` core (DESIGN.md §2): the DES peers, the UDP node, and the
runtime all mutate/read the SAME versioned sorted-array representation
that the serving router uploads to the device, so there is exactly one
routing-table implementation in the system.
"""
from __future__ import annotations

import hashlib
from typing import Iterable, Iterator, List, Optional

from .ringstate import RingState

ID_BITS = 64  # 2**64 ring; plenty for 10^7 peers and keeps IDs in uint64.
RING_SIZE = 1 << ID_BITS


def hash_id(value: bytes | str) -> int:
    """SHA-1 of ``value`` truncated to ID_BITS bits (paper §III, [37])."""
    if isinstance(value, str):
        value = value.encode("utf-8")
    digest = hashlib.sha1(value).digest()
    return int.from_bytes(digest[: ID_BITS // 8], "big")


def peer_id(ip: str, port: int = 0) -> int:
    """Peer ID = hash of its address (paper hashes the IP address)."""
    return hash_id(f"{ip}:{port}" if port else ip)


def key_id(key: bytes | str) -> int:
    return hash_id(key)


def ring_distance(a: int, b: int) -> int:
    """Clockwise distance from a to b on the ring."""
    return (b - a) % RING_SIZE


def in_interval(x: int, lo: int, hi: int, *, inclusive_hi: bool = True) -> bool:
    """True iff x ∈ (lo, hi] (or (lo, hi)) walking clockwise on the ring."""
    d_x = ring_distance(lo, x)
    d_hi = ring_distance(lo, hi)
    if d_x == 0:
        return False
    return d_x <= d_hi if inclusive_hi else d_x < d_hi


class RoutingTable:
    """A full routing table: the sorted set of all known peer IDs.

    Single-hop lookup = find the *successor* of the key ID (the first peer
    clockwise from the key), exactly as in Chord/D1HT.  This class is a
    compatibility facade over a shared ``RingState`` (sorted uint64
    buffers + version + quarantine mask); the Pallas ``ring_lookup64``
    kernel runs the same search vectorized on-device from the state's
    cached hi/lo word-split table.
    """

    __slots__ = ("state", "_ids_cache")

    def __init__(self, ids: Optional[Iterable[int]] = None, *,
                 state: Optional[RingState] = None):
        self.state = state if state is not None else RingState(ids or ())
        self._ids_cache: tuple = (-1, [])

    @property
    def ids(self) -> List[int]:
        """Sorted active peer IDs (quarantined peers are excluded from
        ownership, paper §V), as Python ints for facade compatibility.
        Cached per active_version: DES hot paths (e.g. Calot stretch
        counting) read this once per message, and boxing the numpy view
        every access would be O(n) per call."""
        ver, lst = self._ids_cache
        if ver != self.state.active_version:
            lst = self.state.active_ids_list()
            self._ids_cache = (self.state.active_version, lst)
        return lst

    # -- membership -------------------------------------------------------
    def add(self, pid: int) -> bool:
        return self.state.add(pid)

    def remove(self, pid: int) -> bool:
        return self.state.remove(pid)

    def __contains__(self, pid: int) -> bool:
        return pid in self.state

    def __len__(self) -> int:
        return len(self.state)

    def __iter__(self) -> Iterator[int]:
        return iter(self.state)

    def __eq__(self, other: object) -> bool:
        if isinstance(other, RoutingTable):
            return self.ids == other.ids
        return NotImplemented

    def __repr__(self) -> str:
        return f"RoutingTable(n={len(self)}, version={self.state.version})"

    # -- ring navigation ---------------------------------------------------
    def successor_of(self, x: int) -> int:
        """First peer clockwise from x (the owner of key x)."""
        return self.state.successor_of(x)

    def predecessor_of(self, x: int) -> int:
        return self.state.predecessor_of(x)

    def succ(self, p: int, i: int = 1) -> int:
        """succ(p, i): the i-th successor of peer p (paper §IV). succ(p,0)=p."""
        return self.state.succ(p, i)

    def pred(self, p: int, i: int = 1) -> int:
        return self.state.succ(p, -i)

    def stretch(self, p: int, k: int) -> List[int]:
        """stretch(p,k) = {succ(p,i) | 0 <= i <= k} (paper §IV)."""
        return self.state.stretch(p, k)

    def owner(self, key: bytes | str) -> int:
        return self.state.successor_of(key_id(key))


def build_ring(num_peers: int, *, seed: int = 0) -> RoutingTable:
    """Deterministic ring of ``num_peers`` synthetic peers (10.x.x.x IPs)."""
    ids = []
    i = 0
    seen = set()
    while len(ids) < num_peers:
        ip = f"10.{(seed + i) >> 16 & 255}.{(seed + i) >> 8 & 255}.{(seed + i) & 255}"
        pid = peer_id(ip, port=1000 + ((seed + i) >> 24))
        if pid not in seen:
            seen.add(pid)
            ids.append(pid)
        i += 1
    return RoutingTable(ids)
