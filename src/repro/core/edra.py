"""EDRA — Event Detection and Report Algorithm (paper §IV).

This module contains the *pure* algorithmic pieces shared by the protocol
implementations (repro.dht), the vectorized simulator (core.jax_sim), the
analysis (core.analysis) and the TPU collective mapping
(repro.sharding.collectives):

  * the dissemination tree induced by Rules 1-8 over ring offsets,
  * per-peer acknowledge TTL / hop-depth / parent,
  * the per-interval message-emission logic (Rules 3-4) as a reusable
    ``EventBuffer`` state machine.

Tree structure
--------------
Let the *reporter* P (successor of the peer suffering the event, Rule 6)
sit at offset 0 and index every other peer by its clockwise offset i from
P.  The EDRA rules induce a binomial tree:

  * offset 0 acknowledges with TTL = rho (Rule 6);
  * offset i > 0 is reached exactly once, acknowledging with
    TTL = trailing_zeros(i)  (the lowest set bit of i);
  * its parent in the tree is offset i & (i-1) (clear lowest set bit);
  * its hop depth (number of Theta intervals after the reporter's) is
    popcount(i).

Rule 8 truncates the tree at the ring size: a peer at offset i forwards a
message with TTL = l to offset i + 2**l only if that offset is < n
(otherwise the target would wrap past the reporter and receive the event
twice).  Theorem 1 (exactly-once delivery, average ack time <= rho*Theta/2)
and Theorem 2 (|S| = 2**(rho-l)) are direct consequences and are verified
against this module by tests/test_edra_theorems.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from .tuning import rho as _rho


# ---------------------------------------------------------------------------
# Dissemination tree (vectorized, numpy)
# ---------------------------------------------------------------------------

def ack_ttl(offsets: np.ndarray, n: int) -> np.ndarray:
    """TTL with which the peer at each ring offset acknowledges the event.

    offset 0 (the reporter) acknowledges with TTL = rho (Rule 6); offset
    i > 0 acknowledges with TTL = trailing_zeros(i) (Rules 3+7).
    """
    offsets = np.asarray(offsets, dtype=np.uint64)
    p = _rho(n)
    # trailing zeros via de Bruijn-free approach: popcount((i & -i) - 1)
    i = offsets.astype(np.int64)
    lsb = i & -i
    tz = popcount_np((lsb - 1).astype(np.uint64))
    return np.where(offsets == 0, p, tz).astype(np.int32)


def ack_depth(offsets: np.ndarray) -> np.ndarray:
    """Number of Theta-interval hops from the reporter (popcount)."""
    return popcount_np(np.asarray(offsets, dtype=np.uint64)).astype(np.int32)


def parent_offset(offsets: np.ndarray) -> np.ndarray:
    """Tree parent: clear the lowest set bit. Parent of 0 is 0."""
    i = np.asarray(offsets, dtype=np.int64)
    return (i & (i - 1)).astype(np.int64)


def popcount_np(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.uint64)
    c = np.zeros(x.shape, dtype=np.int64)
    while True:
        nz = x != 0
        if not nz.any():
            break
        c += (x & np.uint64(1)).astype(np.int64)
        x = x >> np.uint64(1)
    return c


def forward_targets(offset: int, ttl: int, n: int) -> List[Tuple[int, int]]:
    """(target_offset, message_ttl) pairs a peer emits for an event.

    A peer that acknowledged an event with TTL = ``ttl`` includes it in all
    messages with TTL < ttl (Rule 3); the message with TTL = l goes to
    succ(p, 2**l) (Rule 7); targets wrapping past the reporter are
    discharged (Rule 8).  Events acknowledged with TTL = 0 are not
    forwarded (Rule 3).
    """
    out = []
    for l in range(ttl - 1, -1, -1):
        tgt = offset + (1 << l)
        if tgt < n:  # Rule 8
            out.append((tgt, l))
    return out


def dissemination_tree(n: int) -> Dict[str, np.ndarray]:
    """Full tree for a ring of n peers: ttl, depth, parent per offset."""
    offs = np.arange(n, dtype=np.uint64)
    return {
        "offset": offs.astype(np.int64),
        "ttl": ack_ttl(offs, n),
        "depth": ack_depth(offs),
        "parent": parent_offset(offs),
    }


def acknowledged_exactly_once(n: int) -> bool:
    """Theorem 1 structural check: every offset reached exactly once."""
    tree = dissemination_tree(n)
    reached = np.zeros(n, dtype=np.int64)
    reached[0] = 1  # reporter
    for off, ttl in zip(tree["offset"], tree["ttl"]):
        if off == 0:
            ttl = tree["ttl"][0]
        for tgt, _l in forward_targets(int(off), int(ttl), n):
            reached[tgt] += 1
    return bool((reached == 1).all())


# ---------------------------------------------------------------------------
# Event buffering state machine (Rules 1-4, 6, 8) — used by protocol peers
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Event:
    """A membership event: a peer joined or left (paper footnote 3)."""

    subject_id: int          # ring ID of the peer that joined/left
    kind: str                # "join" | "leave"
    addr: Tuple[str, int] = ("0.0.0.0", 0)
    seq: int = 0             # tiebreaker for idempotence

    @property
    def wire_bits(self) -> int:
        """m in Eq IV.5: 32 bits for default-port peers, 48 otherwise."""
        return 32 if self.addr[1] in (0, 1117) else 48

    def dedup_key(self) -> Tuple[int, str, int]:
        return (self.subject_id, self.kind, self.seq)


@dataclass
class EventBuffer:
    """Per-peer EDRA buffer: events acknowledged during the current Theta
    interval, tagged with the TTL they were acknowledged with (Rule 2/6).

    At the end of the interval, ``flush`` emits the per-TTL message
    payloads per Rules 1-4 (message M(l) carries every event acknowledged
    with TTL > l; M(0) is always sent; M(l>0) only if non-empty).
    """

    rho: int
    acked: Dict[Tuple[int, str, int], Tuple[Event, int]] = field(default_factory=dict)

    def acknowledge(self, event: Event, ttl: int) -> bool:
        """Record an event acknowledged with ``ttl``. Returns False if the
        event was already acknowledged (duplicate suppression — under
        Theorem 1 duplicates only arise from retransmissions/stabilization).
        """
        k = event.dedup_key()
        if k in self.acked:
            return False
        self.acked[k] = (event, ttl)
        return True

    def __len__(self) -> int:
        return len(self.acked)

    def flush(self) -> Dict[int, List[Event]]:
        """Events to include per outgoing message TTL for this interval.

        Returns {l: [events]} for l in [0, rho): message M(l) carries all
        events acknowledged with TTL > l (Rule 3).  The caller applies
        Rule 8 (range discharge) because it owns the routing table, and
        Rule 4 (M(0) always sent; M(l>0) iff payload non-empty).
        """
        out: Dict[int, List[Event]] = {l: [] for l in range(self.rho)}
        for ev, ttl in self.acked.values():
            for l in range(min(ttl, self.rho)):
                out[l].append(ev)
        self.acked.clear()
        return out
