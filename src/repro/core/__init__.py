"""D1HT core — the paper's primary contribution.

  ring       consistent-hashing identifier ring + full routing tables
  edra       EDRA rules/tree (Rules 1-8, Theorems 1-2 machinery)
  tuning     Eqs III.1, IV.1-IV.4 (Theta/E/T_avg self-tuning)
  analysis   Eqs IV.5-IV.7 + 1h-Calot (VII.1) + OneHop + Quarantine models
  quarantine Quarantine admission mechanism (§V)
  ringstate  unified versioned device-resident routing table (DESIGN.md)
  churn      shared §VII churn-run shapes (DES + vectorized plane)
  jax_sim    vectorized JAX protocol simulator (claims C1/C5 at scale)
"""
from . import analysis, churn, edra, quarantine, ring, ringstate, tuning
from .churn import ChurnConfig, ChurnResult, SessionDist
from .edra import Event, EventBuffer, dissemination_tree
from .quarantine import QuarantineManager
from .ring import RoutingTable, build_ring, hash_id, key_id, peer_id
from .ringstate import OwnerDiff, RingState
from .tuning import EdraParams

__all__ = [
    "analysis", "churn", "edra", "quarantine", "ring", "ringstate", "tuning",
    "ChurnConfig", "ChurnResult", "SessionDist",
    "Event", "EventBuffer", "dissemination_tree", "QuarantineManager",
    "OwnerDiff", "RingState", "RoutingTable", "build_ring", "hash_id", "key_id",
    "peer_id", "EdraParams",
]
