"""Cluster membership on the D1HT ring (the paper's technique as the ML
control plane).

Each training/serving host is a D1HT peer; membership events (node joins,
failures, preemptions) disseminate via EDRA with the paper's Theta tuning,
so every host can make placement decisions from its OWN full routing
table with bounded staleness (< f of lookups see a stale view) and zero
central directory — the property the paper proves scales past directory
servers (§VII-D).

Quarantine (paper §V) doubles as the spot/preemptible admission policy:
a node gets no shards, DP rank, or expert replicas until it has survived
T_q — exactly the paper's defense against volatile peers, repurposed.

This module is deterministic and host-local (events are injected by the
surrounding orchestration or by the DES in tests); the asyncio/UDP D1HT
node in repro.dht drives it live in examples/dht_cluster.py.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.edra import Event
from repro.core.quarantine import QuarantineManager
from repro.core.ring import RoutingTable, peer_id
from repro.core.ringstate import RingState
from repro.core.tuning import EdraParams

from .placement import PlacementPolicy, RingSuccessor


@dataclass
class NodeInfo:
    node_id: int
    addr: Tuple[str, int]
    joined_at: float
    capabilities: Dict[str, float] = field(default_factory=dict)


class Membership:
    """Full-routing-table membership view with quarantine admission."""

    #: sliding event-rate window (seconds) and retained-sample bound for
    #: the §IV-D retune — see ``_retune``
    RATE_HORIZON = 300.0
    RATE_MAX_SAMPLES = 4096

    def __init__(self, *, s_avg: float = 3600.0, f: float = 0.01,
                 t_q: float = 600.0, now: Callable[[], float] = time.monotonic,
                 policy: Optional[PlacementPolicy] = None):
        self.now = now
        # placement policy for §V gateway selection (and, via the serve
        # plane, every replica-set ranking): default ring-successor order
        # is bit-identical to the legacy active_ids()[:2] pick
        self.policy = policy if policy is not None else RingSuccessor()
        self._event_times: deque = deque(maxlen=self.RATE_MAX_SAMPLES)
        # ONE RingState backs the facade table, the placement layer, and
        # the serving router's device-resident lookup table (DESIGN.md §4).
        self.ring_state = RingState()
        self.table = RoutingTable(state=self.ring_state)
        self.nodes: Dict[int, NodeInfo] = {}
        self.quarantine = QuarantineManager(t_q=t_q)
        self.params = EdraParams.derive(2, s_avg, f)
        self._listeners: List[Callable[[Event], None]] = []
        self._events_seen = 0

    # -- event intake (from the D1HT peer / DES / orchestrator) -------------
    def on_event(self, ev: Event) -> None:
        self._events_seen += 1
        self._event_times.append(self.now())
        if ev.kind == "join":
            self.table.add(ev.subject_id)
            self.nodes.setdefault(
                ev.subject_id,
                NodeInfo(ev.subject_id, ev.addr, self.now()))
        else:
            self.table.remove(ev.subject_id)
            self.nodes.pop(ev.subject_id, None)
        self._retune()
        for fn in self._listeners:
            fn(ev)

    def subscribe(self, fn: Callable[[Event], None]) -> None:
        self._listeners.append(fn)

    def _retune(self) -> None:
        """§IV-D self-organization: re-derive Theta from the locally
        observed event rate — no coordination required.

        The rate is estimated over a SLIDING window (the last
        ``RATE_HORIZON`` seconds of event timestamps, bounded by
        ``RATE_MAX_SAMPLES``), not over the view's whole lifetime: a
        lifetime-anchored window decays toward 0 on a long-lived view,
        so a churn burst after a quiet day barely moved Theta — the
        opposite of what §IV-D needs (the estimate must track the
        CURRENT rate so Theta shrinks when churn spikes).  The span of
        the retained samples is clamped below by 1 s (a same-instant
        burst still yields a finite, aggressive rate) and above by the
        horizon; samples older than the horizon are dropped."""
        now = self.now()
        while self._event_times and now - self._event_times[0] > self.RATE_HORIZON:
            self._event_times.popleft()
        if not self._event_times:
            return
        n = max(len(self.table), 2)
        span = now - self._event_times[0]
        window = min(max(span, 1.0), self.RATE_HORIZON)
        r = len(self._event_times) / window
        if r > 0:
            self.params = self.params.retune(n, r)

    # -- joins with quarantine ------------------------------------------------
    def request_join(self, host: str, port: int,
                     preemptible: bool = False) -> int:
        nid = peer_id(host, port)
        if preemptible:
            # policy-ranked gateway pick (§V): under LatencyAware the
            # joiner proxies through its lowest-RTT active peers instead
            # of whoever happens to sort first in the id space
            gateways = self.policy.gateways(self.ring_state, 2, origin=nid)
            # (re-)enqueue: a node restarting before T_q elapsed serves a
            # FRESH quarantine from now (§V — the old incarnation's
            # progress toward admission died with it)
            self.quarantine.enqueue(nid, (host, port), self.now(), gateways)
            if nid in self.table:
                # an ACTIVE member restarting as a spot instance: re-mask
                # through quarantine_member so listeners migrate its
                # owned state (a bare flag flip would orphan it)
                self.quarantine_member(nid)
            elif not self.ring_state.is_quarantined(nid):
                # tracked in the shared state but masked out of ownership
                # until T_q elapses (paper §V): gateways proxy its lookups.
                self.ring_state.add(nid, quarantined=True)
            # else: restart while already quarantine-masked — the tracked
            # masked slot is reused as-is; re-adding would rely on
            # RingState.add treating a same-flag duplicate as a no-op,
            # and any drift there would corrupt the sorted table.
        else:
            self.admit(nid, (host, port))
        return nid

    def admit(self, nid: int, addr: Tuple[str, int]) -> None:
        self.on_event(Event(subject_id=nid, kind="join", addr=addr,
                            seq=self._events_seen + 1))

    def poll_quarantine(self) -> List[int]:
        admitted = []
        for entry in self.quarantine.due(self.now()):
            self.admit(entry.peer_id, entry.addr)
            admitted.append(entry.peer_id)
        return admitted

    def fail(self, nid: int) -> None:
        """Rule-5 style failure: detected by heartbeat silence."""
        if self.quarantine.withdraw(nid) and nid not in self.nodes:
            # volatile peer: never admitted, no event was ever reported,
            # so none is reported now — just drop its masked entry
            self.ring_state.remove(nid)
            return
        # an active member, OR a member re-masked under quarantine — its
        # original join WAS disseminated, so its death must be too (the
        # facade's membership check sees only the active view)
        if nid in self.table or self.ring_state.is_quarantined(nid):
            self.on_event(Event(subject_id=nid, kind="leave",
                                seq=self._events_seen + 1))

    def quarantine_member(self, nid: int) -> bool:
        """Move an ACTIVE member back under the §V mask (straggler /
        flash-crowd damping): it stops owning keys and sessions but stays
        tracked and may keep proxying lookups as a gateway.  No EDRA
        leave event is disseminated — the node did not leave — but local
        listeners (the serve plane) are told so owned state migrates."""
        if not self.ring_state.set_quarantined(nid, True):
            return False
        for fn in self._listeners:
            fn(Event(subject_id=nid, kind="quarantine",
                     seq=self._events_seen + 1))
        return True

    # -- views ---------------------------------------------------------------------
    def size(self) -> int:
        return len(self.table)

    def members(self) -> List[int]:
        return list(self.table.ids)

    def owner_of(self, key: bytes | str) -> int:
        return self.table.owner(key)
