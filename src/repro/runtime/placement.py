"""Consistent-hash placement of work onto ring members (paper §III) and
the pluggable placement-policy layer (DESIGN.md §13).

Everything that must be owned by exactly one node — KV-cache sessions,
MoE expert replicas, data-pipeline file shards, checkpoint shards — is a
*key* on the D1HT ring; its owner is the key's successor, resolved with a
single local lookup against the full routing table (the paper's whole
point: one hop, no directory).  The Pallas ``ring_lookup`` kernel batches
these lookups on-device for the serving router.

Churn behavior inherits consistent hashing's guarantee: a membership
event remaps only the keys in the arc adjacent to the event (~K/n keys),
so elastic re-meshing moves the minimum state.

**PlacementPolicy** unifies what used to be four divergent ad-hoc
"walk the next r ring successors" loops — session admission and
prefix-affinity routing (``ServeCluster.submit``), migration and
stranded-session spill (``ServeCluster._handoff``), block replica
selection (``dht.data.BlockStore``), and §V quarantine-gateway picks
(``Membership.request_join``).  A policy receives the ring's
``ReplicaView`` (the r-way successor list plus candidate metadata) and a
``Topology`` (per-node region/coordinates with an RTT estimator) and
returns a RANKING of the candidates.  Two invariants keep every
consumer correct under any policy:

  * **Set-preserving.**  ``rank`` returns a permutation of the view's
    ids, never a different set: the successor list stays the canonical,
    independently re-derivable location of a key's replicas, so readers
    and repair find the data without consulting the writer's policy.
  * **Deterministic.**  Ranking is a pure function of (view, topology,
    origin, prefer) — two nodes with the same routing table agree on
    placement with zero coordination, exactly the property the paper's
    full-table design buys.

``RingSuccessor`` ranks in ring order — bit-identical to the
pre-refactor loops, and the regression oracle for them.
``LatencyAware`` ranks replica-set members by estimated RTT from the
request's origin, with an affinity hysteresis so a session placed on a
nearby node is not bounced to a marginally-nearer one on every churn
batch (movement stays owner_diff-driven: only sessions whose arcs
changed are even re-ranked).
"""
from __future__ import annotations

from abc import ABC, abstractmethod
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.ring import RoutingTable, hash_id
from repro.core.ringstate import ReplicaView, RingState


@dataclass
class Placement:
    table: Union[RoutingTable, RingState]

    def __post_init__(self) -> None:
        # accept a raw RingState and wrap it, so Membership / the router
        # and Placement always consume the same shared state object
        if isinstance(self.table, RingState):
            self.table = RoutingTable(state=self.table)

    @property
    def state(self) -> RingState:
        return self.table.state

    # -- generic key ownership ------------------------------------------------
    def owner(self, key: str) -> int:
        return self.table.owner(key)

    def owners(self, keys: Sequence[str]) -> List[int]:
        return [self.table.owner(k) for k in keys]

    def replica_owners(self, key: str, r: int) -> List[int]:
        """Successor-list replica group for r-way replicated keys
        (checkpoint shards, hot KV sessions): the owner plus the next
        r-1 distinct active peers clockwise."""
        return self.state.replica_set(key, r)

    # -- MoE experts ---------------------------------------------------------------
    def expert_assignment(self, num_experts: int, model_shards: int,
                          salt: str = "") -> np.ndarray:
        """Permutation mapping expert e -> EP shard, derived from the ring.

        Experts are placed on the ring by hash; each lands on its successor
        member, and members are binned round-robin into the ``model_shards``
        EP groups by ring order.  On membership change only the experts in
        the affected arc migrate (elastic EP).  Returns perm (E,) with
        perm[e] = shard index; applied as a gather on the stacked expert
        weights before EP sharding.
        """
        members = self.table.ids
        n = len(members)
        if n == 0:
            return np.arange(num_experts) % model_shards
        shard_of_member = {m: i % model_shards for i, m in enumerate(members)}
        out = np.empty((num_experts,), np.int64)
        for e in range(num_experts):
            m = self.table.successor_of(hash_id(f"expert/{salt}/{e}"))
            out[e] = shard_of_member[m]
        return out

    def expert_permutation(self, num_experts: int, model_shards: int,
                           salt: str = "") -> np.ndarray:
        """Stable permutation grouping experts by their assigned shard
        (experts_per_shard contiguity for the EP weight layout)."""
        assign = self.expert_assignment(num_experts, model_shards, salt)
        return np.argsort(assign, kind="stable")

    # -- serving sessions ---------------------------------------------------------
    def session_owner(self, session_id: str) -> int:
        return self.owner(f"session/{session_id}")

    def balance_stats(self, num_keys: int = 4096) -> Dict[str, float]:
        counts: Dict[int, int] = {}
        for i in range(num_keys):
            o = self.owner(f"probe/{i}")
            counts[o] = counts.get(o, 0) + 1
        vals = np.array(list(counts.values()), np.float64)
        return {"mean": float(vals.mean()), "max": float(vals.max()),
                "cv": float(vals.std() / max(vals.mean(), 1e-9))}


# ---------------------------------------------------------------------------
# Topology: per-node region placement + RTT estimation
# ---------------------------------------------------------------------------

_MIX = np.uint64(0x9E3779B97F4A7C15)    # splitmix64 odd constant


class Topology:
    """Where each ring node physically sits, and what talking to it
    costs.

    Regions live on an abstract 2-D "millisecond plane": the Euclidean
    distance between two regions' coordinates IS the estimated one-way
    inter-region delay in ms (PlanetLab-flavored: tens of ms between
    datacenters).  Within a region the one-way floor is
    ``intra_rtt_ms / 2`` — the LanDelay regime.

    Nodes are mapped to regions either explicitly (``place``) or, by
    default, via a deterministic splitmix64 hash of the node id — so a
    million-peer ring gets a uniform region mix with zero per-node
    state, and every host derives the SAME map from its routing table
    (the policy-determinism requirement).

    The estimator is deterministic (no jitter): it ranks placements.
    The stochastic twin — actual per-datagram delays — is
    ``repro.dht.des.GeoDelay``, which samples around the same per-pair
    medians, so what the policy optimizes is what the DES measures.
    """

    def __init__(self, regions: Dict[str, Tuple[float, float]], *,
                 intra_rtt_ms: float = 0.2):
        if not regions:
            raise ValueError("topology needs at least one region")
        self.intra_rtt_ms = float(intra_rtt_ms)
        self.names: List[str] = list(regions)
        self._index = {nm: i for i, nm in enumerate(self.names)}
        coords = np.asarray([regions[nm] for nm in self.names], np.float64)
        d = coords[:, None, :] - coords[None, :, :]
        self._oneway_ms = np.sqrt((d * d).sum(-1))
        np.fill_diagonal(self._oneway_ms, self.intra_rtt_ms / 2.0)
        self._pinned: Dict[int, int] = {}
        # sorted pinned-id arrays, rebuilt lazily for vectorized overrides
        self._pin_keys: Optional[np.ndarray] = None
        self._pin_vals: Optional[np.ndarray] = None

    # -- construction helpers -------------------------------------------------
    @classmethod
    def single_region(cls, name: str = "dc0", *,
                      intra_rtt_ms: float = 0.14) -> "Topology":
        """One datacenter — the LAN environment (§VII-C/D: 0.14 ms RTT).
        Every pair is intra-region, so LatencyAware degenerates to ring
        order and the LAN leg of the tradeoff curve is the null test."""
        return cls({name: (0.0, 0.0)}, intra_rtt_ms=intra_rtt_ms)

    @classmethod
    def multi_dc(cls, k: int = 4, *, intra_rtt_ms: float = 0.2) -> "Topology":
        """PlanetLab-flavored WAN: up to 6 named DCs whose pairwise
        one-way delays span ~18–95 ms (the §VII-B regime the WanDelay
        lognormal models in aggregate)."""
        catalog: List[Tuple[str, Tuple[float, float]]] = [
            ("us-east", (0.0, 0.0)),
            ("us-west", (35.0, 0.0)),
            ("eu-west", (45.0, 38.0)),
            ("ap-south", (95.0, 20.0)),
            ("sa-east", (60.0, -55.0)),
            ("ap-north", (80.0, 55.0)),
        ]
        if not 1 <= k <= len(catalog):
            raise ValueError(f"k must be in [1, {len(catalog)}]")
        return cls(dict(catalog[:k]), intra_rtt_ms=intra_rtt_ms)

    # -- node -> region -------------------------------------------------------
    def place(self, node: int, region: str) -> None:
        """Pin a node to a region (overrides the hash assignment)."""
        self._pinned[int(node)] = self._index[region]
        self._pin_keys = self._pin_vals = None

    def region_index(self, ids) -> np.ndarray:
        """(Q,) node ids -> (Q,) region indices: splitmix64-hashed onto
        the region list, with pinned overrides applied vectorized."""
        ids = np.atleast_1d(np.asarray(ids, np.uint64))
        z = ids * _MIX                     # uint64 wraparound is the mix
        z = z ^ (z >> np.uint64(31))
        out = (z % np.uint64(len(self.names))).astype(np.int64)
        if self._pinned:
            if self._pin_keys is None:
                pk = np.fromiter(self._pinned, np.uint64, len(self._pinned))
                order = np.argsort(pk)
                self._pin_keys = pk[order]
                self._pin_vals = np.fromiter(
                    self._pinned.values(), np.int64, len(self._pinned))[order]
            pos = np.searchsorted(self._pin_keys, ids)
            pos = np.minimum(pos, self._pin_keys.size - 1)
            hit = self._pin_keys[pos] == ids
            out[hit] = self._pin_vals[pos[hit]]
        return out

    def region_of(self, node: int) -> str:
        return self.names[int(self.region_index(node)[0])]

    def _origin_index(self, origin) -> int:
        """Region index of an origin given as a region name or node id."""
        if isinstance(origin, str):
            return self._index[origin]
        return int(self.region_index(origin)[0])

    # -- RTT estimation -------------------------------------------------------
    def one_way_ms(self, a, b) -> float:
        return float(self._oneway_ms[self._origin_index(a),
                                     self._origin_index(b)])

    def rtt_ms(self, a, b) -> float:
        return 2.0 * self.one_way_ms(a, b)

    def rtt_ms_many(self, origin, ids) -> np.ndarray:
        """(Q,) node ids -> (Q,) estimated RTT ms from ``origin`` (a node
        id or a region name) — the vectorized ranking input."""
        oi = self._origin_index(origin)
        return 2.0 * self._oneway_ms[oi, self.region_index(ids)]


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------

class PlacementPolicy(ABC):
    """Ranks a key's replica-set candidates for one placement decision.

    Consumers walk the ranked list first-fit (capacity, residency,
    warm-prefix preference stay THEIR concerns); the policy only orders.
    ``origin`` is where the request physically comes from (a node id or
    a Topology region name; None = no locality information), ``prefer``
    is the candidate currently holding the state, if any — policies may
    apply affinity hysteresis to it, and must ignore it when it is not
    in the candidate set.
    """

    name: str = "abstract"
    topology: Optional[Topology] = None

    @abstractmethod
    def rank(self, view: ReplicaView, *, origin=None,
             prefer: Optional[int] = None) -> List[int]:
        """Permutation of ``view.ids`` in descending placement priority."""

    def replica_group(self, state: RingState, key, r: int, *, origin=None,
                      prefer: Optional[int] = None) -> List[int]:
        """Ranked r-way replica group for ``key`` — the drop-in
        replacement for the old ``state.replica_set`` call sites."""
        return self.rank(state.replica_view(key, r), origin=origin,
                         prefer=prefer)

    def gateways(self, state: RingState, k: int, *, origin=None) -> List[int]:
        """§V quarantine gateways for a joining peer: the k active peers
        that will proxy its lookups while it sits out T_q.  Base policy:
        the first k of the active view (bit-identical to the legacy
        ``active_ids()[:2]`` pick)."""
        return [int(x) for x in state.active_ids()[:k]]


class RingSuccessor(PlacementPolicy):
    """Ring-successor order — exactly the pre-policy behavior of every
    call site, kept as the regression oracle: with this policy the serve
    plane, data plane and gateway picks are bit-identical to the
    pre-refactor ad-hoc loops (asserted by tests/test_placement.py)."""

    name = "ring_successor"

    def __init__(self, topology: Optional[Topology] = None):
        # ranking never consults it, but attaching a topology lets the
        # serve plane METER cross-region placements for the baseline
        # (examples/geo_serve.py compares the two policies' counters)
        self.topology = topology

    def rank(self, view: ReplicaView, *, origin=None,
             prefer: Optional[int] = None) -> List[int]:
        return list(view.ids)


class LatencyAware(PlacementPolicy):
    """Prefer low-RTT members of the replica set (locality/proximity-
    aware placement in the survey's taxonomy — the replica SET is fixed
    by the ring; the policy picks *which member* serves, stores first,
    or proxies).

    Ties — and everything within ``tie_ms`` of the best RTT — break by
    ring rank, so intra-region choices stay deterministic and LAN
    topologies degenerate to exact ``RingSuccessor`` behavior.

    Affinity: when ``prefer`` (the current holder) is in the candidate
    set, its effective RTT is discounted by ``affinity_ms`` — a session
    placed on a nearby node is not bounced to a marginally-nearer one by
    every churn batch.  Affinity *survives* churn structurally: the
    serve plane re-ranks only ``owner_diff``-affected sessions, and an
    unaffected session's view (hence its ranking) is unchanged.
    """

    name = "latency_aware"

    def __init__(self, topology: Topology, *, affinity_ms: float = 5.0,
                 tie_ms: float = 0.5):
        self.topology = topology
        self.affinity_ms = float(affinity_ms)
        self.tie_ms = float(tie_ms)

    def _order(self, ids: np.ndarray, rtt: np.ndarray) -> List[int]:
        # quantize to tie_ms buckets so near-equal RTTs fall back to
        # ring order (stable lexsort on the original index)
        q = np.floor(rtt / max(self.tie_ms, 1e-9)).astype(np.int64)
        order = np.lexsort((np.arange(ids.size), q))
        return [int(ids[i]) for i in order]

    def rank(self, view: ReplicaView, *, origin=None,
             prefer: Optional[int] = None) -> List[int]:
        if origin is None or len(view.ids) <= 1:
            return list(view.ids)
        ids = np.fromiter(view.ids, np.uint64, len(view.ids))
        rtt = self.topology.rtt_ms_many(origin, ids)
        if prefer is not None:
            held = ids == np.uint64(prefer)
            if held.any():
                rtt = np.where(held, np.maximum(rtt - self.affinity_ms, 0.0),
                               rtt)
        return self._order(ids, rtt)

    def gateways(self, state: RingState, k: int, *, origin=None) -> List[int]:
        act = state.active_ids()
        if origin is None or act.size <= k:
            return [int(x) for x in act[:k]]
        rtt = self.topology.rtt_ms_many(origin, act)
        return self._order(act, rtt)[:k]
