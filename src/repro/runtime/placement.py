"""Consistent-hash placement of work onto ring members (paper §III).

Everything that must be owned by exactly one node — KV-cache sessions,
MoE expert replicas, data-pipeline file shards, checkpoint shards — is a
*key* on the D1HT ring; its owner is the key's successor, resolved with a
single local lookup against the full routing table (the paper's whole
point: one hop, no directory).  The Pallas ``ring_lookup`` kernel batches
these lookups on-device for the serving router.

Churn behavior inherits consistent hashing's guarantee: a membership
event remaps only the keys in the arc adjacent to the event (~K/n keys),
so elastic re-meshing moves the minimum state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Union

import numpy as np

from repro.core.ring import RoutingTable, hash_id
from repro.core.ringstate import RingState


@dataclass
class Placement:
    table: Union[RoutingTable, RingState]

    def __post_init__(self) -> None:
        # accept a raw RingState and wrap it, so Membership / the router
        # and Placement always consume the same shared state object
        if isinstance(self.table, RingState):
            self.table = RoutingTable(state=self.table)

    @property
    def state(self) -> RingState:
        return self.table.state

    # -- generic key ownership ------------------------------------------------
    def owner(self, key: str) -> int:
        return self.table.owner(key)

    def owners(self, keys: Sequence[str]) -> List[int]:
        return [self.table.owner(k) for k in keys]

    def replica_owners(self, key: str, r: int) -> List[int]:
        """Successor-list replica group for r-way replicated keys
        (checkpoint shards, hot KV sessions): the owner plus the next
        r-1 distinct active peers clockwise."""
        return self.state.replica_set(key, r)

    # -- MoE experts ---------------------------------------------------------------
    def expert_assignment(self, num_experts: int, model_shards: int,
                          salt: str = "") -> np.ndarray:
        """Permutation mapping expert e -> EP shard, derived from the ring.

        Experts are placed on the ring by hash; each lands on its successor
        member, and members are binned round-robin into the ``model_shards``
        EP groups by ring order.  On membership change only the experts in
        the affected arc migrate (elastic EP).  Returns perm (E,) with
        perm[e] = shard index; applied as a gather on the stacked expert
        weights before EP sharding.
        """
        members = self.table.ids
        n = len(members)
        if n == 0:
            return np.arange(num_experts) % model_shards
        shard_of_member = {m: i % model_shards for i, m in enumerate(members)}
        out = np.empty((num_experts,), np.int64)
        for e in range(num_experts):
            m = self.table.successor_of(hash_id(f"expert/{salt}/{e}"))
            out[e] = shard_of_member[m]
        return out

    def expert_permutation(self, num_experts: int, model_shards: int,
                           salt: str = "") -> np.ndarray:
        """Stable permutation grouping experts by their assigned shard
        (experts_per_shard contiguity for the EP weight layout)."""
        assign = self.expert_assignment(num_experts, model_shards, salt)
        return np.argsort(assign, kind="stable")

    # -- serving sessions ---------------------------------------------------------
    def session_owner(self, session_id: str) -> int:
        return self.owner(f"session/{session_id}")

    def balance_stats(self, num_keys: int = 4096) -> Dict[str, float]:
        counts: Dict[int, int] = {}
        for i in range(num_keys):
            o = self.owner(f"probe/{i}")
            counts[o] = counts.get(o, 0) + 1
        vals = np.array(list(counts.values()), np.float64)
        return {"mean": float(vals.mean()), "max": float(vals.max()),
                "cv": float(vals.std() / max(vals.mean(), 1e-9))}
