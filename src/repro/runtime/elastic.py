"""Elastic re-meshing + straggler mitigation driven by membership events.

At 1000+ nodes the failure rate makes static meshes untenable (Eq III.1:
a 4096-host fleet with 30-day mean lifetime sees ~3 events/hour; a spot
fleet sees hundreds).  Policy:

  * A membership event triggers a re-mesh plan: keep the model axis fixed
    (TP/EP topology is wired to ICI), resize the data axis to the largest
    power-of-two of healthy hosts, and restore from the latest checkpoint
    with re-sharding (repro.ckpt restores to any mesh).
  * Straggler mitigation generalizes Rule 5: a host whose step heartbeat
    lags T_detect = 2*Theta behind the fleet median is probed; confirmed
    stragglers are quarantined (paper §V flash-crowd damping) and the
    fleet re-meshes without them.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .membership import Membership


@dataclass
class MeshPlan:
    data_axis: int
    model_axis: int
    participants: List[int]
    dropped: List[int] = field(default_factory=list)

    @property
    def size(self) -> int:
        return self.data_axis * self.model_axis


class ElasticController:
    def __init__(self, membership: Membership, *, model_axis: int,
                 min_data_axis: int = 1):
        self.membership = membership
        self.model_axis = model_axis
        self.min_data_axis = min_data_axis
        self.generation = 0
        self.plan: Optional[MeshPlan] = None
        self._heartbeats: Dict[int, float] = {}
        membership.subscribe(lambda ev: self.replan())

    # -- re-meshing -------------------------------------------------------------
    def replan(self) -> MeshPlan:
        members = self.membership.members()
        hosts_per_group = self.model_axis
        groups = len(members) // hosts_per_group
        data_axis = 1 << max(0, int(math.floor(math.log2(max(groups, 1)))))
        data_axis = max(self.min_data_axis, data_axis)
        used = members[: data_axis * hosts_per_group]
        dropped = members[data_axis * hosts_per_group:]
        self.generation += 1
        self.plan = MeshPlan(data_axis, self.model_axis, used, dropped)
        return self.plan

    # -- straggler detection (Rule 5 generalized) ----------------------------------
    def heartbeat(self, node_id: int, step_time_s: float) -> None:
        self._heartbeats[node_id] = step_time_s

    def stragglers(self, factor: float = 2.0) -> List[int]:
        if len(self._heartbeats) < 3:
            return []
        times = sorted(self._heartbeats.values())
        median = times[len(times) // 2]
        t_detect = factor * max(median, 1e-9)
        return [nid for nid, t in self._heartbeats.items() if t > t_detect]

    def evict_stragglers(self, factor: float = 2.0) -> List[int]:
        out = self.stragglers(factor)
        for nid in out:
            self.membership.fail(nid)          # leave event -> replan()
            self._heartbeats.pop(nid, None)
        return out
