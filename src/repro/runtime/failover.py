"""Checkpoint/restart orchestration bound to membership generations.

The trainer tags every checkpoint with the mesh generation that produced
it; on a membership event the ElasticController bumps the generation and
the trainer (a) drains in-flight steps, (b) restores the latest complete
checkpoint re-sharded to the new mesh, (c) resumes.  Restore-to-any-mesh
comes from repro.ckpt (host-side arrays + device_put with the new
shardings).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro import ckpt as ckpt_lib
from .elastic import ElasticController


@dataclass
class FailoverConfig:
    ckpt_dir: str
    save_every_steps: int = 100
    keep_last: int = 3


class FailoverManager:
    def __init__(self, cfg: FailoverConfig, controller: ElasticController):
        self.cfg = cfg
        self.controller = controller
        self._seen_generation = controller.generation
        os.makedirs(cfg.ckpt_dir, exist_ok=True)

    # -- checkpoint cadence -----------------------------------------------------
    def maybe_save(self, step: int, state: Any) -> Optional[str]:
        if step % self.cfg.save_every_steps:
            return None
        path = ckpt_lib.save(self.cfg.ckpt_dir, step, state)
        self._gc()
        return path

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_", 1)[1]) for d in os.listdir(self.cfg.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.cfg.keep_last]:
            import shutil
            shutil.rmtree(os.path.join(self.cfg.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restart path ----------------------------------------------------------------
    def needs_restore(self) -> bool:
        return self.controller.generation != self._seen_generation

    def restore_latest(self, target_state: Any, shardings: Any = None
                       ) -> tuple[int, Any]:
        step = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if step is None:
            raise FileNotFoundError("no checkpoint to restore")
        state = ckpt_lib.restore(self.cfg.ckpt_dir, step, target_state,
                                 shardings)
        self._seen_generation = self.controller.generation
        return step, state
