"""Checkpoint/restart orchestration bound to membership generations.

The trainer tags every checkpoint with the mesh generation that produced
it; on a membership event the ElasticController bumps the generation and
the trainer (a) drains in-flight steps, (b) restores the latest complete
checkpoint re-sharded to the new mesh, (c) resumes.  Restore-to-any-mesh
comes from repro.ckpt (host-side arrays + device_put with the new
shardings).

``ReplicaSupervisor`` is the serve-plane counterpart: instead of
checkpoints it tracks per-node membership generations so the serve
cluster knows when a node's device-resident state (KV slabs) must be
treated as lost and its replica restarted rather than resumed.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Dict, Optional

from repro import ckpt as ckpt_lib
from .elastic import ElasticController
from .membership import Membership


@dataclass
class FailoverConfig:
    ckpt_dir: str
    save_every_steps: int = 100
    keep_last: int = 3


class FailoverManager:
    def __init__(self, cfg: FailoverConfig, controller: ElasticController):
        self.cfg = cfg
        self.controller = controller
        self._seen_generation = controller.generation
        os.makedirs(cfg.ckpt_dir, exist_ok=True)

    # -- checkpoint cadence -----------------------------------------------------
    def maybe_save(self, step: int, state: Any) -> Optional[str]:
        if step % self.cfg.save_every_steps:
            return None
        path = ckpt_lib.save(self.cfg.ckpt_dir, step, state)
        self._gc()
        return path

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_", 1)[1]) for d in os.listdir(self.cfg.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.cfg.keep_last]:
            import shutil
            shutil.rmtree(os.path.join(self.cfg.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restart path ----------------------------------------------------------------
    def needs_restore(self) -> bool:
        return self.controller.generation != self._seen_generation

    def restore_latest(self, target_state: Any, shardings: Any = None
                       ) -> tuple[int, Any]:
        step = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if step is None:
            raise FileNotFoundError("no checkpoint to restore")
        state = ckpt_lib.restore(self.cfg.ckpt_dir, step, target_state,
                                 shardings)
        self._seen_generation = self.controller.generation
        return step, state


class ReplicaSupervisor:
    """Membership-generation clock for serving replicas.

    Every membership event bumps the global generation.  A node that
    leaves (crash/preemption) has its *required* generation pinned to the
    bump, so a replica stamped before that point — i.e. whose device
    state predates the departure — must be restarted with a fresh cache
    if the node ever re-enters the ring; its sessions were already
    migrated off by the serve cluster and must re-prefill, never resume
    against a stale slab.
    """

    def __init__(self, membership: Membership):
        self.generation = 0
        self._required: Dict[int, int] = {}
        self.membership = membership
        # node id -> device ids of its replica group (TP serving: one
        # ring node spans a device sub-mesh; see models.tp)
        self._groups: Dict[int, tuple] = {}
        membership.subscribe(self._on_event)

    def _on_event(self, ev) -> None:
        self.generation += 1
        if ev.kind != "join":              # leave/quarantine invalidates
            self._required[ev.subject_id] = self.generation

    def stamp(self) -> int:
        """Generation to tag a freshly created replica with."""
        return self.generation

    def needs_restart(self, node_id: int, stamp: int) -> bool:
        """True iff the node suffered an event since ``stamp`` that
        invalidates device state created under it."""
        return stamp < self._required.get(node_id, 0)

    # -- replica groups (tensor-parallel serving) ---------------------------
    def register_group(self, node_id: int, device_ids) -> None:
        """Bind a ring node to the devices of its TP replica group."""
        self._groups[node_id] = tuple(device_ids)

    def release_group(self, node_id: int) -> None:
        self._groups.pop(node_id, None)

    def group_owner(self, device_id: int) -> Optional[int]:
        """Ring node whose replica group holds ``device_id`` (None if the
        device backs no registered group)."""
        for node, devs in self._groups.items():
            if device_id in devs:
                return node
        return None

    def device_lost(self, device_id: int) -> Optional[int]:
        """Partial-group loss policy: losing ANY device of a group loses
        the whole replica — weight shards and KV slices are useless
        without their siblings.  Fails the owning node on the ring
        (generation bump + required-generation pin ride the membership
        event), which triggers the serve cluster's normal migration of
        its sessions to healthy groups.  Returns the failed node id."""
        node = self.group_owner(device_id)
        if node is None:
            return None
        self._groups.pop(node, None)
        if node in set(self.membership.members()):
            self.membership.fail(node)
        return node
