from .elastic import ElasticController, MeshPlan
from .failover import FailoverConfig, FailoverManager, ReplicaSupervisor
from .membership import Membership, NodeInfo
from .placement import (LatencyAware, Placement, PlacementPolicy,
                        RingSuccessor, Topology)

__all__ = ["ElasticController", "MeshPlan", "FailoverConfig",
           "FailoverManager", "ReplicaSupervisor", "Membership", "NodeInfo",
           "Placement", "PlacementPolicy", "RingSuccessor", "LatencyAware",
           "Topology"]
