from .elastic import ElasticController, MeshPlan
from .failover import FailoverConfig, FailoverManager, ReplicaSupervisor
from .membership import Membership, NodeInfo
from .placement import Placement

__all__ = ["ElasticController", "MeshPlan", "FailoverConfig",
           "FailoverManager", "ReplicaSupervisor", "Membership", "NodeInfo",
           "Placement"]
