from .elastic import ElasticController, MeshPlan
from .failover import FailoverConfig, FailoverManager
from .membership import Membership, NodeInfo
from .placement import Placement

__all__ = ["ElasticController", "MeshPlan", "FailoverConfig",
           "FailoverManager", "Membership", "NodeInfo", "Placement"]
