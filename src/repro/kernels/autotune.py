"""Tile-size autotuning for the Pallas kernels (ROADMAP item 3).

A small search-and-cache layer over the five kernels' block/tile sizes
(``ring_lookup``/``ring_lookup_bucketed``, ``edra_tree``,
``decode_attention``, ``flash_attention``, ``ssm_scan``):

  * **Keying** — entries are keyed on ``(backend, kernel, shape bucket)``
    where the shape bucket rounds every dimension up to a power of two,
    so one search covers a whole shape class (a churn-driven q=1000 and
    q=1024 lookup share an entry) and the cache stays small.
  * **Persistence** — winners live in a JSON cache file
    (``$REPRO_AUTOTUNE_CACHE`` or ``~/.cache/repro/autotune.json``); a
    searched entry is reused by every later process on the same backend.
    A corrupt or unreadable cache file degrades to the defaults — it can
    never take the kernels down.
  * **Interpret-mode fallback** — on interpret-only backends (CPU tests,
    CI) there is nothing to tune: :func:`tiles_for` returns the
    hand-picked defaults immediately, with no file I/O, and provenance
    reports ``autotune: "defaults"``.

Resolution (:func:`tiles_for`) NEVER searches — it is called from kernel
wrappers at jit-trace time, where timing a candidate would measure a
tracer.  Searching happens only through the explicit host-level entry
points :func:`autotune_kernel` / :func:`autotune_all`, which benchmarks
and the CI ``compiled-smoke`` job invoke before timing.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Callable, Dict, List, Optional, Tuple

from .backend import default_interpret

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
CACHE_VERSION = 1

# Hand-picked defaults — the committed tile constants each kernel shipped
# with.  These are the interpret-mode answer and the safety net for a
# missing/corrupt cache, so every kernel must work at these values.
DEFAULTS: Dict[str, Dict[str, int]] = {
    "ring_lookup": {"bq": 1024, "bt": 2048},
    "ring_lookup_bucketed": {"bq": 1024},
    "edra_tree": {"bp": 2048},
    "decode_attention": {"bs": 256},
    "flash_attention": {"bq": 128, "bk": 128},
    "ssm_scan": {"bd": 256},
}

# Sweep space per kernel.  Small on purpose: tile choices interact weakly
# and the cache amortizes the search across processes.
CANDIDATES: Dict[str, List[Dict[str, int]]] = {
    "ring_lookup": [{"bq": bq, "bt": bt}
                    for bq in (256, 512, 1024, 2048)
                    for bt in (1024, 2048, 4096)],
    "ring_lookup_bucketed": [{"bq": bq} for bq in (256, 512, 1024, 2048)],
    "edra_tree": [{"bp": bp} for bp in (512, 1024, 2048, 4096)],
    "decode_attention": [{"bs": bs} for bs in (128, 256, 512)],
    "flash_attention": [{"bq": bq, "bk": bk}
                        for bq in (128, 256) for bk in (128, 256)],
    "ssm_scan": [{"bd": bd} for bd in (128, 256, 512)],
}

KERNELS = tuple(DEFAULTS)

# process-level record of how tiles were resolved (for provenance)
_resolutions: set = set()


def _is_interpret() -> bool:  # indirection so tests can monkeypatch
    return default_interpret()


def cache_path() -> str:
    return os.environ.get(
        CACHE_ENV,
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


def _backend_key() -> str:
    import jax

    dev = jax.devices()[0]
    return f"{jax.default_backend()}:{getattr(dev, 'device_kind', 'unknown')}"


def shape_bucket(**dims: int) -> str:
    """Canonical shape-class key: every dim rounded up to a power of two
    (0/1 stay as-is), fields sorted for stability."""
    parts = []
    for k in sorted(dims):
        v = int(dims[k])
        if v > 1:
            v = 1 << (v - 1).bit_length()
        parts.append(f"{k}{v}")
    return "_".join(parts)


def _entry_key(kernel: str, bucket: str) -> str:
    return f"{_backend_key()}/{kernel}/{bucket}"


def load_cache(path: Optional[str] = None) -> dict:
    """Parsed cache file; a missing, corrupt, or wrong-version file reads
    as empty (defaults win) instead of raising."""
    path = path or cache_path()
    try:
        with open(path) as f:
            data = json.load(f)
        if not isinstance(data, dict) \
                or data.get("version") != CACHE_VERSION \
                or not isinstance(data.get("entries"), dict):
            return {"version": CACHE_VERSION, "entries": {}}
        return data
    except (OSError, ValueError):
        return {"version": CACHE_VERSION, "entries": {}}


def _save_cache(data: dict, path: Optional[str] = None) -> None:
    path = path or cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # atomic-ish: never leave a torn file for a concurrent reader
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               prefix=".autotune-")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


# ---------------------------------------------------------------------------
# Per-kernel tile validity (shape constraints the kernels assert on)
# ---------------------------------------------------------------------------

def _tiles_valid(kernel: str, tiles: Dict[str, int], dims: Dict[str, int]) -> bool:
    if kernel == "decode_attention":
        s = dims.get("s")
        return s is None or s % tiles["bs"] == 0
    if kernel == "flash_attention":
        sq, sk = dims.get("sq"), dims.get("sk")
        return (sq is None or sq % tiles["bq"] == 0) and \
            (sk is None or sk % tiles["bk"] == 0)
    if kernel == "ssm_scan":
        din = dims.get("din")
        return din is None or din % tiles["bd"] == 0
    return True


# ---------------------------------------------------------------------------
# Resolution (trace-time safe: cache/defaults only, never a search)
# ---------------------------------------------------------------------------

def tiles_for(kernel: str, **dims: int) -> Dict[str, int]:
    """Tile sizes for one kernel call.

    Interpret-mode backends get the hand-picked defaults immediately (no
    file I/O on the test/CI hot path).  Compiled backends consult the
    persisted cache for this (backend, kernel, shape-bucket) and fall
    back to the defaults on a miss, an invalid entry (tiles that violate
    the call's shape constraints), or a corrupt cache file.
    """
    base = dict(DEFAULTS[kernel])
    if _is_interpret():
        _resolutions.add("defaults")
        return base
    entry = load_cache().get("entries", {}).get(
        _entry_key(kernel, shape_bucket(**dims)))
    if entry and isinstance(entry.get("tiles"), dict):
        tiles = {k: int(v) for k, v in entry["tiles"].items() if k in base}
        if set(tiles) == set(base) and _tiles_valid(kernel, tiles, dims):
            _resolutions.add("cache")
            return tiles
    _resolutions.add("defaults")
    return base


def status_label() -> str:
    """How tiles were resolved so far this process (for provenance):
    ``defaults`` (interpret mode / no cache hits), ``cache`` (every
    resolution hit the cache), or ``mixed``."""
    if not _resolutions or _resolutions == {"defaults"}:
        return "defaults"
    if "searched" in _resolutions:
        return "searched"
    if _resolutions == {"cache"}:
        return "cache"
    return "mixed"


# ---------------------------------------------------------------------------
# Search (host-level only — called by benchmarks / the compiled-smoke job)
# ---------------------------------------------------------------------------

def _time_candidate(fn: Callable[[], object], reps: int) -> float:
    """Best-rep wall seconds with a warmup call (compile + upload)."""
    import jax

    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _default_bench(kernel: str, dims: Dict[str, int]) -> Callable[[dict], float]:
    """Build a ``bench(tiles) -> seconds`` closure on synthetic inputs of
    the requested shape class (lazy kernel imports keep this module
    import-light)."""
    import numpy as np
    import jax.numpy as jnp

    rng = np.random.default_rng(0)

    if kernel == "ring_lookup":
        from .ring_lookup.kernel import ring_lookup_pallas
        keys = jnp.asarray(rng.integers(0, 2**32, dims["q"], dtype=np.uint32))
        table = jnp.asarray(np.sort(rng.integers(
            0, 2**32, dims["n"], dtype=np.uint32)))
        return lambda t: _time_candidate(
            lambda: ring_lookup_pallas(keys, table, interpret=False, **t), 3)
    if kernel == "ring_lookup_bucketed":
        from .ring_lookup.kernel import BW, ring_lookup_bucketed_pallas
        nb = max(dims.get("b", 64), 64)
        khi = jnp.asarray(rng.integers(0, 2**32, dims["q"], dtype=np.uint32))
        klo = jnp.asarray(rng.integers(0, 2**32, dims["q"], dtype=np.uint32))
        bhi = jnp.asarray(rng.integers(0, 2**32, (nb, BW), dtype=np.uint32))
        blo = jnp.asarray(rng.integers(0, 2**32, (nb, BW), dtype=np.uint32))
        occ = jnp.asarray(rng.integers(1, BW - 1, nb, dtype=np.int32))
        return lambda t: _time_candidate(
            lambda: ring_lookup_bucketed_pallas(
                khi, klo, bhi, blo, occ, interpret=False, **t), 3)
    if kernel == "edra_tree":
        from .edra_tree.kernel import edra_tree_pallas
        p = dims["p"]
        off = jnp.asarray(rng.integers(0, 2**20, p, dtype=np.uint32))
        n = jnp.full(p, 2**20, jnp.uint32)
        rep = jnp.asarray(rng.integers(0, 2**20, p, dtype=np.uint32))
        t0 = jnp.asarray(rng.random(p), jnp.float32)
        key = jnp.asarray(rng.integers(0, 2**32, p, dtype=np.uint32))
        return lambda t: _time_candidate(
            lambda: edra_tree_pallas(off, n, rep, t0, key, levels=20,
                                     theta=1.0, delta_avg=0.1,
                                     interpret=False, **t), 3)
    if kernel == "decode_attention":
        from .decode_attention.kernel import decode_attention_pallas
        b, h, hkv, hd, s = (dims.get("b", 8), dims.get("h", 8),
                            dims.get("hkv", 2), dims.get("hd", 128),
                            dims["s"])
        q = jnp.asarray(rng.standard_normal((b, h, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
        ln = jnp.full((b,), s, jnp.int32)
        return lambda t: _time_candidate(
            lambda: decode_attention_pallas(q, k, v, ln, interpret=False,
                                            **t), 3)
    if kernel == "flash_attention":
        from .flash_attention.kernel import flash_attention_pallas
        b, h, hd = dims.get("b", 2), dims.get("h", 8), dims.get("hd", 128)
        sq, sk = dims["sq"], dims["sk"]
        q = jnp.asarray(rng.standard_normal((b, sq, h, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, sk, h, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, sk, h, hd)), jnp.float32)
        return lambda t: _time_candidate(
            lambda: flash_attention_pallas(q, k, v, causal=True,
                                           interpret=False, **t), 3)
    if kernel == "ssm_scan":
        from .ssm_scan.kernel import ssm_scan_pallas
        bb, l, din, n = (dims.get("bb", 2), dims.get("l", 256),
                         dims["din"], dims.get("n", 16))
        x = jnp.asarray(rng.standard_normal((bb, l, din)) * .1, jnp.float32)
        dt = jnp.asarray(np.abs(rng.standard_normal((bb, l, din))) * .1,
                         jnp.float32)
        B = jnp.asarray(rng.standard_normal((bb, l, n)) * .5, jnp.float32)
        C = jnp.asarray(rng.standard_normal((bb, l, n)) * .5, jnp.float32)
        A = jnp.asarray(-np.abs(rng.standard_normal((din, n))) - .1,
                        jnp.float32)
        D = jnp.ones((din,), jnp.float32)
        h0 = jnp.zeros((bb, din, n), jnp.float32)
        return lambda t: _time_candidate(
            lambda: ssm_scan_pallas(x, dt, B, C, A, D, h0, interpret=False,
                                    **t), 3)
    raise KeyError(f"unknown kernel {kernel!r}")


def autotune_kernel(kernel: str, dims: Dict[str, int], *,
                    bench: Optional[Callable[[dict], float]] = None,
                    force: bool = False,
                    path: Optional[str] = None) -> Dict[str, int]:
    """Search the candidate tiles for one (kernel, shape bucket) and
    persist the winner.  A cache hit returns WITHOUT re-searching unless
    ``force``; interpret-only backends return the defaults untouched (no
    search is meaningful against the interpreter)."""
    if kernel not in DEFAULTS:
        raise KeyError(f"unknown kernel {kernel!r}")
    if _is_interpret():
        _resolutions.add("defaults")
        return dict(DEFAULTS[kernel])
    bucket = shape_bucket(**dims)
    key = _entry_key(kernel, bucket)
    cache = load_cache(path)
    hit = cache["entries"].get(key)
    if hit and not force and isinstance(hit.get("tiles"), dict):
        _resolutions.add("cache")
        return {k: int(v) for k, v in hit["tiles"].items()}
    bench = bench or _default_bench(kernel, dims)
    cands = [c for c in CANDIDATES[kernel] if _tiles_valid(kernel, c, dims)] \
        or [dict(DEFAULTS[kernel])]
    results: List[Tuple[float, Dict[str, int]]] = []
    for cand in cands:
        try:
            results.append((float(bench(cand)), cand))
        except Exception:       # a tile the backend rejects is just a loss
            continue
    if not results:
        _resolutions.add("defaults")
        return dict(DEFAULTS[kernel])
    best_s, best = min(results, key=lambda r: r[0])
    import jax

    cache["entries"][key] = {
        "tiles": best, "us": round(best_s * 1e6, 2),
        "candidates": len(results), "jax": jax.__version__,
    }
    _save_cache(cache, path)
    _resolutions.add("searched")
    return dict(best)


# Representative shape classes for a whole-system sweep (the serve and
# churn planes' operating points).
SWEEP_DIMS: Dict[str, List[Dict[str, int]]] = {
    "ring_lookup": [{"q": 4096, "n": 10**6}],
    "ring_lookup_bucketed": [{"q": 4096, "b": 4096}],
    "edra_tree": [{"p": 1 << 18}],
    "decode_attention": [{"s": 1024}],
    "flash_attention": [{"sq": 1024, "sk": 1024}],
    "ssm_scan": [{"din": 1024}],
}


def autotune_all(*, force: bool = False,
                 budget_s: Optional[float] = None) -> Dict[str, dict]:
    """Sweep every kernel's representative shapes (compiled backends
    only; a no-op returning defaults under interpret).  ``budget_s``
    bounds the total wall time — the CI smoke passes ~30 s."""
    t0 = time.perf_counter()
    out: Dict[str, dict] = {}
    for kernel, shapes in SWEEP_DIMS.items():
        for dims in shapes:
            if budget_s is not None \
                    and time.perf_counter() - t0 > budget_s:
                return out
            out[f"{kernel}/{shape_bucket(**dims)}"] = autotune_kernel(
                kernel, dims, force=force)
    return out
