"""Pallas TPU kernel: batched single-token decode attention.

Decode is HBM-bandwidth-bound: each new token must stream the whole KV
cache once.  The kernel tiles the cache along the sequence axis (grid
axis 2, sequential) and keeps the per-(batch, kv-head) query group —
(g, hd), g = H/Hkv query heads — plus the online-softmax state in VMEM,
so the cache is read EXACTLY once per step at full burst width and no
(B,H,S) score tensor ever reaches HBM.

Grid: (B, Hkv, S/BS).  Block shapes: q (1,1,g,hd), kv (1,BS,1,hd) —
the g x BS score tile is MXU-shaped when g is a multiple of 8 and
BS = 128/256.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BS = 256
NEG = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, s_blocks: int, scale: float,
                   bs: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)             # (g, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)          # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = si * bs + jax.lax.iota(jnp.int32, bs)
    valid = pos < len_ref[0]
    s = jnp.where(valid[None, :], s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(si == s_blocks - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, length: jax.Array, *,
                            interpret: bool = True,
                            bs: int | None = None) -> jax.Array:
    """q: (B,H,hd); caches: (B,S,Hkv,hd); length: (B,) -> (B,H,hd)."""
    b, h, hd = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    if bs is None:
        from ..autotune import tiles_for

        bs = tiles_for("decode_attention", b=b, s=s)["bs"]
    BS = int(bs) if s % int(bs) == 0 else globals()["BS"]
    assert s % BS == 0, "pad cache length to a BS multiple"
    qg = q.reshape(b, hkv, g, hd)
    grid = (b, hkv, s // BS)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, s_blocks=s // BS,
                          scale=1.0 / math.sqrt(hd), bs=BS),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, ki, si: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, hd), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, BS, 1, hd), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, BS, 1, hd), lambda bi, ki, si: (bi, si, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, ki, si: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(length.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, h, hd)
