"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, length: jax.Array) -> jax.Array:
    """q: (B,H,hd); caches: (B,S,Hkv,hd); length: (B,) -> (B,H,hd)."""
    b, h, hd = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    sc = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    sc = sc / math.sqrt(hd)
    pos = jnp.arange(s)
    sc = jnp.where(pos[None, None, None, :] < length[:, None, None, None],
                   sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, hd).astype(q.dtype)
