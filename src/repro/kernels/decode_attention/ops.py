"""Jit'd public wrapper for decode attention."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import decode_attention_pallas
from .ref import decode_attention_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, *, use_pallas: bool = True,
                     interpret: bool = True) -> jax.Array:
    if use_pallas:
        return decode_attention_pallas(q, k_cache, v_cache, length,
                                       interpret=interpret)
    return decode_attention_ref(q, k_cache, v_cache, length)
