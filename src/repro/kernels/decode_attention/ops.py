"""Jit'd public wrapper for decode attention.

``interpret=None`` (the default) autodetects the backend: the compiled
Pallas kernel on TPU, interpreter mode everywhere else — so serving code
threads no flag and still gets the real kernel in production.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from ..backend import resolve_interpret
from .kernel import decode_attention_pallas
from .ref import decode_attention_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, *, use_pallas: bool = True,
                     interpret: Optional[bool] = None) -> jax.Array:
    if use_pallas:
        return decode_attention_pallas(q, k_cache, v_cache, length,
                                       interpret=resolve_interpret(interpret))
    return decode_attention_ref(q, k_cache, v_cache, length)
