"""Pallas TPU kernel: S6 selective scan (Mamba-1 hot loop).

TPU adaptation (DESIGN.md §2): the CUDA kernel's warp-level recurrence
becomes a VMEM-resident channel-block recurrence.  Grid (Bb, Din/BD):
each program owns a (BD, N) state slab in VMEM and walks the sequence
with a fori_loop — the state NEVER round-trips to HBM (the jnp lowering
writes (B,L,D,N) decay products; the kernel keeps them in registers).
Channels are embarrassingly parallel; the sequential axis is only L.

All sequence inputs for the block are staged in VMEM ((L,BD)+(L,N) —
for L=4096, BD=256, N=16 that's ~4.5 MB), so dt/B/C/x stream in once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BD = 256     # channels per program


def _ssm_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, h0_ref,
                y_ref, hout_ref, *, seq_len: int):
    a = a_ref[...].astype(jnp.float32)              # (BD, N)
    d = d_ref[...].astype(jnp.float32)              # (BD,)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)        # (BD,)
        dtt = dt_ref[0, t].astype(jnp.float32)      # (BD,)
        bt = b_ref[0, t].astype(jnp.float32)        # (N,)
        ct = c_ref[0, t].astype(jnp.float32)        # (N,)
        da = jnp.exp(dtt[:, None] * a)              # (BD, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y_ref[0, t] = (jnp.sum(h * ct[None, :], axis=1)
                       + d * xt).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, seq_len, step,
                          h0_ref[0].astype(jnp.float32))
    hout_ref[0] = h


def ssm_scan_pallas(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
                    A: jax.Array, D: jax.Array, h0: jax.Array, *,
                    interpret: bool = True, bd: int | None = None):
    """x,dt: (Bb,L,Din); B,C: (Bb,L,N); A: (Din,N); D: (Din,);
    h0: (Bb,Din,N) -> (y (Bb,L,Din), h_last (Bb,Din,N) f32)."""
    bb, l, din = x.shape
    n = A.shape[1]
    if bd is None:
        from ..autotune import tiles_for

        bd = tiles_for("ssm_scan", din=din)["bd"]
    BD = int(bd) if din % int(bd) == 0 else globals()["BD"]
    assert din % BD == 0, "pad d_inner to a BD multiple"
    grid = (bb, din // BD)
    y, h_last = pl.pallas_call(
        functools.partial(_ssm_kernel, seq_len=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, BD), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, l, BD), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, l, n), lambda bi, di: (bi, 0, 0)),
            pl.BlockSpec((1, l, n), lambda bi, di: (bi, 0, 0)),
            pl.BlockSpec((BD, n), lambda bi, di: (di, 0)),
            pl.BlockSpec((BD,), lambda bi, di: (di,)),
            pl.BlockSpec((1, BD, n), lambda bi, di: (bi, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, BD), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, BD, n), lambda bi, di: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb, l, din), x.dtype),
            jax.ShapeDtypeStruct((bb, din, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, B, C, A, D, h0)
    return y, h_last
