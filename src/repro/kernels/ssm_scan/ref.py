"""Pure-jnp oracle for the S6 selective-scan recurrence (Mamba-1 core).

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t * B_t
    y_t = h_t . C_t + D * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
                 A: jax.Array, D: jax.Array,
                 h0: jax.Array | None = None):
    """x,dt: (Bb,L,Din); B,C: (Bb,L,N); A: (Din,N); D: (Din,).

    -> y (Bb,L,Din), h_last (Bb,Din,N). All math in f32.
    """
    bb, l, din = x.shape
    n = A.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt[..., None] * Af[None])          # (Bb,Din,N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    if h0 is None:
        h0 = jnp.zeros((bb, din, n), jnp.float32)
    h_last, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
         Bf.swapaxes(0, 1), Cf.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + D.astype(jnp.float32)[None, None] * xf
    return y.astype(x.dtype), h_last
