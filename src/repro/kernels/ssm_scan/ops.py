"""Jit'd public wrapper for the selective scan.

``interpret=None`` (the default) autodetects the backend: the compiled
Pallas kernel on TPU, interpreter mode everywhere else.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..backend import resolve_interpret
from .kernel import ssm_scan_pallas
from .ref import ssm_scan_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ssm_scan(x, dt, B, C, A, D, h0=None, *, use_pallas: bool = True,
             interpret: Optional[bool] = None):
    if h0 is None:
        h0 = jnp.zeros((x.shape[0], x.shape[2], A.shape[1]), jnp.float32)
    if use_pallas:
        return ssm_scan_pallas(x, dt, B, C, A, D, h0,
                               interpret=resolve_interpret(interpret))
    return ssm_scan_ref(x, dt, B, C, A, D, h0)
