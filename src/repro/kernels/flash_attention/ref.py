"""Pure-jnp oracle: exact softmax attention (GQA, optional causal)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool) -> jax.Array:
    """q: (B,Sq,H,hd); k/v: (B,Sk,Hkv,hd) -> (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)
