"""Jit'd public wrapper for flash attention (Pallas on TPU, jnp oracle)."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention_pallas
from .ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "use_pallas", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, use_pallas: bool = True,
                    interpret: bool = True) -> jax.Array:
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal,
                                      interpret=interpret)
    return attention_ref(q, k, v, causal=causal)
