"""Jit'd public wrapper for flash attention (Pallas on TPU, jnp oracle).

``interpret=None`` (the default) autodetects the backend: the compiled
Pallas kernel on TPU, interpreter mode everywhere else.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from ..backend import resolve_interpret
from .kernel import flash_attention_pallas
from .ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "use_pallas", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, use_pallas: bool = True,
                    interpret: Optional[bool] = None) -> jax.Array:
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal,
                                      interpret=resolve_interpret(interpret))
    return attention_ref(q, k, v, causal=causal)
