"""Pallas TPU kernel: causal flash attention (GQA-aware index maps).

Classic TPU flash schedule: grid (B*H, Sq/BQ, Sk/BK) with the KV axis
innermost ("arbitrary": sequential revisits of the same output block).
Online-softmax running max/denominator live in VMEM scratch; the (BQ,BK)
score tile never leaves VMEM — this is precisely the HBM traffic the jnp
lowering pays (§Roofline memory term) and the kernel removes.

Block shapes are MXU-aligned (BQ=BK=128 >= 8x128 tiles; hd is typically
128).  GQA is handled in the k/v index_map: q head -> kv head = h // g,
so kv tiles are fetched once per q-head group without materializing the
repeated heads.  Causal masking skips fully-masked KV tiles via pl.when.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, sk_blocks: int, scale: float,
                  bq: int, bk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (ki <= qi)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == sk_blocks - 1)
    def _done():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool, interpret: bool = True,
                           bq: int | None = None,
                           bk: int | None = None) -> jax.Array:
    """q: (B,Sq,H,hd); k/v: (B,Sk,Hkv,hd) -> (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    if bq is None or bk is None:
        from ..autotune import tiles_for

        t = tiles_for("flash_attention", sq=sq, sk=sk)
        bq, bk = bq or t["bq"], bk or t["bk"]
    BQ = int(bq) if sq % int(bq) == 0 else globals()["BQ"]
    BK = int(bk) if sk % int(bk) == 0 else globals()["BK"]
    assert sq % BQ == 0 and sk % BK == 0, "pad sequences to 128"
    # flatten (B, H) into the leading grid dim; kv head = head // g
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, hd)
    grid = (b * h, sq // BQ, sk // BK)

    def kv_map(bh, qi, ki):
        return (bh // g, ki, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal,
                          sk_blocks=sk // BK, scale=1.0 / math.sqrt(hd),
                          bq=BQ, bk=BK),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQ, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, BK, hd), kv_map),
            pl.BlockSpec((1, BK, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, BQ, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            # (BQ,) running max, (BQ,) denominator, (BQ,hd) accumulator —
            # resident in VMEM across the sequential KV grid axis
            pltpu.VMEM((BQ,), jnp.float32),
            pltpu.VMEM((BQ,), jnp.float32),
            pltpu.VMEM((BQ, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
