"""EDRA dissemination-tree math, generic over numpy / jax.numpy.

One function — ``tree_math`` — is THE definition of the per-(event,
observer) EDRA tree quantities used by the vectorized churn plane
(repro.core.jax_sim.simulate_churn, DESIGN.md §8):

  ttl     acknowledge TTL: rho(n) for the reporter, trailing_zeros(i)
          for offset i > 0  (Rules 3+6+7 — repro.core.edra.ack_ttl)
  depth   hop depth popcount(i)          (repro.core.edra.ack_depth)
  parent  tree parent i & (i-1)          (repro.core.edra.parent_offset)
  ack     absolute acknowledge time: walk the ancestor chain from the
          reporter (prefixes of i's set bits, high to low); each hop
          waits for the SENDER's next Theta-interval boundary (its
          buffer flush, Rules 1-4) then pays an exponential network
          delay.  theta == 0 models an unbuffered protocol (1h-Calot:
          immediate forwarding).
  sends   messages this observer re-emits for the event — the Rule-8
          truncated fan-out #{l < ttl : i + 2^l < n} (Theorem 1 makes
          these sum to n-1 over a full ring).

Interval phases and per-edge delays are derived from counter-based
uint32 hashes (phase keyed on the peer's ring index, delay keyed on
(event, receiver-prefix)), so the tree is a pure function of its
arguments: the Pallas kernel and this reference produce the same
realization, two observers of one event share their ancestors' ack
times, and no (n,)-sized gather is needed at any scale.

``xp`` is the array namespace (numpy or jax.numpy): the Pallas kernel
body calls ``tree_math(jnp, ...)`` on its block refs, the numpy twin
tests call ``tree_math(np, ...)``, and ``edra_tree_ref`` is the jnp
oracle the ops wrapper dispatches to off-TPU.  All integer work is
uint32 (wrap-around semantics identical in numpy and XLA); times are
float32 (quantization ~0.25 ms at a 2000 s horizon — far below Theta).
"""
from __future__ import annotations

import numpy as np

_PHI = np.uint32(0x9E3779B9)
_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)
_ONE = np.uint32(1)


def _mix(x):
    """lowbias32 finalizer: uint32 -> well-mixed uint32."""
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def _h2(a, b):
    """Two-word hash; ``a`` is the stream key, ``b`` the counter."""
    return _mix(a ^ (b * _PHI))


def _u01(xp, h):
    """uint32 hash -> float32 uniform in (0, 1): 24 high bits + half-ulp."""
    return ((h >> 8).astype(xp.float32) + xp.float32(0.5)) \
        * xp.float32(1.0 / (1 << 24))


def _popcount(xp, x):
    """SWAR popcount on uint32 (population_count does not lower in every
    Pallas backend; this is four shifts and a multiply on the VPU)."""
    x = x - ((x >> 1) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> 2) & np.uint32(0x33333333))
    x = (x + (x >> 4)) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> 24).astype(xp.int32)


def tree_math(xp, offset, n, reporter, t_detect, event_key, *,
              levels: int, theta: float, delta_avg: float, seed: int = 0,
              fill_rate: float = 0.0, e_cap: float = 2.0):
    """Per-pair EDRA tree quantities; see module docstring.

    offset/n/reporter/event_key: (P,) uint32; t_detect: (P,) float32.
    ``levels`` must cover every ring: levels >= ceil(log2(max n)).
    Returns (ack f32, ttl i32, depth i32, parent u32, sends i32).

    ``fill_rate`` > 0 enables the Eq IV.4 early-interval-close model: a
    sender also flushes as soon as its buffer reaches ``e_cap`` events
    (every peer acknowledges every event, so acks arrive at the global
    event rate ``fill_rate``).  Per hop the buffered-event count at our
    ack is sampled as Normal(u*E, sqrt(u*E)) — the Poisson count over
    the elapsed interval fraction u — and the flush happens at
    min(grid boundary, time for the remaining e_cap-1-B arrivals).  At
    the paper's design point r*Theta = E this correction vanishes as
    1/sqrt(E); at small n (e_cap ~ 2) it matches the DES, where a
    second buffered event flushes the interval immediately.
    """
    offset = offset.astype(xp.uint32)
    n = n.astype(xp.uint32)
    reporter = reporter.astype(xp.uint32)
    event_key = event_key.astype(xp.uint32)
    zero = np.uint32(0)

    # rho(n) = ceil(log2 n) via bit-smear of n-1 (exact for n >= 2)
    s = n - _ONE
    for sh in (1, 2, 4, 8, 16):
        s = s | (s >> sh)
    rho_n = _popcount(xp, s)
    lsb = offset & (zero - offset)
    ttl = xp.where(offset == zero, rho_n, _popcount(xp, lsb - _ONE))
    depth = _popcount(xp, offset)
    parent = offset & (offset - _ONE)

    phase_key = np.uint32((seed * 0x9E3779B1 + 0x165667B1) & 0xFFFFFFFF)
    e_buf = fill_rate * theta              # mean acks per full interval
    t = t_detect.astype(xp.float32)
    cur = xp.zeros_like(offset)
    for b in reversed(range(levels)):
        bit = ((offset >> b) & _ONE) != zero
        sender = (reporter + cur) % n
        nxt = cur | np.uint32(1 << b)
        h = _h2(event_key, nxt)            # per-(event, edge) stream
        if theta > 0.0:
            # sender forwards at its next interval boundary (Rules 1-4);
            # the 1e-5 nudge keeps a flush-instant ack in the NEXT interval
            # (float32-scaled analogue of jax_sim's 1e-9)
            ph = _u01(xp, _h2(phase_key, sender)) * xp.float32(theta)
            flush = ph + xp.ceil((t - ph) * xp.float32(1.0 / theta)
                                 + xp.float32(1e-5)) * xp.float32(theta)
            if fill_rate > 0.0:            # Eq IV.4 early close
                u = xp.float32(1.0) - (flush - t) * xp.float32(1.0 / theta)
                u = xp.clip(u, xp.float32(0.0), xp.float32(1.0))
                mean_b = u * xp.float32(e_buf)
                z = (_u01(xp, _mix(h ^ np.uint32(0xB5297A4D)))
                     + _u01(xp, _mix(h ^ np.uint32(0x68E31DA4)))
                     + _u01(xp, _mix(h ^ np.uint32(0x1B56C4E9)))
                     - xp.float32(1.5)) * xp.float32(2.0)
                buffered = mean_b + xp.sqrt(mean_b) * z
                need = xp.clip(xp.float32(e_cap - 1.0) - buffered,
                               xp.float32(0.0), None)
                flush = xp.minimum(flush,
                                   t + need * xp.float32(1.0 / fill_rate))
        else:
            flush = t                      # unbuffered (1h-Calot)
        dly = -xp.log(_u01(xp, h)) * xp.float32(delta_avg)
        t = xp.where(bit, flush + dly, t)
        cur = xp.where(bit, nxt, cur)

    sends = xp.zeros_like(depth)
    for l in range(levels):
        fits = (offset + np.uint32(1 << l)) < n         # Rule 8
        sends = sends + xp.where((l < ttl) & fits, 1, 0).astype(xp.int32)
    return t, ttl, depth, parent, sends


def edra_tree_ref(offset, n, reporter, t_detect, event_key, *,
                  levels: int, theta: float, delta_avg: float,
                  seed: int = 0, fill_rate: float = 0.0,
                  e_cap: float = 2.0):
    """jnp oracle with the exact ``tree_math`` semantics (the dispatch
    target off-TPU and the twin the kernel sweeps compare against)."""
    import jax.numpy as jnp

    return tree_math(jnp, offset, n, reporter, t_detect, event_key,
                     levels=levels, theta=theta, delta_avg=delta_avg,
                     seed=seed, fill_rate=fill_rate, e_cap=e_cap)
