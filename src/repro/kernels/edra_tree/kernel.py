"""Pallas TPU kernel: batched EDRA dissemination-tree evaluation.

The vectorized churn plane needs, for millions of (event, observer)
pairs, the observer's acknowledge time plus its tree coordinates (TTL,
depth, parent, Rule-8 fan-out).  Materializing the (E, n) event-by-peer
matrix of ``jax_sim._simulate_core`` dies at n = 10^6 (E*n ~ 10^11), so
this kernel walks each pair's *ancestor chain* instead: the path from
the reporter to offset i visits the prefixes of i's set bits (high to
low), which is at most ``levels`` = ceil(log2 n) hops of pure uint32
bit-twiddling + float32 arithmetic per pair — no gathers, no
cross-pair communication, O(P * log n) total work.

Interval phases and link delays come from counter-based hashes (see
ref.tree_math), so the kernel needs NO (n,)-sized side table: every
block is self-contained and the grid is embarrassingly parallel over
pair blocks.  The math lives in ref.tree_math and is shared verbatim
with the numpy reference — the kernel body just runs it on jnp block
refs, keeping kernel == oracle by construction (modulo libm ulps in
log/ceil).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import tree_math

BP = 2048          # pairs per program (16 sublanes x 128 lanes of uint32)


def _edra_tree_kernel(off_ref, n_ref, rep_ref, t0_ref, key_ref,
                      ack_ref, ttl_ref, depth_ref, par_ref, sends_ref, *,
                      levels: int, theta: float, delta_avg: float,
                      seed: int, fill_rate: float, e_cap: float):
    ack, ttl, depth, parent, sends = tree_math(
        jnp, off_ref[...], n_ref[...], rep_ref[...], t0_ref[...],
        key_ref[...], levels=levels, theta=theta, delta_avg=delta_avg,
        seed=seed, fill_rate=fill_rate, e_cap=e_cap)
    ack_ref[...] = ack
    ttl_ref[...] = ttl
    depth_ref[...] = depth
    par_ref[...] = parent
    sends_ref[...] = sends


def edra_tree_pallas(offset: jax.Array, n: jax.Array, reporter: jax.Array,
                     t_detect: jax.Array, event_key: jax.Array, *,
                     levels: int, theta: float, delta_avg: float,
                     seed: int = 0, fill_rate: float = 0.0,
                     e_cap: float = 2.0, interpret: bool = True,
                     bp: int | None = None):
    """offset/n/reporter/event_key: (P,) uint32; t_detect: (P,) float32.

    Returns (ack f32, ttl i32, depth i32, parent u32, sends i32), each
    (P,).  ``theta`` and ``delta_avg`` specialize the trace — one
    compile per operating point, never per event batch.
    """
    p = offset.shape[0]
    if bp is None:
        from ..autotune import tiles_for

        bp = tiles_for("edra_tree", p=p)["bp"]
    BP = int(bp)
    pp = (p + BP - 1) // BP * BP
    pad = pp - p
    offset = jnp.pad(offset, (0, pad))
    # pad n with 1 (never 0: the chain walk reduces indices mod n)
    n = jnp.pad(n, (0, pad), constant_values=jnp.uint32(1))
    reporter = jnp.pad(reporter, (0, pad))
    t_detect = jnp.pad(t_detect, (0, pad))
    event_key = jnp.pad(event_key, (0, pad))
    spec = pl.BlockSpec((BP,), lambda i: (i,))
    ack, ttl, depth, parent, sends = pl.pallas_call(
        functools.partial(_edra_tree_kernel, levels=levels, theta=theta,
                          delta_avg=delta_avg, seed=seed,
                          fill_rate=fill_rate, e_cap=e_cap),
        grid=(pp // BP,),
        in_specs=[spec] * 5,
        out_specs=[spec] * 5,
        out_shape=[
            jax.ShapeDtypeStruct((pp,), jnp.float32),
            jax.ShapeDtypeStruct((pp,), jnp.int32),
            jax.ShapeDtypeStruct((pp,), jnp.int32),
            jax.ShapeDtypeStruct((pp,), jnp.uint32),
            jax.ShapeDtypeStruct((pp,), jnp.int32),
        ],
        interpret=interpret,
    )(offset, n, reporter, t_detect, event_key)
    return ack[:p], ttl[:p], depth[:p], parent[:p], sends[:p]
