"""Jit'd public wrapper: batched EDRA-tree ack times / coordinates.

Same dispatch contract as ``ring_lookup``: Pallas kernel by default,
``interpret=None`` autodetects the backend (compiled on TPU,
interpreter mode elsewhere), ``use_pallas=False`` pins the jnp oracle.
``theta``/``delta_avg``/``levels``/``seed`` are static — one trace per
operating point (a churn sweep entry), never per event batch.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from ..backend import resolve_interpret
from .kernel import edra_tree_pallas
from .ref import edra_tree_ref


@partial(jax.jit, static_argnames=("levels", "theta", "delta_avg", "seed",
                                   "fill_rate", "e_cap",
                                   "use_pallas", "interpret"))
def edra_tree(offset: jax.Array, n: jax.Array, reporter: jax.Array,
              t_detect: jax.Array, event_key: jax.Array, *,
              levels: int, theta: float, delta_avg: float, seed: int = 0,
              fill_rate: float = 0.0, e_cap: float = 2.0,
              use_pallas: bool = True,
              interpret: Optional[bool] = None):
    """(P,) uint32 offsets/ring sizes/reporters/event keys + (P,) f32
    detection times -> (ack f32, ttl i32, depth i32, parent u32,
    sends i32), each (P,).  See kernels.edra_tree.ref.tree_math for the
    exact semantics (``fill_rate``/``e_cap`` arm the Eq IV.4
    early-interval-close model)."""
    if use_pallas:
        return edra_tree_pallas(offset, n, reporter, t_detect, event_key,
                                levels=levels, theta=theta,
                                delta_avg=delta_avg, seed=seed,
                                fill_rate=fill_rate, e_cap=e_cap,
                                interpret=resolve_interpret(interpret))
    return edra_tree_ref(offset, n, reporter, t_detect, event_key,
                         levels=levels, theta=theta, delta_avg=delta_avg,
                         seed=seed, fill_rate=fill_rate, e_cap=e_cap)
