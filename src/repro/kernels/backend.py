"""Backend autodetection shared by the Pallas kernel wrappers.

Pallas kernels compile only for TPU; everywhere else (CPU tests, CI,
interactive runs) they must execute in interpreter mode.  Call sites used
to hardcode ``interpret=True``, which silently kept the *interpreted*
kernel on real TPUs too — production paths now resolve the flag from the
actual backend unless the caller pins it explicitly.
"""
from __future__ import annotations

from functools import lru_cache


@lru_cache(maxsize=1)
def default_interpret() -> bool:
    """True iff Pallas kernels must run interpreted (any non-TPU backend)."""
    import jax

    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    """``None`` -> autodetect; an explicit bool wins."""
    return default_interpret() if interpret is None else bool(interpret)
