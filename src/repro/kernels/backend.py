"""Backend autodetection shared by the Pallas kernel wrappers.

Pallas kernels compile only for TPU; everywhere else (CPU tests, CI,
interactive runs) they must execute in interpreter mode.  Call sites used
to hardcode ``interpret=True``, which silently kept the *interpreted*
kernel on real TPUs too — production paths now resolve the flag from the
actual backend unless the caller pins it explicitly.
"""
from __future__ import annotations

from functools import lru_cache


@lru_cache(maxsize=1)
def default_interpret() -> bool:
    """True iff Pallas kernels must run interpreted (any non-TPU backend)."""
    import jax

    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    """``None`` -> autodetect; an explicit bool wins."""
    return default_interpret() if interpret is None else bool(interpret)


@lru_cache(maxsize=1)
def bucket_budget_bytes() -> int:
    """Upper bound on the bucketized ring-lookup table (DESIGN.md §7).

    The bucketized kernel gathers per-query rows from a table resident
    on the accelerator, so its footprint must respect the device's fast
    memory: on TPU the matrix competes for VMEM (one core has ~16 MiB —
    leave headroom for the query blocks and outputs), while interpreted
    backends (CPU tests, CI) only burn host RAM.  RingState stops
    escalating the directory — and falls back to the flat-scan kernel —
    once the matrix would outgrow this budget.
    """
    return 8 << 20 if not default_interpret() else 256 << 20
