"""Backend autodetection + provenance shared by the Pallas kernel wrappers.

Pallas kernels compile only for TPU; everywhere else (CPU tests, CI,
interactive runs) they must execute in interpreter mode.  Call sites used
to hardcode ``interpret=True``, which silently kept the *interpreted*
kernel on real TPUs too — production paths now resolve the flag from the
actual backend unless the caller pins it explicitly.

This module is also the single source of truth for benchmark provenance:
every BENCH_*.json derives its ``mode``/``backend`` block from
:func:`provenance` / :func:`mode_label` instead of hardcoding a string
that would silently lie on an accelerator runner.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Optional


@lru_cache(maxsize=1)
def default_interpret() -> bool:
    """True iff Pallas kernels must run interpreted (any non-TPU backend)."""
    import jax

    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    """``None`` -> autodetect; an explicit bool wins."""
    return default_interpret() if interpret is None else bool(interpret)


def mode_label(interpret: Optional[bool] = None) -> str:
    """Execution-mode string for benchmark provenance, derived from the
    interpret flag a benchmark actually ran with (``None`` = autodetect),
    never hardcoded: ``pallas-interpret-cpu`` on a CPU CI runner,
    ``pallas-compiled-tpu`` on a real accelerator."""
    import jax

    kind = "interpret" if resolve_interpret(interpret) else "compiled"
    return f"pallas-{kind}-{jax.default_backend()}"


def provenance(interpret: Optional[bool] = None) -> dict:
    """Measurement provenance block for BENCH_*.json files.

    Records everything a future reader needs to decide whether two
    benchmark files are comparable: execution mode (interpret vs
    compiled — absolute numbers are NEVER comparable across modes, see
    DESIGN.md §10), backend/device identity, and the jax version."""
    import jax

    dev = jax.devices()[0]
    return {
        "mode": mode_label(interpret),
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", str(dev)),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
    }


def _device_memory_bytes() -> Optional[int]:
    """Fast-memory capacity of device 0 via ``memory_stats()``, or None
    when the backend doesn't report it (CPU, some plugin backends)."""
    import jax

    try:
        stats = jax.devices()[0].memory_stats()
    except Exception:
        return None
    if not stats:
        return None
    limit = stats.get("bytes_limit") or stats.get("bytes_reservable_limit")
    return int(limit) if limit else None


@lru_cache(maxsize=1)
def bucket_budget_bytes() -> int:
    """Upper bound on the bucketized ring-lookup table (DESIGN.md §7).

    The bucketized kernel gathers per-query rows from a table resident
    on the accelerator, so its footprint must respect the device's fast
    memory: on compiled backends the matrix competes with the query
    blocks and outputs for on-chip memory, while interpreted backends
    (CPU tests, CI) only burn host RAM.  RingState stops escalating the
    directory — and falls back to the flat-scan kernel — once the matrix
    would outgrow this budget.

    The compiled-path constant (8 MB, sized for a ~16 MiB-VMEM TPU core)
    is validated against the device's reported memory when
    ``memory_stats()`` is available: a small accelerator caps the budget
    at 1/16 of its actual capacity instead of trusting a constant that
    could overflow it.
    """
    if default_interpret():
        return 256 << 20
    budget = 8 << 20
    mem = _device_memory_bytes()
    if mem is not None:
        budget = min(budget, max(mem // 16, 1 << 20))
    return budget
