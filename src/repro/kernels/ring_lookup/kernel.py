"""Pallas TPU kernel: batched consistent-hash ring lookup.

TPU adaptation of the DHT hot path (DESIGN.md §2): a binary search is
gather-heavy and serial — poison for the VPU.  Instead each program
block computes bisect_left as a *compare-and-count* reduction:

    idx(q) = sum_j [table[j] < q]

which is one broadcasted (BQ x BT) uint compare + row-sum per table tile
— pure vector lanes, no gathers, and the table tiles stream through VMEM.
For routing tables up to ~10^6 peers (the paper's largest system) the
O(N) count costs less than the lane-divergent O(log N) search on TPU.

Grid: (Q/BQ, N/BT); the table axis is the innermost (arbitrary) dim and
accumulates into the output block, which stays resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 1024          # queries per program (8 sublanes x 128 lanes)
BT = 2048          # table entries per tile (8 KiB of uint32 in VMEM)


def _ring_lookup_kernel(q_ref, t_ref, o_ref, *, n_total: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]                                  # (BQ,)
    t = t_ref[...]                                  # (BT,)
    # mask table padding (last tile may exceed n_total)
    base = ti * BT
    valid = (base + jax.lax.iota(jnp.int32, BT)) < n_total
    lt = (t[None, :] < q[:, None]) & valid[None, :]
    o_ref[...] += jnp.sum(lt.astype(jnp.int32), axis=1)


def ring_lookup_pallas(keys: jax.Array, table: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """keys: (Q,) uint32; table: (N,) sorted uint32 -> (Q,) int32."""
    q, n = keys.shape[0], table.shape[0]
    qp = (q + BQ - 1) // BQ * BQ
    np_ = (n + BT - 1) // BT * BT
    keys_p = jnp.pad(keys, (0, qp - q))
    table_p = jnp.pad(table, (0, np_ - n),
                      constant_values=jnp.array(0, table.dtype))
    grid = (qp // BQ, np_ // BT)
    counts = pl.pallas_call(
        functools.partial(_ring_lookup_kernel, n_total=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BQ,), lambda qi, ti: (qi,)),
            pl.BlockSpec((BT,), lambda qi, ti: (ti,)),
        ],
        out_specs=pl.BlockSpec((BQ,), lambda qi, ti: (qi,)),
        out_shape=jax.ShapeDtypeStruct((qp,), jnp.int32),
        interpret=interpret,
    )(keys_p, table_p)
    return (counts[:q] % n).astype(jnp.int32)
