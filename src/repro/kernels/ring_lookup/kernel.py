"""Pallas TPU kernel: batched consistent-hash ring lookup.

TPU adaptation of the DHT hot path (DESIGN.md §2): a binary search is
gather-heavy and serial — poison for the VPU.  Instead each program
block computes bisect_left as a *compare-and-count* reduction:

    idx(q) = sum_j [table[j] < q]

which is one broadcasted (BQ x BT) uint compare + row-sum per table tile
— pure vector lanes, no gathers, and the table tiles stream through VMEM.
For routing tables up to ~10^6 peers (the paper's largest system) the
O(N) count costs less than the lane-divergent O(log N) search on TPU.

Grid: (Q/BQ, N/BT); the table axis is the innermost (arbitrary) dim and
accumulates into the output block, which stays resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 1024          # queries per program (8 sublanes x 128 lanes)
BT = 2048          # table entries per tile (8 KiB of uint32 in VMEM)


def _tiles(kernel: str, bq, bt=None, **dims):
    """Resolve (bq, bt) through the autotune cache when unset.

    Explicit arguments always win; otherwise the persisted per-backend
    winner (or the module defaults under interpret / cache miss).  Lazy
    import keeps kernels importable without the autotune package."""
    from ..autotune import tiles_for

    t = tiles_for(kernel, **dims)
    bq = int(bq) if bq else t["bq"]
    if bt is None and "bt" not in t:
        return bq
    return bq, (int(bt) if bt else t["bt"])


def _ring_lookup_kernel(q_ref, t_ref, o_ref, *, n_total: int, bt: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]                                  # (bq,)
    t = t_ref[...]                                  # (bt,)
    # mask table padding (last tile may exceed n_total)
    base = ti * bt
    valid = (base + jax.lax.iota(jnp.int32, bt)) < n_total
    lt = (t[None, :] < q[:, None]) & valid[None, :]
    o_ref[...] += jnp.sum(lt.astype(jnp.int32), axis=1)


def ring_lookup_pallas(keys: jax.Array, table: jax.Array, *,
                       interpret: bool = True,
                       bq: int | None = None,
                       bt: int | None = None) -> jax.Array:
    """keys: (Q,) uint32; table: (N,) sorted uint32 -> (Q,) int32."""
    q, n = keys.shape[0], table.shape[0]
    if n == 0:
        # mirror RingState.lookup's contract instead of surfacing the
        # mod-by-zero from the counts[:q] % n wraparound below
        raise LookupError("empty routing table")
    BQ, BT = _tiles("ring_lookup", bq, bt, q=q, n=n)
    qp = (q + BQ - 1) // BQ * BQ
    np_ = (n + BT - 1) // BT * BT
    keys_p = jnp.pad(keys, (0, qp - q))
    table_p = jnp.pad(table, (0, np_ - n),
                      constant_values=jnp.array(0, table.dtype))
    grid = (qp // BQ, np_ // BT)
    counts = pl.pallas_call(
        functools.partial(_ring_lookup_kernel, n_total=n, bt=BT),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BQ,), lambda qi, ti: (qi,)),
            pl.BlockSpec((BT,), lambda qi, ti: (ti,)),
        ],
        out_specs=pl.BlockSpec((BQ,), lambda qi, ti: (qi,)),
        out_shape=jax.ShapeDtypeStruct((qp,), jnp.int32),
        interpret=interpret,
    )(keys_p, table_p)
    return (counts[:q] % n).astype(jnp.int32)


def _ring_lookup64_kernel(n_ref, qhi_ref, qlo_ref, thi_ref, tlo_ref, o_ref,
                          *, bt: int):
    """Two-word (hi, lo) lexicographic compare-and-count.

    Full 64-bit ring IDs are carried as a uint32 (hi, lo) word pair
    (DESIGN.md §3): TPUs have no native uint64 lanes, and two uint32
    compares per entry keep the reduction on the VPU.  ``table < key``
    lexicographically iff  hi < qhi  or  (hi == qhi and lo < qlo).

    The live table length arrives as data (``n_ref``), not as a Python
    constant, so the jitted kernel is specialized only on the *capacity*
    (padded table shape) — membership churn never recompiles it.
    """
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    n_total = n_ref[0]
    qhi = qhi_ref[...]                              # (bq,)
    qlo = qlo_ref[...]
    thi = thi_ref[...]                              # (bt,)
    tlo = tlo_ref[...]
    base = ti * bt
    valid = (base + jax.lax.iota(jnp.int32, bt)) < n_total
    lt = (thi[None, :] < qhi[:, None]) | (
        (thi[None, :] == qhi[:, None]) & (tlo[None, :] < qlo[:, None]))
    lt = lt & valid[None, :]
    o_ref[...] += jnp.sum(lt.astype(jnp.int32), axis=1)


def ring_lookup64_pallas(keys_hi: jax.Array, keys_lo: jax.Array,
                         table_hi: jax.Array, table_lo: jax.Array,
                         n: jax.Array, *,
                         interpret: bool = True,
                         bq: int | None = None,
                         bt: int | None = None) -> jax.Array:
    """64-bit batched successor lookup over a hi/lo split table.

    keys_hi/keys_lo: (Q,) uint32 word pairs of the query IDs;
    table_hi/table_lo: (CAP,) uint32 word pairs, sorted by (hi, lo) in the
    first ``n`` slots (the rest is capacity padding, contents ignored);
    n: (1,) int32 live entry count (dynamic — no recompile on churn).
    Returns (Q,) int32 successor *indices* into the live table.
    """
    q, cap = keys_hi.shape[0], table_hi.shape[0]
    BQ, BT = _tiles("ring_lookup", bq, bt, q=q, n=cap)
    qp = (q + BQ - 1) // BQ * BQ
    capp = (cap + BT - 1) // BT * BT
    keys_hi = jnp.pad(keys_hi, (0, qp - q))
    keys_lo = jnp.pad(keys_lo, (0, qp - q))
    table_hi = jnp.pad(table_hi, (0, capp - cap))
    table_lo = jnp.pad(table_lo, (0, capp - cap))
    grid = (qp // BQ, capp // BT)
    counts = pl.pallas_call(
        functools.partial(_ring_lookup64_kernel, bt=BT),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda qi, ti: (0,)),
            pl.BlockSpec((BQ,), lambda qi, ti: (qi,)),
            pl.BlockSpec((BQ,), lambda qi, ti: (qi,)),
            pl.BlockSpec((BT,), lambda qi, ti: (ti,)),
            pl.BlockSpec((BT,), lambda qi, ti: (ti,)),
        ],
        out_specs=pl.BlockSpec((BQ,), lambda qi, ti: (qi,)),
        out_shape=jax.ShapeDtypeStruct((qp,), jnp.int32),
        interpret=interpret,
    )(n.astype(jnp.int32), keys_hi, keys_lo, table_hi, table_lo)
    return (counts[:q] % n[0]).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Two-level bucketized lookup (DESIGN.md §7)
# ---------------------------------------------------------------------------
#
# The flat kernels above compare every query against every table tile:
# O(N) per key, which collapses at million-peer scale.  The bucketized
# kernel bounds each query to ONE row of a radix-partitioned table:
#
#   bucket(id) = top R bits of the 64-bit id  (R = log2(rows))
#   rows (B, BW): row b holds the sorted active ids whose top bits are b
#                 in its first occ[b] slots; every slot >= occ[b] holds
#                 the bucket's SUCCESSOR id (the first active id past the
#                 bucket's range, wrapping to the ring origin), so
#
#   owner(q) = row[bucket(q)][ #\{j < occ : row[j] < q\} ]
#
# with no branch: an in-bucket successor lands on a live slot, an
# overshoot (q greater than everything in its bucket) lands on the
# successor padding.  occ must stay < BW (one pad slot reserved) —
# RingState falls back to the flat kernel when a bucket overflows.
#
# The kernel returns owner IDENTITIES (hi, lo), not global indices:
# ranks would need a prefix-sum directory whose entries all shift on any
# churn, while identities keep the device update O(touched buckets).

BW = 128           # bucket row width (one VPU lane row of uint32)


def _ring_lookup_bucketed_kernel(qhi_ref, qlo_ref, bhi_ref, blo_ref,
                                 occ_ref, ohi_ref, olo_ref, *, shift: int):
    """Per query block: one row gather + one (BQ, BW) compare-and-count.

    The row gather is the paged-attention access pattern (per-query row
    indices into a table resident outside the block): in interpret mode
    it is a numpy take; on TPU it lowers to a VMEM gather, so the
    dispatch layer only selects this kernel while the bucket matrix fits
    the device budget (repro.kernels.backend.bucket_budget_bytes).
    """
    qhi = qhi_ref[...]                               # (BQ,) uint32
    qlo = qlo_ref[...]
    b = jax.lax.shift_right_logical(
        qhi, jnp.uint32(shift)).astype(jnp.int32) if shift < 32 \
        else jnp.zeros_like(qhi, jnp.int32)
    rhi = jnp.take(bhi_ref[...], b, axis=0)          # (BQ, BW)
    rlo = jnp.take(blo_ref[...], b, axis=0)
    occ = jnp.take(occ_ref[...], b)                  # (BQ,)
    j = jax.lax.broadcasted_iota(jnp.int32, rhi.shape, 1)
    lt = (rhi < qhi[:, None]) | (
        (rhi == qhi[:, None]) & (rlo < qlo[:, None]))
    cnt = jnp.sum((lt & (j < occ[:, None])).astype(jnp.int32), axis=1)
    ohi_ref[...] = jnp.take_along_axis(rhi, cnt[:, None], axis=1)[:, 0]
    olo_ref[...] = jnp.take_along_axis(rlo, cnt[:, None], axis=1)[:, 0]


def ring_lookup_bucketed_pallas(keys_hi: jax.Array, keys_lo: jax.Array,
                                bkt_hi: jax.Array, bkt_lo: jax.Array,
                                occ: jax.Array, *,
                                interpret: bool = True,
                                bq: int | None = None):
    """Bucketized 64-bit successor lookup: O(BW) work per key.

    keys_hi/keys_lo: (Q,) uint32 query word pairs; bkt_hi/bkt_lo:
    (B, BW) uint32 bucket rows (B a power of two — the radix directory);
    occ: (B,) int32 live occupancy per row (< BW; the slack slots carry
    the bucket successor id).  Occupancy and row contents travel as
    data, so churn re-specializes nothing — only a directory resize
    (capacity doubling) changes the shapes.  Returns ((Q,) hi, (Q,) lo)
    owner id words.
    """
    q = keys_hi.shape[0]
    nb = bkt_hi.shape[0]
    r = nb.bit_length() - 1
    if nb != 1 << r:
        raise ValueError(f"bucket count {nb} is not a power of two")
    # BW is a data-layout constant shared with RingState._BUCKET_ROW, not
    # a tunable — only the query block size goes through the autotuner.
    BQ = _tiles("ring_lookup_bucketed", bq, q=q, b=nb)
    qp = (q + BQ - 1) // BQ * BQ
    keys_hi = jnp.pad(keys_hi, (0, qp - q))
    keys_lo = jnp.pad(keys_lo, (0, qp - q))
    out_hi, out_lo = pl.pallas_call(
        functools.partial(_ring_lookup_bucketed_kernel, shift=32 - r),
        grid=(qp // BQ,),
        in_specs=[
            pl.BlockSpec((BQ,), lambda qi: (qi,)),
            pl.BlockSpec((BQ,), lambda qi: (qi,)),
            pl.BlockSpec((nb, BW), lambda qi: (0, 0)),
            pl.BlockSpec((nb, BW), lambda qi: (0, 0)),
            pl.BlockSpec((nb,), lambda qi: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BQ,), lambda qi: (qi,)),
            pl.BlockSpec((BQ,), lambda qi: (qi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp,), jnp.uint32),
            jax.ShapeDtypeStruct((qp,), jnp.uint32),
        ],
        interpret=interpret,
    )(keys_hi, keys_lo, bkt_hi, bkt_lo, occ)
    return out_hi[:q], out_lo[:q]
