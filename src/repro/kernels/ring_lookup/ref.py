"""Pure-jnp oracle for the consistent-hash ring lookup.

successor index of key k in a sorted ring table = bisect_left(table, k)
mod N (the first peer clockwise from the key; wraps to index 0 past the
last peer) — identical semantics to repro.core.ring.RoutingTable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ring_lookup_ref(keys: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """keys: (Q,) uint32/int32; table: (N,) sorted same dtype -> (Q,) int32."""
    idx = jnp.searchsorted(table, keys, side="left")
    return (idx % table.shape[0]).astype(jnp.int32)


def ring_lookup64_ref(keys_hi: jnp.ndarray, keys_lo: jnp.ndarray,
                      table_hi: jnp.ndarray, table_lo: jnp.ndarray,
                      n: jnp.ndarray) -> jnp.ndarray:
    """64-bit oracle on hi/lo uint32 word pairs (no uint64 needed, so it
    runs without jax x64): bisect_left over the lexicographic order

        (thi, tlo) < (qhi, qlo)  iff  thi < qhi  or (thi == qhi, tlo < qlo)

    computed as a per-query compare-and-count over the ``n`` live entries
    of the capacity-padded table; vmap keeps the (Q, CAP) compare fused.
    """
    cap = table_hi.shape[0]
    valid = jnp.arange(cap, dtype=jnp.int32) < n[0]

    def count(qh, ql):
        lt = (table_hi < qh) | ((table_hi == qh) & (table_lo < ql))
        return jnp.sum(jnp.where(valid & lt, 1, 0))

    counts = jax.vmap(count)(keys_hi, keys_lo)
    return (counts % n[0]).astype(jnp.int32)


def ring_lookup_bucketed_ref(keys_hi: jnp.ndarray, keys_lo: jnp.ndarray,
                             bkt_hi: jnp.ndarray, bkt_lo: jnp.ndarray,
                             occ: jnp.ndarray):
    """Oracle for the bucketized kernel (same math, plain jnp).

    Row b of the (B, BW) bucket table holds the sorted active ids with
    top bits b in its first occ[b] slots and the bucket's successor id
    everywhere after, so ``row[count_of_smaller]`` IS the owner — both
    for in-bucket successors and for overshoot past the bucket's last
    entry.  Returns ((Q,) hi, (Q,) lo) owner id words.
    """
    nb, bw = bkt_hi.shape
    shift = 32 - (nb.bit_length() - 1)
    b = (jax.lax.shift_right_logical(keys_hi, jnp.uint32(shift))
         .astype(jnp.int32)) if shift < 32 else jnp.zeros_like(
        keys_hi, jnp.int32)
    rhi = jnp.take(bkt_hi, b, axis=0)                # (Q, BW)
    rlo = jnp.take(bkt_lo, b, axis=0)
    robo = jnp.take(occ, b)                          # (Q,)
    j = jnp.arange(bw, dtype=jnp.int32)[None, :]
    lt = (rhi < keys_hi[:, None]) | (
        (rhi == keys_hi[:, None]) & (rlo < keys_lo[:, None]))
    cnt = jnp.sum((lt & (j < robo[:, None])).astype(jnp.int32), axis=1)
    ohi = jnp.take_along_axis(rhi, cnt[:, None], axis=1)[:, 0]
    olo = jnp.take_along_axis(rlo, cnt[:, None], axis=1)[:, 0]
    return ohi, olo
