"""Pure-jnp oracle for the consistent-hash ring lookup.

successor index of key k in a sorted ring table = bisect_left(table, k)
mod N (the first peer clockwise from the key; wraps to index 0 past the
last peer) — identical semantics to repro.core.ring.RoutingTable.
"""
from __future__ import annotations

import jax.numpy as jnp


def ring_lookup_ref(keys: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """keys: (Q,) uint32/int32; table: (N,) sorted same dtype -> (Q,) int32."""
    idx = jnp.searchsorted(table, keys, side="left")
    return (idx % table.shape[0]).astype(jnp.int32)
