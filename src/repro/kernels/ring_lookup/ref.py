"""Pure-jnp oracle for the consistent-hash ring lookup.

successor index of key k in a sorted ring table = bisect_left(table, k)
mod N (the first peer clockwise from the key; wraps to index 0 past the
last peer) — identical semantics to repro.core.ring.RoutingTable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ring_lookup_ref(keys: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """keys: (Q,) uint32/int32; table: (N,) sorted same dtype -> (Q,) int32."""
    idx = jnp.searchsorted(table, keys, side="left")
    return (idx % table.shape[0]).astype(jnp.int32)


def ring_lookup64_ref(keys_hi: jnp.ndarray, keys_lo: jnp.ndarray,
                      table_hi: jnp.ndarray, table_lo: jnp.ndarray,
                      n: jnp.ndarray) -> jnp.ndarray:
    """64-bit oracle on hi/lo uint32 word pairs (no uint64 needed, so it
    runs without jax x64): bisect_left over the lexicographic order

        (thi, tlo) < (qhi, qlo)  iff  thi < qhi  or (thi == qhi, tlo < qlo)

    computed as a per-query compare-and-count over the ``n`` live entries
    of the capacity-padded table; vmap keeps the (Q, CAP) compare fused.
    """
    cap = table_hi.shape[0]
    valid = jnp.arange(cap, dtype=jnp.int32) < n[0]

    def count(qh, ql):
        lt = (table_hi < qh) | ((table_hi == qh) & (table_lo < ql))
        return jnp.sum(jnp.where(valid & lt, 1, 0))

    counts = jax.vmap(count)(keys_hi, keys_lo)
    return (counts % n[0]).astype(jnp.int32)
