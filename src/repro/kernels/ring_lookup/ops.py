"""Jit'd public wrapper: route key IDs to ring successor indices."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..backend import resolve_interpret
from .kernel import ring_lookup64_pallas, ring_lookup_pallas
from .ref import ring_lookup64_ref, ring_lookup_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ring_lookup(keys: jax.Array, table: jax.Array, *,
                use_pallas: bool = True,
                interpret: Optional[bool] = None) -> jax.Array:
    """keys (Q,), sorted table (N,) -> successor indices (Q,) int32.

    ``interpret=None`` (default) autodetects: compiled on TPU,
    interpreter mode everywhere else.
    """
    if use_pallas:
        return ring_lookup_pallas(keys, table,
                                  interpret=resolve_interpret(interpret))
    return ring_lookup_ref(keys, table)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ring_lookup64(keys_hi: jax.Array, keys_lo: jax.Array,
                  table_hi: jax.Array, table_lo: jax.Array,
                  n: jax.Array, *,
                  use_pallas: bool = True,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Full 64-bit successor lookup on a hi/lo word-split device table.

    The table arrays are *capacity* buffers: sorted live entries in the
    first ``n`` slots (n is a (1,) int32 array, traced — membership churn
    changes only its value, so the jit cache key is the capacity and the
    kernel never recompiles until capacity doubles).  Returns successor
    indices into the live entries.
    """
    if use_pallas:
        return ring_lookup64_pallas(keys_hi, keys_lo, table_hi, table_lo, n,
                                    interpret=resolve_interpret(interpret))
    return ring_lookup64_ref(keys_hi, keys_lo, table_hi, table_lo, n)
