"""Jit'd public wrapper: route key IDs to ring successor indices."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from ..backend import resolve_interpret
from .kernel import (ring_lookup64_pallas, ring_lookup_bucketed_pallas,
                     ring_lookup_pallas)
from .ref import (ring_lookup64_ref, ring_lookup_bucketed_ref,
                  ring_lookup_ref)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ring_lookup(keys: jax.Array, table: jax.Array, *,
                use_pallas: bool = True,
                interpret: Optional[bool] = None) -> jax.Array:
    """keys (Q,), sorted table (N,) -> successor indices (Q,) int32.

    ``interpret=None`` (default) autodetects: compiled on TPU,
    interpreter mode everywhere else.
    """
    if use_pallas:
        return ring_lookup_pallas(keys, table,
                                  interpret=resolve_interpret(interpret))
    return ring_lookup_ref(keys, table)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ring_lookup64(keys_hi: jax.Array, keys_lo: jax.Array,
                  table_hi: jax.Array, table_lo: jax.Array,
                  n: jax.Array, *,
                  use_pallas: bool = True,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Full 64-bit successor lookup on a hi/lo word-split device table.

    The table arrays are *capacity* buffers: sorted live entries in the
    first ``n`` slots (n is a (1,) int32 array, traced — membership churn
    changes only its value, so the jit cache key is the capacity and the
    kernel never recompiles until capacity doubles).  Returns successor
    indices into the live entries.
    """
    if use_pallas:
        return ring_lookup64_pallas(keys_hi, keys_lo, table_hi, table_lo, n,
                                    interpret=resolve_interpret(interpret))
    return ring_lookup64_ref(keys_hi, keys_lo, table_hi, table_lo, n)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ring_lookup_bucketed(keys_hi: jax.Array, keys_lo: jax.Array,
                         bkt_hi: jax.Array, bkt_lo: jax.Array,
                         occ: jax.Array, *,
                         use_pallas: bool = True,
                         interpret: Optional[bool] = None):
    """Two-level successor lookup: O(bucket-row) work per key.

    The (B, BW) bucket table and (B,) occupancy travel as data — churn
    changes only values, so the jit cache key is the directory size B
    and the kernel re-specializes only when the directory resizes (a
    capacity-doubling event), never on membership events.  Returns the
    owner id word pair ((Q,) hi, (Q,) lo) — identities, not ranks, so a
    membership batch only has to rewrite its touched rows.
    """
    if use_pallas:
        return ring_lookup_bucketed_pallas(
            keys_hi, keys_lo, bkt_hi, bkt_lo, occ,
            interpret=resolve_interpret(interpret))
    return ring_lookup_bucketed_ref(keys_hi, keys_lo, bkt_hi, bkt_lo, occ)
