"""Jit'd public wrapper: route key IDs to ring successor indices."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ring_lookup_pallas
from .ref import ring_lookup_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ring_lookup(keys: jax.Array, table: jax.Array, *,
                use_pallas: bool = True, interpret: bool = True) -> jax.Array:
    """keys (Q,), sorted table (N,) -> successor indices (Q,) int32.

    ``interpret=True`` (default) runs the Pallas kernel body in the
    interpreter — required on CPU; set False on real TPUs.
    """
    if use_pallas:
        return ring_lookup_pallas(keys, table, interpret=interpret)
    return ring_lookup_ref(keys, table)
