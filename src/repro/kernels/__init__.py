# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from .autotune import autotune_all, autotune_kernel, tiles_for
from .backend import (default_interpret, mode_label, provenance,
                      resolve_interpret)

__all__ = ["default_interpret", "resolve_interpret", "mode_label",
           "provenance", "tiles_for", "autotune_kernel", "autotune_all"]
