"""Encoder-decoder transformer (whisper-style backbone).

The conv audio frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, T_audio, d) in place of the two
conv layers over mel spectrograms.  Positions are sinusoidal (whisper uses
sinusoidal encoder positions and learned decoder positions; we use
sinusoidal for both so decode_32k doesn't require a 32k-row table —
recorded in DESIGN.md as a backbone-preserving simplification).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.specs import shard
from . import layers as L

Params = Dict[str, Any]


def _norm(cfg, abstract):
    if abstract:
        return jax.ShapeDtypeStruct((cfg.d_model,), L.dt(cfg))
    return jnp.ones((cfg.d_model,), L.dt(cfg))


def _sinusoid(positions: jax.Array, d: int, dtype) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) *
                    jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _enc_layer_params(cfg, rng, abstract):
    r1, r2 = (jax.random.split(rng) if rng is not None else (None, None))
    return {"ln1": _norm(cfg, abstract),
            "attn": L.attention_params(cfg, r1, abstract),
            "ln2": _norm(cfg, abstract),
            "mlp": L.mlp_params(cfg, cfg.d_ff, r2, abstract)}


def _dec_layer_params(cfg, rng, abstract):
    r1, r2, r3 = (jax.random.split(rng, 3) if rng is not None
                  else (None, None, None))
    return {"ln1": _norm(cfg, abstract),
            "attn": L.attention_params(cfg, r1, abstract),
            "ln_x": _norm(cfg, abstract),
            "xattn": L.attention_params(cfg, r2, abstract),
            "ln2": _norm(cfg, abstract),
            "mlp": L.mlp_params(cfg, cfg.d_ff, r3, abstract)}


def _stack(make, cfg, rng, abstract, n):
    if abstract:
        one = make(cfg, None, True)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), one)
    rngs = jax.random.split(rng, n)
    return jax.vmap(lambda r: make(cfg, r, False))(rngs)


def init_params(cfg: ModelConfig, rng=None, abstract: bool = False) -> Params:
    r1, r2, r3 = (jax.random.split(rng, 3) if rng is not None
                  else (None, None, None))
    return {
        "embed": L.embed_params(cfg, r1, abstract),
        "encoder": _stack(_enc_layer_params, cfg, r2, abstract,
                          cfg.encoder_layers),
        "decoder": _stack(_dec_layer_params, cfg, r3, abstract,
                          cfg.num_layers),
        "ln_enc": _norm(cfg, abstract),
        "ln_f": _norm(cfg, abstract),
    }


def param_pspecs(cfg: ModelConfig) -> Params:
    a = L.attention_specs(cfg)
    m = L.mlp_specs(cfg)
    enc = {"ln1": (None,), "attn": a, "ln2": (None,), "mlp": m}
    dec = {"ln1": (None,), "attn": a, "ln_x": (None,), "xattn": a,
           "ln2": (None,), "mlp": m}
    st = lambda tree: jax.tree.map(lambda sp: ("layers",) + tuple(sp), tree,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return {"embed": L.embed_specs(cfg), "encoder": st(enc),
            "decoder": st(dec), "ln_enc": (None,), "ln_f": (None,)}


def encode(params: Params, frames: jax.Array, cfg: ModelConfig, *,
           impl: str = "full") -> jax.Array:
    """frames: stub conv-frontend output (B, T, d)."""
    b, t, d = frames.shape
    pos = jnp.arange(t)
    x = frames.astype(L.dt(cfg)) + _sinusoid(pos, d, L.dt(cfg))[None]
    x = shard(x, "batch", "seq", "act_embed")
    positions = jnp.broadcast_to(pos, (b, t))

    def body(carry, lp):
        h = L.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        a, _ = L.attention(lp["attn"], h, cfg, positions=positions,
                           causal=False, use_rope=False, impl=impl)
        carry = carry + a
        h = L.rms_norm(carry, lp["ln2"], cfg.norm_eps)
        return carry + L.mlp(lp["mlp"], h, cfg), None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["encoder"])
    return L.rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def _dec_body(cfg, lp, x, enc_out, positions, enc_positions, impl,
              self_cache=None, cache_index=None, cross_kv=None):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, new_self = L.attention(lp["attn"], h, cfg, positions=positions,
                              causal=True, cache=self_cache,
                              cache_index=cache_index, impl=impl)
    x = x + a
    h = L.rms_norm(x, lp["ln_x"], cfg.norm_eps)
    if cross_kv is not None:
        # decode: precomputed encoder K/V
        q = (h @ lp["xattn"]["wq"]).reshape(
            h.shape[0], h.shape[1], cfg.num_heads, cfg.resolved_head_dim)
        a = L.decode_attention(q, cross_kv[0], cross_kv[1])
        a = a.reshape(h.shape[0], h.shape[1], -1) @ lp["xattn"]["wo"]
    else:
        a, _ = L.attention(lp["xattn"], h, cfg, positions=positions,
                           causal=False, kv_x=enc_out,
                           kv_positions=enc_positions, use_rope=False,
                           impl=impl)
    x = x + a
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + L.mlp(lp["mlp"], h, cfg), new_self


def train_loss(params: Params, batch: Dict[str, jax.Array],
               cfg: ModelConfig, *, impl: str = "full") -> jax.Array:
    frames, tokens, labels = batch["frames"], batch["tokens"], batch["labels"]
    enc_out = encode(params, frames, cfg, impl=impl)
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg) \
        + _sinusoid(jnp.arange(s), cfg.d_model, L.dt(cfg))[None]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    enc_positions = jnp.broadcast_to(jnp.arange(enc_out.shape[1]),
                                     (b, enc_out.shape[1]))

    def body(carry, lp):
        out, _ = _dec_body(cfg, lp, carry, enc_out, positions,
                           enc_positions, impl)
        return out, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["decoder"])
    h = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.chunked_ce_loss(params["embed"], h, labels, cfg)


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dtype = L.dt(cfg)
    lc, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    t = cfg.audio_frames
    return {
        "k": jax.ShapeDtypeStruct((lc, batch, max_len, hkv, hd), dtype),
        "v": jax.ShapeDtypeStruct((lc, batch, max_len, hkv, hd), dtype),
        "xk": jax.ShapeDtypeStruct((lc, batch, t, hkv, hd), dtype),
        "xv": jax.ShapeDtypeStruct((lc, batch, t, hkv, hd), dtype),
    }


def cache_pspecs(cfg: ModelConfig) -> Dict[str, Tuple]:
    kv = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {"k": kv, "v": kv, "xk": kv, "xv": kv}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, max_len))


def forward_with_cache(params: Params, tokens: jax.Array, cache: Dict,
                       cfg: ModelConfig, cache_index, *,
                       frames: Optional[jax.Array] = None,
                       impl: str = "full") -> Tuple[jax.Array, Dict]:
    """Decode step (or prefill when frames is given: fills cross K/V)."""
    b, s = tokens.shape
    positions = cache_index + jnp.broadcast_to(jnp.arange(s), (b, s))
    x = L.embed(params["embed"], tokens, cfg) \
        + _sinusoid(positions, cfg.d_model, L.dt(cfg))

    if frames is not None:
        enc_out = encode(params, frames, cfg, impl=impl)

        def fill(lp):
            hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            k = (enc_out @ lp["xattn"]["wk"]).reshape(
                b, enc_out.shape[1], hkv, hd)
            v = (enc_out @ lp["xattn"]["wv"]).reshape(
                b, enc_out.shape[1], hkv, hd)
            return k, v

        xk, xv = jax.vmap(fill)(params["decoder"])
        cache = dict(cache, xk=xk.astype(cache["xk"].dtype),
                     xv=xv.astype(cache["xv"].dtype))

    def body(carry, xs):
        lp, k_l, v_l, xk_l, xv_l = xs
        out, new_self = _dec_body(cfg, lp, carry, None, positions, None, impl,
                                  self_cache=(k_l, v_l),
                                  cache_index=cache_index,
                                  cross_kv=(xk_l, xv_l))
        return out, new_self

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    h = L.rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = L.logits_fn(params["embed"], h, cfg)[:, 0]
    return logits, dict(cache, k=nk, v=nv)
