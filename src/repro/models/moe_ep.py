"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

The GSPMD-lowered einsum/scatter MoE (layers.moe_block) lets the SPMD
partitioner guess how to move tokens to experts; on the 236B configs it
guesses badly — it replicates the combine scatter across the global batch
and all-reduces (B,S,d) twice per layer (~3 TB/device/step measured,
EXPERIMENTS.md §Perf-B).  This module is the production formulation:

  * tokens are sharded over BOTH mesh axes (batch over data, sequence
    over model) so each device owns T_loc = tokens/(data*model) rows;
  * routing is computed locally; slots are binned by destination EP
    shard into fixed-capacity buffers (C2 = T_loc*k*cf/M);
  * ONE lax.all_to_all ships rows to expert owners, local sort+capacity
    places them into (E_loc, C3, d) slabs for MXU einsums, and the
    reverse all_to_all brings outputs home — per-device collective volume
    is the theoretical T_loc*k*d*cf per direction, nothing replicated;
  * everything inside shard_map is local jnp — no partitioner guessing —
    and the whole block is differentiable (all_to_all transposes to
    all_to_all).

Expert-to-shard ownership follows the D1HT ring via
repro.runtime.placement (consistent hashing decides which EP shard owns
which expert; on elastic events only the affected arc of experts moves).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.sharding import specs as sh

Params = Dict[str, Any]


def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    return jax.nn.gelu


def _local_moe(x_loc: jax.Array, router: jax.Array, w1, w2, w3, *,
               cfg: ModelConfig, m_shards: int, axis: str) -> jax.Array:
    """Per-device body. x_loc: (T_loc, d); w*: local (E_loc, d, f) shards."""
    t, d = x_loc.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    e_loc = e // m_shards
    cf = cfg.moe_capacity_factor
    c2 = max(1, int(math.ceil(t * k * cf / m_shards)))      # per-dst slots
    c3 = max(1, int(math.ceil(m_shards * c2 * 1.0 / e_loc)))  # local slab

    gate = jnp.einsum("td,de->te", x_loc, router,
                      preferred_element_type=jnp.float32)
    weights, ids = jax.lax.top_k(jax.nn.softmax(gate, axis=-1), k)
    weights = (weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
               ).astype(x_loc.dtype)

    flat_ids = ids.reshape(t * k)
    flat_w = weights.reshape(t * k)
    tok = jnp.repeat(jnp.arange(t), k)
    dst = flat_ids // e_loc                                  # target shard

    order = jnp.argsort(dst)
    sdst = dst[order]
    stok = tok[order]
    sid = flat_ids[order] % e_loc                            # local expert @dst
    sw = flat_w[order]
    pos = jnp.arange(t * k)
    starts = jnp.searchsorted(sdst, jnp.arange(m_shards))
    rank = pos - starts[sdst]
    rank_c = jnp.where(rank < c2, rank, c2)                  # c2 = OOB drop

    send_x = jnp.zeros((m_shards, c2, d), x_loc.dtype).at[
        sdst, rank_c].set(x_loc[stok], mode="drop")
    send_e = jnp.full((m_shards, c2), e_loc, jnp.int32).at[
        sdst, rank_c].set(sid, mode="drop")                  # e_loc = empty

    recv_x = jax.lax.all_to_all(send_x, axis, split_axis=0,
                                concat_axis=0, tiled=True)
    recv_e = jax.lax.all_to_all(send_e, axis, split_axis=0,
                                concat_axis=0, tiled=True)

    rows = recv_x.reshape(m_shards * c2, d)
    eids = recv_e.reshape(m_shards * c2)                     # e_loc = empty
    order2 = jnp.argsort(eids)
    s2 = eids[order2]
    starts2 = jnp.searchsorted(s2, jnp.arange(e_loc))
    rank2 = jnp.arange(rows.shape[0]) - starts2[jnp.clip(s2, 0, e_loc - 1)]
    rank2_c = jnp.where((rank2 < c3) & (s2 < e_loc), rank2, c3)

    xin = jnp.zeros((e_loc, c3, d), x_loc.dtype).at[
        s2, rank2_c].set(rows[order2], mode="drop")

    act = _act(cfg.act)
    h = act(jnp.einsum("ecd,edf->ecf", xin, w1))
    if w3 is not None:
        h = h * jnp.einsum("ecd,edf->ecf", xin, w3)
    eout = jnp.einsum("ecf,efd->ecd", h, w2)

    valid2 = (s2 < e_loc) & (rank2 < c3)
    gathered = eout[jnp.clip(s2, 0, e_loc - 1),
                    jnp.clip(rank2_c, 0, c3 - 1)]
    gathered = jnp.where(valid2[:, None], gathered, 0)
    rows_out = jnp.zeros_like(rows).at[order2].set(gathered)

    back = jax.lax.all_to_all(rows_out.reshape(m_shards, c2, d), axis,
                              split_axis=0, concat_axis=0, tiled=True)

    valid = rank < c2
    vals = back[sdst, jnp.clip(rank_c, 0, c2 - 1)]
    vals = jnp.where(valid[:, None], vals, 0) * sw[:, None]
    out = jnp.zeros((t, d), x_loc.dtype).at[stok].add(vals)
    return out


def moe_block_ep(params: Params, x: jax.Array, cfg: ModelConfig
                 ) -> Optional[jax.Array]:
    """EP a2a MoE. Returns None when no suitable mesh is active (caller
    falls back to the GSPMD formulation)."""
    mesh = sh.current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    m_shards = mesh.shape["model"]
    b, s, d = x.shape
    if cfg.moe_experts % m_shards or s % m_shards:
        return None

    bspec = sh.logical_spec("batch")
    batch_entry = bspec[0] if len(bspec) else None
    x_spec = P(batch_entry, "model", None)      # seq sharded over model
    w_spec = P("model", None, None)
    has_w3 = "w3" in params

    if has_w3:
        def fn(x_l, router, w1, w2, w3):
            t_loc = x_l.shape[0] * x_l.shape[1]
            out = _local_moe(x_l.reshape(t_loc, d), router, w1, w2, w3,
                             cfg=cfg, m_shards=m_shards, axis="model")
            return out.reshape(x_l.shape)
        out = jax.shard_map(
            fn, mesh=mesh,
            in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
            out_specs=x_spec, check_vma=False,
        )(x, params["router"].astype(x.dtype), params["w1"], params["w2"],
          params["w3"])
    else:
        def fn(x_l, router, w1, w2):
            t_loc = x_l.shape[0] * x_l.shape[1]
            out = _local_moe(x_l.reshape(t_loc, d), router, w1, w2, None,
                             cfg=cfg, m_shards=m_shards, axis="model")
            return out.reshape(x_l.shape)
        out = jax.shard_map(
            fn, mesh=mesh,
            in_specs=(x_spec, P(None, None), w_spec, w_spec),
            out_specs=x_spec, check_vma=False,
        )(x, params["router"].astype(x.dtype), params["w1"], params["w2"])

    if cfg.moe_shared_experts:
        act = _act(cfg.act)
        hs = act(x @ params["sw1"])
        if "sw3" in params:
            hs = hs * (x @ params["sw3"])
        out = out + hs @ params["sw2"]
    return sh.shard(out, "batch", "seq", "act_embed")
