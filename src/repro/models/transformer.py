"""Decoder-only transformer (dense / MoE / MLA) with scan-over-layers.

Layer weights are stacked on a leading ``layers`` axis and consumed by
``jax.lax.scan`` — one compiled layer body regardless of depth, which
keeps dry-run HLO size and compile time flat across the 94-layer configs.
Activation rematerialization is configurable (cfg.remat in
{none, dots, full}).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import layers as L

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _layer_params(cfg: ModelConfig, rng, abstract: bool) -> Params:
    p: Params = {"ln1": _norm(cfg, abstract), "ln2": _norm(cfg, abstract)}
    r1, r2 = (jax.random.split(rng) if rng is not None else (None, None))
    if cfg.mla_kv_lora:
        p["attn"] = L.mla_params(cfg, r1, abstract)
    else:
        p["attn"] = L.attention_params(cfg, r1, abstract)
    if cfg.moe_experts:
        p["moe"] = L.moe_params(cfg, r2, abstract)
    else:
        p["mlp"] = L.mlp_params(cfg, cfg.d_ff, r2, abstract)
    return p


def _norm(cfg: ModelConfig, abstract: bool):
    if abstract:
        return jax.ShapeDtypeStruct((cfg.d_model,), L.dt(cfg))
    return jnp.ones((cfg.d_model,), L.dt(cfg))


def _stack(cfg: ModelConfig, rng, abstract: bool, n_layers: int) -> Params:
    if abstract:
        one = _layer_params(cfg, None, True)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_layers,) + s.shape, s.dtype), one)
    rngs = jax.random.split(rng, n_layers)
    return jax.vmap(lambda r: _layer_params(cfg, r, False))(rngs)


def init_params(cfg: ModelConfig, rng=None, abstract: bool = False) -> Params:
    r_emb, r_layers = (jax.random.split(rng) if rng is not None else (None, None))
    return {
        "embed": L.embed_params(cfg, r_emb, abstract),
        "layers": _stack(cfg, r_layers, abstract, cfg.num_layers),
        "ln_f": _norm(cfg, abstract),
    }


def param_pspecs(cfg: ModelConfig) -> Params:
    """Pytree of logical-axis tuples matching init_params' structure."""
    layer = {"ln1": (None,), "ln2": (None,)}
    layer["attn"] = (L.mla_specs(cfg) if cfg.mla_kv_lora
                     else L.attention_specs(cfg))
    if cfg.moe_experts:
        layer["moe"] = L.moe_specs(cfg)
    else:
        layer["mlp"] = L.mlp_specs(cfg)
    stacked = jax.tree.map(lambda sp: ("layers",) + tuple(sp), layer,
                           is_leaf=lambda x: isinstance(x, tuple))
    return {"embed": L.embed_specs(cfg), "layers": stacked, "ln_f": (None,)}


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------

def _layer_body(cfg: ModelConfig, lp: Params, x: jax.Array, *,
                positions: jax.Array, impl: str,
                cache: Optional[Tuple] = None,
                cache_index=None,
                decode_kernel: Optional[bool] = None,
                chunk: bool = False
                ) -> Tuple[jax.Array, Optional[Tuple]]:
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla_kv_lora:
        a, new_cache = L.mla_attention(lp["attn"], h, cfg, positions=positions,
                                       cache=cache, cache_index=cache_index,
                                       impl=impl)
    else:
        a, new_cache = L.attention(lp["attn"], h, cfg, positions=positions,
                                   causal=True, cache=cache,
                                   cache_index=cache_index, impl=impl,
                                   decode_kernel=decode_kernel, chunk=chunk)
    x = x + a
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe_experts:
        m = None
        if cfg.moe_impl == "ep":
            from .moe_ep import moe_block_ep
            m = moe_block_ep(lp["moe"], h, cfg)
        if m is None:
            m = L.moe_block(lp["moe"], h, cfg)
    else:
        m = L.mlp(lp["mlp"], h, cfg)
    return x + m, new_cache


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def backbone(params: Params, x: jax.Array, cfg: ModelConfig, *,
             positions: jax.Array, impl: str = "full") -> jax.Array:
    """Embedded input -> final hidden states (no caches)."""

    def body(carry, lp):
        out, _ = _layer_body(cfg, lp, carry, positions=positions, impl=impl)
        return out, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["layers"])
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def train_loss(params: Params, batch: Dict[str, jax.Array],
               cfg: ModelConfig, *, impl: str = "full") -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    x = L.embed(params["embed"], tokens, cfg)
    if "image_embeds" in batch:                     # VLM: stub ViT output
        img = batch["image_embeds"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
        pad = jnp.zeros(img.shape[:2], labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
        mask_img = img.shape[1]
    else:
        mask_img = 0
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    h = backbone(params, x, cfg, positions=positions, impl=impl)
    if mask_img:
        h, labels = h[:, mask_img:], labels[:, mask_img:]
    return L.chunked_ce_loss(params["embed"], h, labels, cfg)


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    lcount, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    dtype = L.dt(cfg)
    if cfg.mla_kv_lora:
        return {
            "c": jax.ShapeDtypeStruct(
                (lcount, batch, max_len, cfg.mla_kv_lora), dtype),
            "r": jax.ShapeDtypeStruct(
                (lcount, batch, max_len, cfg.mla_qk_rope_dim), dtype),
        }
    return {
        "k": jax.ShapeDtypeStruct((lcount, batch, max_len, hkv, hd), dtype),
        "v": jax.ShapeDtypeStruct((lcount, batch, max_len, hkv, hd), dtype),
    }


def cache_pspecs(cfg: ModelConfig) -> Dict[str, Tuple]:
    if cfg.mla_kv_lora:
        return {"c": ("layers", "batch", "kv_seq", None),
                "r": ("layers", "batch", "kv_seq", None)}
    return {"k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "v": ("layers", "batch", "kv_seq", "kv_heads", None)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, max_len))


def kv_block_shape(cfg: ModelConfig, chunk: int) -> Tuple[int, ...]:
    """Fixed block shape for ``chunk`` cache positions: (2, chunk,
    layers, kv_heads, head_dim) — k and v stacked on the leading axis so
    one DHT block carries a whole chunk's cache state."""
    return (2, chunk, cfg.num_layers, cfg.num_kv_heads,
            cfg.resolved_head_dim)


def export_kv_block(cfg: ModelConfig, cache: Dict, row: int, off: int,
                    chunk: int):
    """Pull cache positions [off, off+chunk) of one batch row to host as
    a (2, chunk, layers, kv_heads, head_dim) numpy slab (the data
    plane's wire format)."""
    import numpy as np
    k = np.asarray(cache["k"][:, row, off:off + chunk])   # (L, c, H, D)
    v = np.asarray(cache["v"][:, row, off:off + chunk])
    return np.stack([k.transpose(1, 0, 2, 3), v.transpose(1, 0, 2, 3)])


def export_kv_block_shards(cfg: ModelConfig, cache: Dict, row: int, off: int,
                           chunk: int, shards: int):
    """Per-shard slabs for one chunk: shard ``s`` carries kv_heads
    [s*H/shards, (s+1)*H/shards) in the same (2, chunk, layers, heads,
    head_dim) wire format.  Under a TP group each head range lives on
    exactly one device, so every slice pulls only that device's bytes —
    ``np.concatenate(slabs, axis=3)`` reassembles the full slab."""
    import numpy as np
    hkv = cfg.num_kv_heads
    if shards < 1 or hkv % shards:
        raise ValueError(f"shards={shards} must divide kv_heads={hkv}")
    hl = hkv // shards
    out = []
    for s_i in range(shards):
        lo = s_i * hl
        k = np.asarray(cache["k"][:, row, off:off + chunk, lo:lo + hl])
        v = np.asarray(cache["v"][:, row, off:off + chunk, lo:lo + hl])
        out.append(np.stack([k.transpose(1, 0, 2, 3),
                             v.transpose(1, 0, 2, 3)]))
    return out


def cache_with_blocks(cfg: ModelConfig, max_len: int, blocks,
                      shardings: Optional[Dict[str, Any]] = None) -> Dict:
    """Fresh single-row cache with a contiguous run of exported slabs
    already written at positions [0, len(blocks)*chunk).

    Assembled HOST-side and shipped as one device array per k/v: a
    per-block ``.at[].set`` costs a dispatched XLA op (and a first-call
    compile) per block, which at serve-plane block sizes is as slow as
    just recomputing the chunk — this path is O(1) dispatches however
    long the imported run is.  ``shardings`` ({"k": NamedSharding, "v":
    ...}) lands each k/v directly under a TP group's layout: device_put
    splits the host slab so every device receives only its kv_heads
    slice."""
    import numpy as np
    shapes = cache_shapes(cfg, 1, max_len)
    k = np.zeros(shapes["k"].shape, shapes["k"].dtype)
    v = np.zeros(shapes["v"].shape, shapes["v"].dtype)
    if blocks:
        kk = np.concatenate([b[0] for b in blocks])   # (covered, L, H, D)
        vv = np.concatenate([b[1] for b in blocks])
        covered = kk.shape[0]
        k[:, 0, :covered] = kk.transpose(1, 0, 2, 3)
        v[:, 0, :covered] = vv.transpose(1, 0, 2, 3)
    if shardings is not None:
        return {"k": jax.device_put(k, shardings["k"]),
                "v": jax.device_put(v, shardings["v"])}
    return {"k": jnp.asarray(k), "v": jnp.asarray(v)}


def import_kv_block(cfg: ModelConfig, cache: Dict, row: int, off: int,
                    block) -> Dict:
    """Write an exported slab back into cache positions [off, off+chunk)
    of one batch row.  Bit-faithful: the imported KV is byte-identical
    to what the exporting replica computed, so decode from the merged
    cache is token-identical to never having moved."""
    chunk = block.shape[1]
    k = jnp.asarray(block[0].transpose(1, 0, 2, 3),
                    cache["k"].dtype)[:, None]            # (L, 1, c, H, D)
    v = jnp.asarray(block[1].transpose(1, 0, 2, 3), cache["v"].dtype)[:, None]
    return {"k": cache["k"].at[:, row:row + 1, off:off + chunk].set(k),
            "v": cache["v"].at[:, row:row + 1, off:off + chunk].set(v)}


def _cache_tuple(cfg, cache_l):
    return (cache_l["c"], cache_l["r"]) if cfg.mla_kv_lora \
        else (cache_l["k"], cache_l["v"])


def _cache_dict(cfg, tup):
    return ({"c": tup[0], "r": tup[1]} if cfg.mla_kv_lora
            else {"k": tup[0], "v": tup[1]})


def forward_with_cache(params: Params, tokens: jax.Array, cache: Dict,
                       cfg: ModelConfig, cache_index, *,
                       impl: str = "full",
                       decode_kernel: Optional[bool] = None,
                       image_embeds: Optional[jax.Array] = None,
                       chunk: bool = False
                       ) -> Tuple[jax.Array, Dict]:
    """Prefill (S>1) or decode (S==1): returns (last-position logits, cache).

    ``cache_index`` may be a scalar (prefill / lockstep decode) or a (B,)
    array of per-slot cache positions (continuous-batching decode: every
    row writes and attends at its own length).

    ``chunk=True`` marks a fixed-shape *continuation* prefill segment
    (scalar ``cache_index``, possibly > 0): attention spans the whole
    cache under the absolute causal mask, and ALL-position logits
    (B, S, V) are returned so the caller can select the true last prompt
    position when the segment carries right-padding.
    """
    x = L.embed(params["embed"], tokens, cfg)
    if image_embeds is not None:
        x = jnp.concatenate([image_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    idx = jnp.asarray(cache_index)
    off = idx[:, None] if idx.ndim else idx
    positions = off + jnp.broadcast_to(jnp.arange(s), x.shape[:2])

    def body(carry, xs):
        lp, cl = xs
        out, new_cache = _layer_body(cfg, lp, carry, positions=positions,
                                     impl=impl, cache=_cache_tuple(cfg, cl),
                                     cache_index=idx,
                                     decode_kernel=decode_kernel,
                                     chunk=chunk)
        return out, _cache_dict(cfg, new_cache)

    x, new_caches = jax.lax.scan(body, x, (params["layers"], cache))
    if chunk:
        h = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        return L.logits_fn(params["embed"], h, cfg), new_caches
    h = L.rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = L.logits_fn(params["embed"], h, cfg)[:, 0]
    return logits, new_caches
