"""State-space sequence mixers: Mamba-1 (S6 selective scan) and Mamba-2
(SSD chunked matmul form), in pure JAX.

TPU adaptation (DESIGN.md §2): the CUDA selective-scan kernel is replaced
by a seq-chunked formulation — ``lax.scan`` over chunks carrying the SSM
state, with an associative scan (Mamba-1) or the SSD matmul form
(Mamba-2) inside each chunk, so the materialized working set stays
VMEM/HBM-friendly and the intra-chunk math lands on the MXU.
``repro.kernels.ssm_scan`` provides the Pallas kernel for the hot loop;
these jnp paths are its oracle and the dry-run lowering.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.specs import shard
from .layers import _make, dt as _dt

Params = Dict[str, Any]


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def mamba_heads(cfg: ModelConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, (cfg.d_model + 15) // 16)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def mamba_params(cfg: ModelConfig, rng=None, abstract=False) -> Params:
    d, din, st = cfg.d_model, d_inner(cfg), cfg.ssm_state
    if cfg.mamba_version == 1:
        r = dt_rank(cfg)
        shapes = {
            "in_proj": (d, 2 * din),
            "conv_w": (cfg.ssm_conv, din),
            "conv_b": (din,),
            "x_proj": (din, r + 2 * st),
            "dt_proj": (r, din),
            "dt_bias": (din,),
            "A_log": (din, st),
            "D": (din,),
            "out_proj": (din, d),
        }
    else:
        h = mamba_heads(cfg)
        conv_dim = din + 2 * st
        shapes = {
            "in_proj": (d, 2 * din + 2 * st + h),   # z, x, B, C, dt
            "conv_w": (cfg.ssm_conv, conv_dim),
            "conv_b": (conv_dim,),
            "dt_bias": (h,),
            "A_log": (h,),
            "D": (h,),
            "norm_w": (din,),
            "out_proj": (din, d),
        }
    p = _make(shapes, cfg, rng, abstract, fan_in=d)
    if not abstract and rng is not None:
        # S4-style dt/A init keeps the scan stable at init time
        if cfg.mamba_version == 1:
            a = jnp.broadcast_to(jnp.arange(1, st + 1, dtype=jnp.float32),
                                 (din, st))
            p["A_log"] = jnp.log(a).astype(_dt(cfg))
        else:
            p["A_log"] = jnp.zeros((mamba_heads(cfg),), _dt(cfg))
            p["norm_w"] = jnp.ones((din,), _dt(cfg))
        p["dt_bias"] = jnp.full(p["dt_bias"].shape,
                                math.log(math.expm1(0.01)), _dt(cfg))
        p["D"] = jnp.ones(p["D"].shape, _dt(cfg))
    return p


def mamba_specs(cfg: ModelConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    if cfg.mamba_version == 1:
        return {"in_proj": ("embed", "ff"), "conv_w": (None, "ff"),
                "conv_b": ("ff",), "x_proj": ("ff", None),
                "dt_proj": (None, "ff"), "dt_bias": ("ff",),
                "A_log": ("ff", None), "D": ("ff",),
                "out_proj": ("ff", "embed")}
    return {"in_proj": ("embed", "ff"), "conv_w": (None, "ff"),
            "conv_b": ("ff",), "dt_bias": ("heads",), "A_log": ("heads",),
            "D": ("heads",), "norm_w": ("ff",), "out_proj": ("ff", "embed")}


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """x: (B,S,C); w: (K,C). Returns (out, new_state)."""
    k = w.shape[0]
    if state is not None:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(k - 1):, :]
    else:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_state = None
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + b[None, None, :]), new_state


# ---------------------------------------------------------------------------
# Mamba-1: S6 selective scan (chunked associative scan)
# ---------------------------------------------------------------------------

def mamba1_forward(params: Params, x: jax.Array, cfg: ModelConfig,
                   state: Optional[Dict[str, jax.Array]] = None
                   ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B,S,d). state (decode): {"h": (B,din,st), "conv": (B,K-1,din)}."""
    b, s, d = x.shape
    din, st = d_inner(cfg), cfg.ssm_state
    xz = x @ params["in_proj"]
    xs, z = xz[..., :din], xz[..., din:]
    xs = shard(xs, "batch", "seq", "ff")

    conv_state = state["conv"] if state is not None else None
    xs, new_conv = _causal_conv(xs, params["conv_w"], params["conv_b"],
                                conv_state)

    proj = xs @ params["x_proj"]                           # (B,S,r+2st)
    r = dt_rank(cfg)
    dt_raw, Bc, Cc = proj[..., :r], proj[..., r:r + st], proj[..., r + st:]
    dt_v = jax.nn.softplus(dt_raw @ params["dt_proj"]
                           + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))      # (din, st)

    if state is not None and s == 1:                        # decode step
        h0 = state["h"]
        da = jnp.exp(dt_v[:, 0, :, None] * A[None])         # (B,din,st)
        dbx = (dt_v[:, 0, :, None] * Bc[:, 0, None, :]
               * xs[:, 0, :, None].astype(jnp.float32))
        h = da * h0 + dbx
        y = jnp.einsum("bds,bs->bd", h, Cc[:, 0].astype(jnp.float32))
        y = y + params["D"].astype(jnp.float32) * xs[:, 0].astype(jnp.float32)
        y = (y[:, None, :]).astype(x.dtype)
        new_state = {"h": h, "conv": new_conv}
    else:
        h0 = state["h"] if state is not None else None
        y, h_last = _scan_chunks_m1(xs, dt_v, Bc, Cc, A, params["D"], cfg, h0)
        new_state = ({"h": h_last, "conv": new_conv}
                     if state is not None else None)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return shard(out, "batch", "seq", "act_embed"), new_state


def _scan_chunks_m1(xs, dt_v, Bc, Cc, A, D, cfg: ModelConfig,
                    h0: Optional[jax.Array] = None):
    b, s, din = xs.shape
    st = A.shape[1]
    c = min(cfg.ssm_chunk, s)
    n = s // c
    # (n, B, c, ...) chunked
    def chop(t):
        return t[:, :n * c].reshape(b, n, c, *t.shape[2:]).swapaxes(0, 1)

    xs_c, dt_c, B_c, C_c = map(chop, (xs, dt_v, Bc, Cc))

    scan_dt = jnp.dtype(cfg.ssm_scan_dtype)

    def chunk_step(h, inp):
        xck, dtk, Bk, Ck = inp
        da = jnp.exp(dtk[..., None] * A[None, None])         # (B,c,din,st)
        dbx = (dtk[..., None] * Bk[:, :, None, :]
               * xck[..., None].astype(jnp.float32))
        # associative scan within the chunk: h_t = da_t h_{t-1} + dbx_t
        # (elements materialized in cfg.ssm_scan_dtype; carry stays f32)
        def op(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2
        a_s, b_s = jax.lax.associative_scan(
            op, (da.astype(scan_dt), dbx.astype(scan_dt)), axis=1)
        # stay in scan_dt end-to-end: converting the (B,c,d,N) tree output
        # to f32 would re-materialize the full slab (measured, §Perf-A)
        hs = a_s * h[:, None].astype(scan_dt) + b_s          # (B,c,din,st)
        y = jnp.einsum("bcds,bcs->bcd", hs, Ck.astype(scan_dt),
                       preferred_element_type=jnp.float32)
        return hs[:, -1].astype(jnp.float32), y

    if h0 is None:
        h0 = jnp.zeros((b, din, st), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_step, h0, (xs_c, dt_c, B_c, C_c))
    y = ys.swapaxes(0, 1).reshape(b, n * c, din)
    y = y + D.astype(jnp.float32)[None, None, :] * xs.astype(jnp.float32)
    return y.astype(xs.dtype), h_last


# ---------------------------------------------------------------------------
# Mamba-2: SSD (chunked matmul form)
# ---------------------------------------------------------------------------

def mamba2_forward(params: Params, x: jax.Array, cfg: ModelConfig,
                   state: Optional[Dict[str, jax.Array]] = None
                   ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B,S,d). state: {"h": (B,H,P,st), "conv": (B,K-1,din+2st)}."""
    b, s, d = x.shape
    din, st = d_inner(cfg), cfg.ssm_state
    h_n, p_d = mamba_heads(cfg), cfg.ssm_head_dim

    proj = x @ params["in_proj"]
    z = proj[..., :din]
    xBC = proj[..., din:2 * din + 2 * st]
    dt_raw = proj[..., 2 * din + 2 * st:]
    conv_state = state["conv"] if state is not None else None
    xBC, new_conv = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                                 conv_state)
    xs = shard(xBC[..., :din], "batch", "seq", "ff")
    Bc, Cc = xBC[..., din:din + st], xBC[..., din + st:]
    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32)
                           + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))                 # (H,)
    xh = xs.reshape(b, s, h_n, p_d)

    if state is not None and s == 1:
        h0 = state["h"]                                     # (B,H,P,st)
        da = jnp.exp(dt_v[:, 0] * A[None])                  # (B,H)
        dbx = jnp.einsum("bhp,bs->bhps",
                         (dt_v[:, 0, :, None] * xh[:, 0].astype(jnp.float32)),
                         Bc[:, 0].astype(jnp.float32))
        h = da[..., None, None] * h0 + dbx
        y = jnp.einsum("bhps,bs->bhp", h, Cc[:, 0].astype(jnp.float32))
        y = y + params["D"].astype(jnp.float32)[None, :, None] \
            * xh[:, 0].astype(jnp.float32)
        y = y.reshape(b, 1, din).astype(x.dtype)
        new_state = {"h": h, "conv": new_conv}
    else:
        h0 = state["h"] if state is not None else None
        y, h_last = _ssd_chunks(xh, dt_v, Bc, Cc, A, params["D"], cfg, h0)
        new_state = ({"h": h_last, "conv": new_conv}
                     if state is not None else None)
    y = _gated_rmsnorm(y, jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return shard(out, "batch", "seq", "act_embed"), new_state


def _gated_rmsnorm(y, gate, w, eps):
    orig = y.dtype
    y = y.astype(jnp.float32) * gate.astype(jnp.float32)
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(orig)


def _segsum(logd: jax.Array) -> jax.Array:
    """log decay(i<-j) = sum_{t=j+1..i} logd_t, lower-triangular."""
    c = logd.shape[-1]
    cs = jnp.cumsum(logd, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]              # (.., i, j)
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunks(xh, dt_v, Bc, Cc, A, D, cfg: ModelConfig,
                h0: Optional[jax.Array] = None):
    b, s, h_n, p_d = xh.shape
    st = Bc.shape[-1]
    c = min(cfg.ssm_chunk, s)
    n = s // c

    def chop(t):
        return t[:, :n * c].reshape(b, n, c, *t.shape[2:]).swapaxes(0, 1)

    x_c = chop(xh.astype(jnp.float32))                      # (n,B,c,H,P)
    dt_c = chop(dt_v)                                       # (n,B,c,H)
    B_cc = chop(Bc.astype(jnp.float32))                     # (n,B,c,st)
    C_cc = chop(Cc.astype(jnp.float32))

    def chunk_step(hprev, inp):
        xk, dtk, Bk, Ck = inp
        logd = dtk * A[None, None, :]                       # (B,c,H)
        logd_t = jnp.swapaxes(logd, 1, 2)                   # (B,H,c)
        seg = _segsum(logd_t)                               # (B,H,c,c)
        # intra-chunk (attention-like, MXU):
        cb = jnp.einsum("bis,bjs->bij", Ck, Bk)             # (B,c,c)
        scores = cb[:, None] * jnp.exp(seg)                 # (B,H,c,c)
        xdt = xk * dtk[..., None]                           # (B,c,H,P)
        y_intra = jnp.einsum("bhij,bjhp->bihp", scores, xdt)
        # inter-chunk: contribution of carried state
        dcum = jnp.cumsum(logd_t, axis=-1)                  # (B,H,c)
        y_inter = jnp.einsum("bihs,bhps->bihp",
                             Ck[:, :, None, :] * jnp.exp(dcum)[..., None]
                             .swapaxes(1, 2),
                             hprev)
        # new carried state
        dlast = dcum[..., -1:]                              # (B,H,1)
        w_state = jnp.exp(dlast - dcum)                     # decay j->end
        hk = jnp.einsum("bjhp,bjs->bhps",
                        xdt * jnp.swapaxes(w_state, 1, 2)[..., None], Bk)
        h_new = hprev * jnp.exp(dlast)[..., None] + hk
        return h_new, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((b, h_n, p_d, st), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_step, h0, (x_c, dt_c, B_cc, C_cc))
    y = ys.swapaxes(0, 1).reshape(b, n * c, h_n, p_d)
    y = y + D.astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    return y.reshape(b, n * c, h_n * p_d).astype(xh.dtype), h_last


def mamba_forward(params: Params, x: jax.Array, cfg: ModelConfig,
                  state: Optional[Dict[str, jax.Array]] = None):
    if cfg.mamba_version == 1:
        return mamba1_forward(params, x, cfg, state)
    return mamba2_forward(params, x, cfg, state)


def mamba_state_shapes(cfg: ModelConfig, batch: int) -> Dict[str, Tuple]:
    din, st, k = d_inner(cfg), cfg.ssm_state, cfg.ssm_conv
    if cfg.mamba_version == 1:
        return {"h": (batch, din, st), "conv": (batch, k - 1, din)}
    return {"h": (batch, mamba_heads(cfg), cfg.ssm_head_dim, st),
            "conv": (batch, k - 1, din + 2 * st)}
