"""Hybrid SSM + shared-attention backbone (zamba2-style).

N Mamba-2 blocks with ONE shared transformer block (attention + MLP whose
weights are reused) invoked every ``cfg.shared_attn_every`` SSM blocks.
The SSM stack is scanned in groups so the shared block can be interleaved
without unrolling all layers: ceil(N/k) groups of (<=k scanned mamba
layers, then the shared block).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import layers as L
from . import ssm as S

Params = Dict[str, Any]


def _norm(cfg, abstract):
    if abstract:
        return jax.ShapeDtypeStruct((cfg.d_model,), L.dt(cfg))
    return jnp.ones((cfg.d_model,), L.dt(cfg))


def _ssm_layer_params(cfg, rng, abstract):
    return {"ln": _norm(cfg, abstract),
            "mamba": S.mamba_params(cfg, rng, abstract)}


def init_params(cfg: ModelConfig, rng=None, abstract: bool = False) -> Params:
    if abstract:
        one = _ssm_layer_params(cfg, None, True)
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + s.shape,
                                           s.dtype), one)
        r1 = r2 = r3 = None
    else:
        r0, r1, r2, r3 = jax.random.split(rng, 4)
        rngs = jax.random.split(r0, cfg.num_layers)
        stacked = jax.vmap(lambda r: _ssm_layer_params(cfg, r, False))(rngs)
    out = {
        "embed": L.embed_params(cfg, r3, abstract),
        "layers": stacked,
        "ln_f": _norm(cfg, abstract),
    }
    if cfg.shared_attn_every > 0:
        out["shared"] = {
            "ln1": _norm(cfg, abstract),
            "attn": L.attention_params(cfg, r1, abstract),
            "ln2": _norm(cfg, abstract),
            "mlp": L.mlp_params(cfg, cfg.d_ff, r2, abstract),
        }
    return out


def param_pspecs(cfg: ModelConfig) -> Params:
    layer = {"ln": (None,), "mamba": S.mamba_specs(cfg)}
    stacked = jax.tree.map(lambda sp: ("layers",) + tuple(sp), layer,
                           is_leaf=lambda x: isinstance(x, tuple))
    out = {"embed": L.embed_specs(cfg), "layers": stacked, "ln_f": (None,)}
    if cfg.shared_attn_every > 0:
        out["shared"] = {"ln1": (None,), "attn": L.attention_specs(cfg),
                         "ln2": (None,), "mlp": L.mlp_specs(cfg)}
    return out


def num_shared_sites(cfg: ModelConfig) -> int:
    k = cfg.shared_attn_every
    return (cfg.num_layers + k - 1) // k if k else 0


def _group_bounds(cfg: ModelConfig):
    k = cfg.shared_attn_every or cfg.num_layers
    bounds = []
    i = 0
    while i < cfg.num_layers:
        bounds.append((i, min(i + k, cfg.num_layers)))
        i += k
    return bounds


def _slice_layers(params_stacked, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], params_stacked)


def _shared_block(cfg, sp, x, positions, impl, cache=None, cache_index=None):
    h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
    a, new_cache = L.attention(sp["attn"], h, cfg, positions=positions,
                               causal=True, cache=cache,
                               cache_index=cache_index, impl=impl)
    x = x + a
    h = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
    return x + L.mlp(sp["mlp"], h, cfg), new_cache


def backbone(params: Params, x: jax.Array, cfg: ModelConfig, *,
             positions: jax.Array, impl: str = "full",
             state: Optional[Dict] = None, attn_cache: Optional[Dict] = None,
             cache_index=None) -> Tuple[jax.Array, Optional[Dict], Optional[Dict]]:
    """state: stacked SSM states (L, ...); attn_cache: {"k","v"} with a
    leading shared-site axis (G, B, S, hkv, hd)."""

    decode = state is not None
    new_states = [] if decode else None
    new_k, new_v = ([], []) if attn_cache is not None else (None, None)

    def ssm_body(carry, xs):
        if decode:
            lp, st = xs
        else:
            lp, st = xs, None
        h = L.rms_norm(carry, lp["ln"], cfg.norm_eps)
        out, new_st = S.mamba_forward(lp["mamba"], h, cfg, st)
        res = carry + out
        return res, (new_st if decode else None)

    body = ssm_body if decode else _maybe_remat(cfg, ssm_body)
    shared_fn = _shared_block
    if not decode and cfg.remat != "none":
        # the shared block is invoked at ~N/k unrolled sites; without remat
        # every site's flash intermediates stay live through the backward
        shared_fn = jax.checkpoint(
            _shared_block, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(0, 4))

    use_shared = cfg.shared_attn_every > 0
    for g, (lo, hi) in enumerate(_group_bounds(cfg)):
        lp = _slice_layers(params["layers"], lo, hi)
        if decode:
            st = jax.tree.map(lambda a, lo=lo, hi=hi: a[lo:hi], state)
            x, new_st = jax.lax.scan(body, x, (lp, st))
            new_states.append(new_st)
        else:
            x, _ = jax.lax.scan(body, x, lp)
        if not use_shared:
            continue
        cache_g = None
        if attn_cache is not None:
            cache_g = (attn_cache["k"][g], attn_cache["v"][g])
        x, ncache = shared_fn(cfg, params["shared"], x, positions, impl,
                              cache=cache_g, cache_index=cache_index)
        if attn_cache is not None:
            new_k.append(ncache[0])
            new_v.append(ncache[1])

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    out_state = (jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_states)
                 if decode else None)
    out_cache = ({"k": jnp.stack(new_k), "v": jnp.stack(new_v)}
                 if (attn_cache is not None and use_shared) else attn_cache)
    return x, out_state, out_cache


def _maybe_remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def train_loss(params: Params, batch: Dict[str, jax.Array],
               cfg: ModelConfig, *, impl: str = "full") -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    x = L.embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    h, _, _ = backbone(params, x, cfg, positions=positions, impl=impl)
    return L.chunked_ce_loss(params["embed"], h, labels, cfg)


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dtype = L.dt(cfg)
    st = S.mamba_state_shapes(cfg, batch)
    out = {
        "state": {
            "h": jax.ShapeDtypeStruct(
                (cfg.num_layers,) + st["h"], jnp.float32),
            "conv": jax.ShapeDtypeStruct(
                (cfg.num_layers,) + st["conv"], dtype),
        },
    }
    g = num_shared_sites(cfg)
    if g:
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        out["attn"] = {
            "k": jax.ShapeDtypeStruct((g, batch, max_len, hkv, hd), dtype),
            "v": jax.ShapeDtypeStruct((g, batch, max_len, hkv, hd), dtype),
        }
    return out


def cache_pspecs(cfg: ModelConfig) -> Dict[str, Any]:
    out = {
        "state": {"h": ("layers", "batch", "heads", None, None)
                  if cfg.mamba_version == 2 else
                  ("layers", "batch", "ff", None),
                  "conv": ("layers", "batch", None, "ff")},
    }
    if num_shared_sites(cfg):
        out["attn"] = {"k": (None, "batch", "kv_seq", "kv_heads", None),
                       "v": (None, "batch", "kv_seq", "kv_heads", None)}
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, max_len))


def forward_with_cache(params: Params, tokens: jax.Array, cache: Dict,
                       cfg: ModelConfig, cache_index, *,
                       impl: str = "full") -> Tuple[jax.Array, Dict]:
    x = L.embed(params["embed"], tokens, cfg)
    s = x.shape[1]
    positions = cache_index + jnp.broadcast_to(jnp.arange(s), x.shape[:2])
    h, new_state, new_attn = backbone(
        params, x, cfg, positions=positions, impl=impl,
        state=cache["state"], attn_cache=cache.get("attn"),
        cache_index=cache_index)
    logits = L.logits_fn(params["embed"], h[:, -1:], cfg)[:, 0]
    out = {"state": new_state}
    if new_attn is not None:
        out["attn"] = new_attn
    return logits, out
