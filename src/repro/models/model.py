"""Unified model facade dispatching on ModelConfig.family.

Public surface used by train/serve/launch:

    m = Model(cfg)
    params   = m.init(rng)                      # real weights (small cfgs)
    aparams  = m.abstract_params()              # ShapeDtypeStructs (dry-run)
    pspecs   = m.param_pspecs()                 # logical-axis tuples
    loss     = m.train_loss(params, batch)
    logits, cache = m.prefill(params, batch)    # fills the KV/SSM cache
    logits, cache = m.decode_step(params, cache, tokens, index)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import encdec, hybrid, transformer

Params = Dict[str, Any]


def _module(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec
    if cfg.family in ("ssm", "hybrid"):
        return hybrid
    return transformer        # dense | moe | vlm


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    attn_impl: str = "full"   # "full" (baseline) | "tri" (§Perf optimized)
    # Pallas decode-attention kernel: None = auto (kernel iff the cache
    # length is tileable; compiled on TPU, interpreted elsewhere),
    # True/False pins it.  Serving threads this through to the kernel.
    decode_use_kernel: Optional[bool] = None

    # -- parameters ----------------------------------------------------------
    def init(self, rng) -> Params:
        return _module(self.cfg).init_params(self.cfg, rng, abstract=False)

    def abstract_params(self) -> Params:
        return _module(self.cfg).init_params(self.cfg, None, abstract=True)

    def param_pspecs(self) -> Params:
        return _module(self.cfg).param_pspecs(self.cfg)

    # -- training --------------------------------------------------------------
    def train_loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        return _module(self.cfg).train_loss(params, batch, self.cfg,
                                            impl=self.attn_impl)

    # -- serving ----------------------------------------------------------------
    def cache_shapes(self, batch: int, max_len: int):
        return _module(self.cfg).cache_shapes(self.cfg, batch, max_len)

    def cache_pspecs(self):
        return _module(self.cfg).cache_pspecs(self.cfg)

    def init_cache(self, batch: int, max_len: int):
        return _module(self.cfg).init_cache(self.cfg, batch, max_len)

    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                cache) -> Tuple[jax.Array, Any]:
        """Process the prompt, filling the cache from position 0."""
        cfg = self.cfg
        mod = _module(cfg)
        idx = jnp.zeros((), jnp.int32)
        if cfg.family == "encdec":
            return mod.forward_with_cache(params, batch["tokens"], cache, cfg,
                                          idx, frames=batch["frames"],
                                          impl=self.attn_impl)
        if cfg.family == "vlm":
            return mod.forward_with_cache(params, batch["tokens"], cache, cfg,
                                          idx,
                                          image_embeds=batch["image_embeds"],
                                          impl=self.attn_impl)
        return mod.forward_with_cache(params, batch["tokens"], cache, cfg,
                                      idx, impl=self.attn_impl)

    @property
    def supports_per_slot_decode(self) -> bool:
        """True when decode_step accepts a (B,) per-slot index array
        (transformer families; SSM/hybrid/enc-dec decode in lockstep)."""
        return _module(self.cfg) is transformer

    @property
    def supports_chunked_prefill(self) -> bool:
        """True when ``prefill_chunk`` can continue a prefill mid-cache
        (standard-attention transformers; MLA's absorbed cache and the
        SSM/enc-dec families have no chunk continuation path)."""
        return _module(self.cfg) is transformer and not self.cfg.mla_kv_lora

    def prefill_chunk(self, params: Params, tokens: jax.Array, cache,
                      index) -> Tuple[jax.Array, Any]:
        """One fixed-shape prefill segment starting at scalar cache
        position ``index``.  Queries attend over the whole cache (earlier
        chunks included) under the absolute causal mask; returns
        ALL-position logits (B, S, V) so the caller can pick the true
        last prompt column when the final segment is right-padded.
        Because every call shares the segment shape, a whole admit
        retraces nothing after the first chunk ever processed."""
        return transformer.forward_with_cache(
            params, tokens, cache, self.cfg, index, impl=self.attn_impl,
            decode_kernel=self.decode_use_kernel, chunk=True)

    # -- KV-cache blocks (DHT data plane, DESIGN.md §11) ---------------------
    @property
    def supports_kv_blocks(self) -> bool:
        """True when the KV cache can be exported/imported as fixed-shape
        position-range blocks (standard-attention transformers; MLA's
        absorbed cache and SSM state are not position-sliceable)."""
        return self.supports_chunked_prefill

    def kv_block_shape(self, chunk: int):
        """(2, chunk, layers, kv_heads, head_dim) slab shape — k and v
        stacked — for one ``chunk``-position cache block."""
        self._require_kv_blocks()
        return transformer.kv_block_shape(self.cfg, chunk)

    def export_kv_block(self, cache, row: int, off: int, chunk: int):
        """Host numpy slab of cache positions [off, off+chunk) for batch
        row ``row`` (the replicated data plane's wire format)."""
        self._require_kv_blocks()
        return transformer.export_kv_block(self.cfg, cache, row, off, chunk)

    def import_kv_block(self, cache, row: int, off: int, block):
        """Write an exported slab back into a cache (bit-faithful: decode
        from the merged cache is token-identical to the exporter's)."""
        self._require_kv_blocks()
        return transformer.import_kv_block(self.cfg, cache, row, off, block)

    def cache_with_blocks(self, max_len: int, blocks):
        """Fresh 1-row cache pre-filled with a contiguous slab run from
        position 0 — one host assembly + one device transfer per k/v,
        instead of a dispatched set per block (the admit-latency floor
        for cache handoffs and prefix-cache hits)."""
        self._require_kv_blocks()
        return transformer.cache_with_blocks(self.cfg, max_len, blocks)

    def _require_kv_blocks(self) -> None:
        if not self.supports_kv_blocks:
            raise NotImplementedError(
                f"family {self.cfg.family} has no KV block export path")

    def decode_step(self, params: Params, cache, tokens: jax.Array,
                    index) -> Tuple[jax.Array, Any]:
        """One token per sequence.  ``index`` is the current cache length:
        a scalar steps every row in lockstep; a (B,) array steps each slot
        at its OWN position (continuous batching over mixed-length
        sessions; only when ``supports_per_slot_decode``)."""
        mod = _module(self.cfg)
        if mod is transformer:
            return mod.forward_with_cache(
                params, tokens, cache, self.cfg, index, impl=self.attn_impl,
                decode_kernel=self.decode_use_kernel)
        return mod.forward_with_cache(
            params, tokens, cache, self.cfg, index, impl=self.attn_impl)

    # -- dry-run helpers ------------------------------------------------------------
    def param_count(self) -> int:
        return self.cfg.param_count()
