"""Tensor-parallel replica groups: one ring node = a device sub-mesh.

A ``TPReplicaGroup`` runs an unmodified transformer under ``shard_map``
on a 1-D ("model",) sub-mesh from ``launch.mesh.replica_groups``.  The
sharding map is mesh-transformer-jax style:

  * column-parallel: wq/wk/wv (heads), mlp w1/w3 (ff), expert w1/w3
    (moe_ff), lm_head (vocab), embedding rows (vocab);
  * row-parallel:    wo (heads), mlp w2 (ff), expert w2 (moe_ff) — each
    followed by ONE psum (the ``psum_tp`` hooks in ``models.layers``);
  * KV cache:        k/v sharded on kv_heads, so per-device cache bytes
    drop 1/TP (MLA's compressed c/r caches replicate; only its heads
    shard);
  * MoE:             experts replicate (the router must pick identical
    slots on every device) while the expert ff dim shards — the
    ``TP_RULES`` overrides below.

The trick that keeps the model code unmodified: inside the shard_map
body every array is already the LOCAL shard, so the group calls the
model with a cfg whose head counts are divided by tp — the same
forward code then "just works" on local shapes, and ``tp_context``
activates the psum/axis-index hooks (and turns interior ``shard()``
constraints into no-ops).  Because weight shards are exact row/column
partitions and psum reduces in a deterministic order, decode tokens
are identical to single-device execution.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding import specs as sh
from repro.sharding.collectives import shard_map_compat
from . import transformer
from .model import Model

# Logical-rule overrides for the 1-axis ("model") group mesh; every
# other DEFAULT_RULES entry resolves naturally (heads/kv_heads/ff/vocab
# -> "model"; batch/embed/moe_embed reference only absent axes and
# filter to replicated).
TP_RULES: Dict[str, Any] = {"experts": None, "moe_ff": "model",
                            "moe_embed": None}

_TP_FAMILIES = ("dense", "moe")


def validate_tp(cfg, tp: int) -> None:
    """Reject configs a ``tp``-way group cannot shard exactly.  Partial
    shards would silently change math; every sharded dim must divide."""
    if tp < 1:
        raise ValueError(f"tp={tp} must be >= 1")
    if cfg.family not in _TP_FAMILIES:
        raise ValueError(
            f"tensor parallelism covers the transformer families "
            f"{_TP_FAMILIES}, not family={cfg.family!r}")

    def div(name: str, val: int) -> None:
        if val % tp:
            raise ValueError(
                f"tp={tp} must divide cfg.{name}={val} exactly "
                f"(a partial shard would change the math)")

    div("num_heads", cfg.num_heads)
    div("vocab", cfg.vocab)
    if not cfg.mla_kv_lora:
        div("num_kv_heads", cfg.num_kv_heads)
    if cfg.moe_experts:
        div("moe_d_ff", cfg.moe_d_ff)
    else:
        div("d_ff", cfg.d_ff)


class TPReplicaGroup:
    """Compiled TP execution plane for one replica group (sub-mesh).

    Owns the resolved param/cache shardings and the jitted shard_map
    programs (prefill, chunked prefill, full-slab decode, bucketized
    slot decode) for ``model`` on ``mesh``.  ``ServeCluster`` keeps one
    instance per group index, so a replica restarted onto the same
    group reuses every compiled executable.
    """

    def __init__(self, model: Model, mesh: Mesh, *, axis: str = "model"):
        if len(mesh.axis_names) != 1 or mesh.axis_names[0] != axis:
            raise ValueError(
                f"replica group mesh must be 1-D over ({axis!r},), got "
                f"{mesh.axis_names}")
        self.model = model
        self.mesh = mesh
        self.axis = axis
        self.tp = mesh.devices.size
        cfg = model.cfg
        validate_tp(cfg, self.tp)
        over: Dict[str, Any] = {
            "num_heads": cfg.num_heads // self.tp,
            # pin head_dim: the default derives it from d_model/num_heads,
            # which would silently grow under the local head count
            "head_dim": cfg.resolved_head_dim,
        }
        if not cfg.mla_kv_lora:
            over["num_kv_heads"] = cfg.num_kv_heads // self.tp
        self.local_model = dataclasses.replace(
            model, cfg=cfg.with_overrides(**over))

        def is_tup(x):
            return isinstance(x, tuple)

        with sh.mesh_context(mesh, TP_RULES):
            self._param_specs = jax.tree.map(
                lambda t: sh.logical_spec(*t), model.param_pspecs(),
                is_leaf=is_tup)
            self._param_shardings = jax.tree.map(
                lambda t: NamedSharding(mesh, sh.logical_spec(*t)),
                model.param_pspecs(), is_leaf=is_tup)
            self._cache_specs = {
                k: sh.logical_spec(*t)
                for k, t in model.cache_pspecs().items()}
        self._cache_shardings = {
            k: NamedSharding(mesh, s) for k, s in self._cache_specs.items()}
        self._fns: Optional[Tuple] = None

    # -- parameters / cache ---------------------------------------------------
    def shard_params(self, params):
        """Lay global params out over the group: each device receives
        only its row/column shard of every weight."""
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), params,
            self._param_shardings)

    def init_cache(self, batch: int, max_len: int):
        shapes = self.model.cache_shapes(batch, max_len)
        return {
            k: jax.device_put(jnp.zeros(s.shape, s.dtype),
                              self._cache_shardings[k])
            for k, s in shapes.items()}

    def cache_with_blocks(self, max_len: int, blocks):
        """Host slab run -> fresh 1-row cache landed straight under the
        group's kv_heads sharding (each device gets only its slice)."""
        return transformer.cache_with_blocks(
            self.model.cfg, max_len, blocks, shardings=self._cache_shardings)

    def export_kv_block(self, cache, row: int, off: int, chunk: int):
        """Full (shard-concatenated) slab — the prefix cache's
        content-addressed format, importable by any tp degree."""
        return transformer.export_kv_block(self.model.cfg, cache, row, off,
                                           chunk)

    def export_kv_shards(self, cache, row: int, off: int,
                         chunk: int) -> List[np.ndarray]:
        """Per-device slabs (shard s = kv_heads slice held by device s) —
        the per-shard KVB1 handoff wire format."""
        return transformer.export_kv_block_shards(
            self.model.cfg, cache, row, off, chunk, self.tp)

    def per_device_cache_bytes(self, cache) -> int:
        """Bytes one device holds for ``cache`` (1/TP of the global
        cache for sharded k/v) — asserted by the tp bench/tests."""
        return sum(leaf.addressable_shards[0].data.nbytes
                   for leaf in jax.tree.leaves(cache))

    def device_ids(self) -> List[int]:
        return [d.id for d in self.mesh.devices.reshape(-1)]

    # -- compiled programs ----------------------------------------------------
    def fns(self) -> Tuple:
        """(prefill, decode_full, decode_slots, prefill_chunk) — the
        shard_map analogues of ``serve.server._jitted``'s unfused
        programs, built once per group."""
        if self._fns is None:
            self._fns = self._build_fns()
        return self._fns

    def _build_fns(self) -> Tuple:
        lm = self.local_model
        axis, mesh = self.axis, self.mesh
        pP, cP = self._param_specs, self._cache_specs
        logit1 = P(None, axis)          # (B, V): logits stay vocab-sharded
        logit2 = P(None, None, axis)    # (B, S, V) all-position chunk logits

        def rep(n: int) -> P:
            return P(*([None] * n))

        def wrap(f, in_specs, out_specs):
            def inner(*args):
                with sh.tp_context(axis):
                    return f(*args)
            return jax.jit(shard_map_compat(inner, mesh, in_specs,
                                            out_specs))

        prefill = wrap(lambda p, b, c: lm.prefill(p, b, c),
                       (pP, {"tokens": rep(2)}, cP), (logit1, cP))
        prefill_chunk = None
        if lm.supports_chunked_prefill:
            prefill_chunk = wrap(
                lambda p, t, c, i: lm.prefill_chunk(p, t, c, i),
                (pP, rep(2), cP, P()), (logit2, cP))
        decode_full = wrap(
            lambda p, c, t, n: lm.decode_step(p, c, t, n),
            (pP, cP, rep(2), rep(1)), (logit1, cP))

        def slots_body(p, c, t, n, idx):
            # mirrors _jitted.decode_slots exactly (bit-identical decode):
            # gather padded bucket rows, step them, scatter fresh KV back
            sub = jax.tree.map(
                lambda x: jnp.take(x, idx, axis=1, mode="fill",
                                   fill_value=0), c)
            tok = jnp.take(t, idx, axis=0, mode="fill", fill_value=0)
            ln = jnp.take(n, idx, axis=0, mode="fill", fill_value=0)
            logits, new_sub = lm.decode_step(p, sub, tok, ln)
            out = jax.tree.map(
                lambda x, s: x.at[:, idx].set(s, mode="drop"), c, new_sub)
            return logits, out

        decode_slots = wrap(slots_body,
                            (pP, cP, rep(2), rep(1), rep(1)), (logit1, cP))
        return prefill, decode_full, decode_slots, prefill_chunk
