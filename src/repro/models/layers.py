"""Model building blocks (pure JAX, shard-annotated, bf16-friendly).

Attention comes in two lowering strategies:
  * ``full``  — online-softmax flash over all (q-chunk, kv-chunk) pairs
                with causal masking (baseline; wastes ~2x score FLOPs on
                masked pairs, like a naive jnp implementation would);
  * ``tri``   — statically enumerated lower-triangular chunk pairs
                (exact-FLOP causal flash; the §Perf optimized path).
On real TPUs ``repro.kernels.flash_attention`` replaces both; the jnp
paths double as its oracle and as the dry-run lowering.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.specs import psum_tp, shard, tp_axis, tp_index

Params = Dict[str, Any]


def dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    orig = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(orig)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash attention (jnp reference paths; see repro.kernels for the TPU kernel)
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, d)) \
        .reshape(b, s, h * groups, d)


def _attn_block(q, k, v, m, l, acc, mask=None):
    """One online-softmax step. q:(B,H,Cq,hd) k,v:(B,H,Ck,hd)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * (1.0 / math.sqrt(q.shape[-1]))
    if mask is not None:
        s = jnp.where(mask, s, -1e30)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + p.sum(axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, chunk: int = 512,
                    impl: str = "full") -> jax.Array:
    """q: (B,Sq,H,hd), k/v: (B,Sk,Hkv,hd) -> (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    qt = jnp.swapaxes(q, 1, 2)              # (B,H,Sq,hd)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if impl == "tri" and causal and sq == sk and sq % chunk == 0:
        return _flash_tri(qt, kt, vt, chunk).swapaxes(1, 2)
    return _flash_full(qt, kt, vt, causal, chunk, sq, sk).swapaxes(1, 2)


def _flash_full(qt, kt, vt, causal, chunk, sq, sk):
    b, h, _, hd = qt.shape
    hv = vt.shape[-1]
    ck = min(chunk, sk)
    nk = (sk + ck - 1) // ck
    pad = nk * ck - sk
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kt.reshape(b, h, nk, ck, hd)
    vb = vt.reshape(b, h, nk, ck, hv)
    q_pos = jnp.arange(sq)

    def step(carry, j):
        m, l, acc = carry
        kj = kb[:, :, j]
        vj = vb[:, :, j]
        k_pos = j * ck + jnp.arange(ck)
        mask = (k_pos[None, :] < sk)
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        m, l, acc = _attn_block(qt, kj, vj, m, l, acc,
                                mask=mask[None, None, :, :])
        return (m, l, acc), None

    init = (jnp.full((b, h, sq), -1e30, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, hv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, jnp.arange(nk))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(qt.dtype)


def _flash_tri(qt, kt, vt, chunk):
    """Exact-FLOP causal flash: scan only lower-triangular chunk pairs."""
    b, h, s, hd = qt.shape
    hv = vt.shape[-1]
    n = s // chunk
    qb = qt.reshape(b, h, n, chunk, hd)
    kb = kt.reshape(b, h, n, chunk, hd)
    vb = vt.reshape(b, h, n, chunk, hv)
    pairs = np.array([(i, j) for i in range(n) for j in range(i + 1)],
                     dtype=np.int32)                       # (P, 2)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))

    def step(carry, pair):
        m, l, acc = carry                                   # (b,h,n,chunk[,hd])
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qb, i, axis=2, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, axis=2, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, axis=2, keepdims=False)
        mi = jax.lax.dynamic_index_in_dim(m, i, axis=2, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, axis=2, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, axis=2, keepdims=False)
        mask = jnp.where(i == j, tri, jnp.ones_like(tri))[None, None]
        mi, li, ai = _attn_block(qi, kj, vj, mi, li, ai, mask=mask)
        m = jax.lax.dynamic_update_index_in_dim(m, mi, i, axis=2)
        l = jax.lax.dynamic_update_index_in_dim(l, li, i, axis=2)
        acc = jax.lax.dynamic_update_index_in_dim(acc, ai, i, axis=2)
        return (m, l, acc), None

    init = (jnp.full((b, h, n, chunk), -1e30, jnp.float32),
            jnp.zeros((b, h, n, chunk), jnp.float32),
            jnp.zeros((b, h, n, chunk, hv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, jnp.asarray(pairs))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(qt.dtype)
    return out.reshape(b, h, s, hv)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: Optional[jax.Array] = None, *,
                     use_kernel: Optional[bool] = None) -> jax.Array:
    """Single-position attention over a KV cache.

    q: (B,1,H,hd); caches: (B,S,Hkv,hd). ``length`` (B,) masks valid
    positions *per row*, so every slot of a continuous-batching replica
    attends at its own cache position.  When the cache length is
    kernel-tileable the Pallas decode kernel streams it (compiled on TPU,
    interpreted elsewhere); ``use_kernel`` pins the choice.
    """
    b, _, h, hd = q.shape
    hkv = k_cache.shape[2]
    if use_kernel is None:
        from repro.kernels.backend import default_interpret
        from repro.kernels.decode_attention.kernel import BS as _BS
        # auto only picks the kernel where it COMPILES: interpret mode
        # exists for correctness, not speed — on non-TPU backends the jnp
        # reference path is ~2x faster, so it stays unless pinned
        use_kernel = length is not None and k_cache.shape[1] % _BS == 0 \
            and h % hkv == 0 and not default_interpret()
    if use_kernel:
        from repro.kernels.decode_attention.ops import \
            decode_attention as _kernel_decode
        out = _kernel_decode(q[:, 0], k_cache, v_cache,
                             jnp.asarray(length, jnp.int32))
        return out[:, None].astype(q.dtype)
    k = _repeat_kv(k_cache, h // hkv)
    v = _repeat_kv(v_cache, h // hkv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    if length is not None:
        pos = jnp.arange(k.shape[1])
        s = jnp.where(pos[None, None, None, :] < length[:, None, None, None],
                      s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Standard (GQA) attention layer
# ---------------------------------------------------------------------------

def attention_params(cfg: ModelConfig, rng=None, abstract=False,
                     cross: bool = False) -> Params:
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    shapes = {
        "wq": (d, h * hd),
        "wk": (d, hkv * hd),
        "wv": (d, hkv * hd),
        "wo": (h * hd, d),
    }
    if cfg.qkv_bias:
        shapes.update({"bq": (h * hd,), "bk": (hkv * hd,), "bv": (hkv * hd,)})
    return _make(shapes, cfg, rng, abstract, fan_in=d)


def attention_specs(cfg: ModelConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    sp = {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
          "wv": ("embed", "kv_heads"), "wo": ("heads", "embed")}
    if cfg.qkv_bias:
        sp.update({"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",)})
    return sp


def attention(params: Params, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, causal: bool = True,
              kv_x: Optional[jax.Array] = None,
              kv_positions: Optional[jax.Array] = None,
              cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None,
              use_rope: bool = True,
              impl: str = "full",
              decode_kernel: Optional[bool] = None,
              chunk: bool = False
              ) -> Tuple[jax.Array, Optional[Tuple]]:
    """GQA attention. Returns (out, new_cache).

    ``cache_index`` is either a scalar (prefill / lockstep decode: every
    row writes at the same position) or a (B,) array of per-slot cache
    positions (continuous-batching decode: each slot advances at its own
    length; requires s == 1).

    ``chunk`` marks a *continuation* prefill segment (chunked prefill,
    scalar ``cache_index`` > 0 allowed): the fresh queries must attend
    over the WHOLE cache — earlier chunks included — under the absolute
    causal mask ``pos_k <= pos_q``, not just the fresh segment.  The
    plain s > 1 path is only correct at offset 0.
    """
    b, s, d = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    src = x if kv_x is None else kv_x
    q = (x @ params["wq"] + params.get("bq", 0)).reshape(b, s, h, hd)
    k = (src @ params["wk"] + params.get("bk", 0)).reshape(b, src.shape[1], hkv, hd)
    v = (src @ params["wv"] + params.get("bv", 0)).reshape(b, src.shape[1], hkv, hd)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        kpos = positions if kv_positions is None else kv_positions
        k = rope(k, kpos, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "kv_seq", "kv_heads", None)
    v = shard(v, "batch", "kv_seq", "kv_heads", None)
    new_cache = None
    if cache is not None:
        k_cache, v_cache = cache
        idx = jnp.asarray(cache_index)
        if idx.ndim:                       # per-slot positions, s == 1 only
            rows = jnp.arange(b)
            k_cache = k_cache.at[rows, idx].set(k[:, 0].astype(k_cache.dtype))
            v_cache = v_cache.at[rows, idx].set(v[:, 0].astype(v_cache.dtype))
            lengths = idx + 1
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), idx, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), idx, axis=1)
            lengths = jnp.full((b,), idx + s)
        new_cache = (k_cache, v_cache)
        if s == 1:
            out = decode_attention(q, k_cache, v_cache, length=lengths,
                                   use_kernel=decode_kernel)
        elif chunk:
            # continuation chunk: attend over the full cache (earlier
            # chunks live below ``idx``) with the absolute causal mask.
            # Garbage rows at positions >= idx + s are masked out.
            kc = _repeat_kv(k_cache, h // hkv)
            vc = _repeat_kv(v_cache, h // hkv)
            sc = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                            preferred_element_type=jnp.float32)
            sc = sc * (1.0 / math.sqrt(hd))
            q_pos = idx + jnp.arange(s)
            k_pos = jnp.arange(kc.shape[1])
            sc = jnp.where((k_pos[None, :] <= q_pos[:, None])[None, None],
                           sc, -1e30)
            p = jax.nn.softmax(sc, axis=-1).astype(vc.dtype)
            out = jnp.einsum("bhqk,bkhd->bqhd", p, vc).astype(q.dtype)
        else:
            # prefill: attend over the fresh segment with flash (the cache
            # is being filled from scratch) — never materialize S x S
            out = flash_attention(q, k, v, causal=causal, impl=impl)
    else:
        out = flash_attention(q, k, v, causal=causal, impl=impl)
    out = out.reshape(b, s, h * hd)
    # row-parallel combine: under TP each device holds h/tp heads and the
    # matching wo rows, so the projection is a partial sum over heads
    out = psum_tp(out @ params["wo"])
    return shard(out, "batch", "seq", "act_embed"), new_cache


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v2) — compressed KV, shared rope key
# ---------------------------------------------------------------------------

def mla_params(cfg: ModelConfig, rng=None, abstract=False) -> Params:
    d, h = cfg.d_model, cfg.num_heads
    qk_n, qk_r, v_hd = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_head_dim
    r_kv, r_q = cfg.mla_kv_lora, cfg.mla_q_lora
    shapes = {
        "w_dkv": (d, r_kv + qk_r),                 # compress kv + shared rope k
        "w_ukv": (r_kv, h * (qk_n + v_hd)),        # decompress to k_nope, v
        "wo": (h * v_hd, d),
    }
    if r_q:
        shapes["w_dq"] = (d, r_q)
        shapes["w_uq"] = (r_q, h * (qk_n + qk_r))
    else:
        shapes["wq"] = (d, h * (qk_n + qk_r))
    return _make(shapes, cfg, rng, abstract, fan_in=d)


def mla_specs(cfg: ModelConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    sp = {"w_dkv": ("embed", None), "w_ukv": (None, "heads"),
          "wo": ("heads", "embed")}
    if cfg.mla_q_lora:
        sp.update({"w_dq": ("embed", None), "w_uq": (None, "heads")})
    else:
        sp["wq"] = ("embed", "heads")
    return sp


def mla_attention(params: Params, x: jax.Array, cfg: ModelConfig, *,
                  positions: jax.Array,
                  cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                  cache_index: Optional[jax.Array] = None,
                  impl: str = "full") -> Tuple[jax.Array, Optional[Tuple]]:
    b, s, d = x.shape
    h = cfg.num_heads
    qk_n, qk_r, v_hd = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_head_dim
    r_kv = cfg.mla_kv_lora

    if cfg.mla_q_lora:
        q = (x @ params["w_dq"]) @ params["w_uq"]
    else:
        q = x @ params["wq"]
    q = q.reshape(b, s, h, qk_n + qk_r)
    q_nope, q_rope = q[..., :qk_n], q[..., qk_n:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = x @ params["w_dkv"]                       # (b,s,r_kv+qk_r)
    c_kv, k_rope = ckv[..., :r_kv], ckv[..., r_kv:]
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        # MLA's serving win: cache only (c_kv, k_rope) — r_kv + qk_r per pos
        c_cache, r_cache = cache
        idx = jnp.asarray(cache_index)
        if idx.ndim:                       # per-slot positions, s == 1 only
            rows = jnp.arange(b)
            c_cache = c_cache.at[rows, idx].set(c_kv[:, 0].astype(c_cache.dtype))
            r_cache = r_cache.at[rows, idx].set(k_rope[:, 0].astype(r_cache.dtype))
        else:
            c_cache = jax.lax.dynamic_update_slice_in_dim(
                c_cache, c_kv.astype(c_cache.dtype), idx, axis=1)
            r_cache = jax.lax.dynamic_update_slice_in_dim(
                r_cache, k_rope.astype(r_cache.dtype), idx, axis=1)
        new_cache = (c_cache, r_cache)

    if cache is not None and s == 1:
        # absorbed decode: attention entirely in the compressed r_kv space
        # (never materializes per-head K/V over the 32k cache)
        w_ukv = params["w_ukv"].reshape(r_kv, h, qk_n + v_hd)
        w_uk, w_uv = w_ukv[..., :qk_n], w_ukv[..., qk_n:]
        q_c = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)     # (B,1,H,r_kv)
        c_cache, r_cache = new_cache
        scale = 1.0 / math.sqrt(qk_n + qk_r)
        s_c = jnp.einsum("bshr,bTr->bhsT", q_c, c_cache,
                         preferred_element_type=jnp.float32)
        s_r = jnp.einsum("bshr,bTr->bhsT", q_rope, r_cache,
                         preferred_element_type=jnp.float32)
        scores = (s_c + s_r) * scale
        pos = jnp.arange(c_cache.shape[1])
        lim = jnp.asarray(cache_index) + 1
        if lim.ndim:                       # per-slot lengths: (B,) -> (B,1,1,1)
            lim = lim[:, None, None, None]
        valid = pos[None, None, None, :] < lim
        scores = jnp.where(valid, scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1).astype(c_cache.dtype)
        out_c = jnp.einsum("bhsT,bTr->bshr", p, c_cache)     # (B,1,H,r_kv)
        out = jnp.einsum("bshr,rhv->bshv", out_c, w_uv)
    else:
        # train / prefill: expand K/V for this segment and run flash
        kv = (c_kv @ params["w_ukv"]).reshape(b, s, h, qk_n + v_hd)
        k_nope, v = kv[..., :qk_n], kv[..., qk_n:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (*k_nope.shape[:3], qk_r))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        qf = shard(qf, "batch", "seq", "heads", None)
        k = shard(k, "batch", "kv_seq", "heads", None)
        v = shard(v, "batch", "kv_seq", "heads", None)
        out = flash_attention(qf, k, v, causal=True, impl=impl)
    out = psum_tp(out.reshape(b, s, h * v_hd) @ params["wo"])
    return shard(out, "batch", "seq", "act_embed"), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    return jax.nn.gelu


def mlp_params(cfg: ModelConfig, d_ff: int, rng=None, abstract=False) -> Params:
    d = cfg.d_model
    shapes = {"w1": (d, d_ff), "w2": (d_ff, d)}
    if cfg.act == "silu":
        shapes["w3"] = (d, d_ff)
    return _make(shapes, cfg, rng, abstract, fan_in=d)


def mlp_specs(cfg: ModelConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    sp = {"w1": ("embed", "ff"), "w2": ("ff", "embed")}
    if cfg.act == "silu":
        sp["w3"] = ("embed", "ff")
    return sp


def mlp(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = _act(cfg.act)
    h = act(x @ params["w1"])
    if "w3" in params:
        h = h * (x @ params["w3"])
    h = shard(h, "batch", "seq", "ff")
    out = psum_tp(h @ params["w2"])          # row-parallel over the ff shard
    return shard(out, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# MoE block — sort-based dropping dispatch (GShard-style capacity), EP over
# the "experts" logical axis.  Expert-to-shard placement is a consistent-hash
# permutation from repro.runtime.placement (the D1HT ring decides ownership).
# ---------------------------------------------------------------------------

def moe_params(cfg: ModelConfig, rng=None, abstract=False) -> Params:
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    shapes = {
        "router": (d, e),
        "w1": (e, d, f),
        "w2": (e, f, d),
    }
    if cfg.act == "silu":
        shapes["w3"] = (e, d, f)
    if cfg.moe_weight_dtype == "int8":
        p = _make(shapes, cfg, rng, abstract, fan_in=d)
        out: Params = {"router": p["router"]}
        for name in ("w1", "w2", "w3"):
            if name not in p:
                continue
            if abstract:
                out[name] = jax.ShapeDtypeStruct(shapes[name], jnp.int8)
                out[name + "_scale"] = jax.ShapeDtypeStruct((e,), jnp.float32)
            else:
                w = p[name].astype(jnp.float32)
                scale = jnp.max(jnp.abs(w), axis=(1, 2)) / 127.0 + 1e-12
                out[name] = jnp.clip(jnp.round(w / scale[:, None, None]),
                                     -127, 127).astype(jnp.int8)
                out[name + "_scale"] = scale
        if cfg.moe_shared_experts:
            fs = cfg.moe_shared_experts * f
            sh_shapes = {"sw1": (d, fs), "sw2": (fs, d)}
            if cfg.act == "silu":
                sh_shapes["sw3"] = (d, fs)
            out.update(_make(sh_shapes, cfg, rng, abstract, fan_in=d))
        return out
    if cfg.moe_shared_experts:
        fs = cfg.moe_shared_experts * f
        shapes.update({"sw1": (d, fs), "sw2": (fs, d)})
        if cfg.act == "silu":
            shapes["sw3"] = (d, fs)
    return _make(shapes, cfg, rng, abstract, fan_in=d)


def moe_specs(cfg: ModelConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    sp = {"router": ("embed", None),
          "w1": ("experts", "moe_embed", "moe_ff"),
          "w2": ("experts", "moe_ff", "moe_embed")}
    if cfg.act == "silu":
        sp["w3"] = ("experts", "moe_embed", "moe_ff")
    if cfg.moe_weight_dtype == "int8":
        for name in ("w1", "w2", "w3"):
            if name in sp:
                sp[name + "_scale"] = ("experts",)
    if cfg.moe_shared_experts:
        sp.update({"sw1": ("embed", "ff"), "sw2": ("ff", "embed")})
        if cfg.act == "silu":
            sp["sw3"] = ("embed", "ff")
    return sp


def moe_block(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B,S,d). Per-batch-row grouped dispatch with capacity dropping."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    cap = max(1, int(math.ceil(s * k * cfg.moe_capacity_factor / e)))

    gate_logits = jnp.einsum("bsd,de->bse", x, params["router"],
                             preferred_element_type=jnp.float32)
    weights, ids = jax.lax.top_k(jax.nn.softmax(gate_logits, axis=-1), k)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)

    flat_ids = ids.reshape(b, s * k)                       # (B, S*k)
    flat_w = weights.reshape(b, s * k).astype(x.dtype)
    token_of_slot = jnp.broadcast_to(
        jnp.arange(s)[:, None], (s, k)).reshape(s * k)

    order = jnp.argsort(flat_ids, axis=-1)                 # per-row sort
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    sorted_tok = token_of_slot[order]                      # (B, S*k)
    sorted_w = jnp.take_along_axis(flat_w, order, axis=-1)
    # within-expert rank of each sorted slot
    pos = jnp.arange(s * k)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(
        sorted_ids)                                        # (B, E)
    rank = pos[None, :] - jnp.take_along_axis(starts, sorted_ids, axis=-1)
    # overflow slots get rank=cap, an out-of-bounds index dropped by scatter
    rank_c = jnp.where(rank < cap, rank, cap)

    # Index-only dispatch: build a slot->token map (B,E,C) so one gather
    # fills the expert slots and one scatter-add combines them — no
    # (B, S*k, d) token-copy intermediates (6x-activation-sized; they blew
    # up the 236B dry-runs).
    bi = jnp.broadcast_to(jnp.arange(b)[:, None], sorted_ids.shape)
    tok_for_slot = jnp.full((b, e, cap), s, jnp.int32)      # s = OOB sentinel
    tok_for_slot = tok_for_slot.at[bi, sorted_ids, rank_c].set(
        sorted_tok, mode="drop")
    w_for_slot = jnp.zeros((b, e, cap), x.dtype).at[
        bi, sorted_ids, rank_c].set(sorted_w, mode="drop")

    xin = jnp.take_along_axis(
        x, tok_for_slot.reshape(b, e * cap)[..., None], axis=1,
        mode="fill", fill_value=0)
    xin = shard(xin.reshape(b, e, cap, d), "batch", "experts", None, None)

    act = _act(cfg.act)

    def ew(name):
        w = params[name]
        if w.dtype == jnp.int8:   # serving quantization: dequant after move
            # pin the INT8 tensor to the post-gather sharding so the FSDP
            # all-gather moves 1-byte weights, not the bf16 dequant output
            w = shard(w, "experts", None, None)
            w = w.astype(x.dtype) * params[name + "_scale"].astype(
                x.dtype)[:, None, None]
        return w

    h = act(jnp.einsum("becd,edf->becf", xin, ew("w1")))
    if "w3" in params:
        h = h * jnp.einsum("becd,edf->becf", xin, ew("w3"))
    h = shard(h, "batch", "experts", None, None)
    eout = jnp.einsum("becf,efd->becd", h, ew("w2"))
    eout = eout * w_for_slot[..., None]
    eout = shard(eout, "batch", "experts", None, None)

    # one scatter-add combines slots back to tokens (OOB sentinel dropped)
    bi3 = jnp.broadcast_to(jnp.arange(b)[:, None, None], (b, e, cap))
    out = jnp.zeros((b, s, d), x.dtype).at[bi3, tok_for_slot].add(
        eout, mode="drop")

    if cfg.moe_shared_experts:
        hs = act(x @ params["sw1"])
        if "sw3" in params:
            hs = hs * (x @ params["sw3"])
        out = out + hs @ params["sw2"]
    # Under TP the expert (and shared-expert) ff dim is sharded while the
    # replicated router picks identical slots on every device, so routed
    # output, gate scaling, scatter-add combine and shared experts are all
    # linear in per-device partial sums: ONE psum at the end suffices.
    out = psum_tp(out)
    return shard(out, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def embed_params(cfg: ModelConfig, rng=None, abstract=False) -> Params:
    shapes = {"embedding": (cfg.vocab, cfg.d_model)}
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (cfg.d_model, cfg.vocab)
    return _make(shapes, cfg, rng, abstract, fan_in=cfg.d_model, std=0.02)


def embed_specs(cfg: ModelConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    sp = {"embedding": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        sp["lm_head"] = ("embed", "vocab")
    return sp


def embed(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    table = params["embedding"]
    if tp_axis() is not None and table.shape[0] != cfg.vocab:
        # vocab-sharded table (EmbeddingShard idiom): each device looks up
        # the tokens that fall in its row range, zeros the rest, and one
        # psum assembles the full embedding on every device.
        vloc = table.shape[0]
        loc = tokens - tp_index() * vloc
        ok = (loc >= 0) & (loc < vloc)
        out = jnp.take(table, jnp.clip(loc, 0, vloc - 1), axis=0)
        out = psum_tp(jnp.where(ok[..., None], out, 0).astype(dt(cfg)))
    else:
        out = jnp.take(table, tokens, axis=0).astype(dt(cfg))
    return shard(out, "batch", "seq", "act_embed")


def logits_fn(params: Params, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = params["lm_head"] if "lm_head" in params else params["embedding"].T
    return jnp.einsum("bsd,dv->bsv", h, w,
                      preferred_element_type=jnp.float32)


def chunked_ce_loss(params: Params, h: jax.Array, labels: jax.Array,
                    cfg: ModelConfig) -> jax.Array:
    """Cross entropy without materializing (B,S,V) at once."""
    b, s, d = h.shape
    c = min(cfg.loss_chunk, s)
    n = s // c
    hc = h[:, :n * c].reshape(b, n, c, d).swapaxes(0, 1)       # (n,B,c,d)
    lc = labels[:, :n * c].reshape(b, n, c).swapaxes(0, 1)

    def step(tot, xs):
        hx, lx = xs
        logits = logits_fn(params, hx, cfg)                    # (B,c,V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - gold), None

    tot, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hc, lc))
    return tot / (b * n * c)


# ---------------------------------------------------------------------------
# param construction helper
# ---------------------------------------------------------------------------

def _make(shapes: Dict[str, Tuple[int, ...]], cfg: ModelConfig, rng,
          abstract: bool, fan_in: int, std: Optional[float] = None) -> Params:
    out: Params = {}
    dtype = dt(cfg)
    keys = (jax.random.split(rng, len(shapes))
            if (rng is not None and not abstract) else [None] * len(shapes))
    for (name, shape), key in zip(sorted(shapes.items()), keys):
        if abstract:
            out[name] = jax.ShapeDtypeStruct(shape, dtype)
        else:
            scale = std if std is not None else 1.0 / math.sqrt(fan_in)
            if len(shape) == 1:
                out[name] = jnp.zeros(shape, dtype)
            else:
                out[name] = (jax.random.normal(key, shape, jnp.float32)
                             * scale).astype(dtype)
    return out
