"""nemotron-4-15b [dense] — GQA kv=8, squared-ReLU MLP.
[arXiv:2402.16819; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=24576, vocab=256000, act="relu2",
    source="arXiv:2402.16819; unverified",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=192,
        vocab=256, loss_chunk=16, remat="none")
