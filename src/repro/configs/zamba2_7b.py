"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks,
ssm_state=64. [arXiv:2411.15242; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, mamba_version=2,
    ssm_head_dim=64, shared_attn_every=6,
    source="arXiv:2411.15242; unverified",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab=256, ssm_state=16, ssm_head_dim=16, shared_attn_every=2,
        ssm_chunk=16, loss_chunk=16, remat="none")
