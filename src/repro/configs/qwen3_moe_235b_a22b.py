"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, GQA kv=4, head_dim 128.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    head_dim=128, d_ff=1536, vocab=151936,
    moe_experts=128, moe_top_k=8, moe_d_ff=1536,
    opt_dtype="bfloat16",   # 235B: fp32 moments would not fit one pod
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=96, vocab=256, moe_experts=8, moe_top_k=2, moe_d_ff=96,
        loss_chunk=16, remat="none")
