"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=1536, vocab=102400,
    moe_experts=160, moe_top_k=6, moe_d_ff=1536, moe_shared_experts=2,
    mla_kv_lora=512, mla_q_lora=1536,
    mla_qk_nope_dim=128, mla_qk_rope_dim=64, mla_v_head_dim=128,
    opt_dtype="bfloat16",
    source="arXiv:2405.04434; hf",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=96,
        vocab=256, moe_experts=8, moe_top_k=2, moe_d_ff=96,
        moe_shared_experts=1, mla_kv_lora=32, mla_q_lora=48,
        mla_qk_nope_dim=16, mla_qk_rope_dim=8, mla_v_head_dim=16,
        loss_chunk=16, remat="none")
