"""command-r-35b [dense] — GQA kv=8, no bias.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab=256000,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=160,
        vocab=256, loss_chunk=16, remat="none")
