"""falcon-mamba-7b [ssm] — mamba1, attention-free, ssm_state=16.
[arXiv:2410.05355; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2, mamba_version=1,
    source="arXiv:2410.05355; unverified",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        num_layers=2, d_model=64, vocab=256, ssm_state=8, ssm_chunk=16,
        loss_chunk=16, remat="none")
