"""Assigned architecture registry — exact configs from the public pool.

Each entry provides the FULL config (used only via the dry-run:
ShapeDtypeStruct, no allocation) and a ``smoke()`` reduction of the same
family (small depth/width/experts/vocab) for CPU tests.
"""
from __future__ import annotations

import importlib
from typing import List

from .base import ModelConfig, SHAPES, ShapeConfig

ARCH_IDS = [
    "whisper-small",
    "qwen3-moe-235b-a22b",
    "deepseek-v2-236b",
    "nemotron-4-15b",
    "internlm2-20b",
    "qwen2.5-3b",
    "command-r-35b",
    "falcon-mamba-7b",
    "zamba2-7b",
    "internvl2-2b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke()


def shape_cells(arch: str) -> List[ShapeConfig]:
    """The shape suite for an arch, with the mandated skips applied."""
    cfg = get_config(arch)
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue   # pure full-attention arch: noted skip (DESIGN.md §4)
        out.append(s)
    return out


def skipped_cells(arch: str) -> List[str]:
    cfg = get_config(arch)
    if not cfg.supports_long_context:
        return ["long_500k"]
    return []


def all_cells() -> List[tuple]:
    cells = []
    for a in ARCH_IDS:
        for s in shape_cells(a):
            cells.append((a, s.name))
    return cells
