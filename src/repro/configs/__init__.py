from .base import ModelConfig, SHAPES, ShapeConfig
from .registry import (ARCH_IDS, all_cells, get_config, get_smoke_config,
                       shape_cells, skipped_cells)

__all__ = ["ModelConfig", "SHAPES", "ShapeConfig", "ARCH_IDS", "all_cells",
           "get_config", "get_smoke_config", "shape_cells", "skipped_cells"]
