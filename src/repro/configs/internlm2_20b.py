"""internlm2-20b [dense] — GQA kv=8. [arXiv:2403.17297; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab=92544,
    source="arXiv:2403.17297; hf",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab=256, loss_chunk=16, remat="none")
