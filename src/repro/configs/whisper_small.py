"""whisper-small [audio] — enc-dec, conv frontend stubbed.
[arXiv:2212.04356; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=12, encoder_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab=51865,
    audio_frames=1500, act="gelu",
    source="arXiv:2212.04356; unverified",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab=256, audio_frames=16,
        loss_chunk=16, remat="none")
