"""qwen2.5-3b [dense] — GQA kv=2, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
    head_dim=128, d_ff=11008, vocab=151936, qkv_bias=True,
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, loss_chunk=16, remat="none")
