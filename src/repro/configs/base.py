"""Model / run configuration dataclasses.

One frozen ``ModelConfig`` describes any architecture in the assigned pool
(dense / MoE / MLA / SSM / hybrid / enc-dec / VLM).  ``ShapeConfig``
describes an input-shape cell (train_4k / prefill_32k / decode_32k /
long_500k).  Everything downstream (models, sharding, launch) is driven
by these two objects.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // num_heads

    # --- MoE ---------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    moe_impl: str = "gspmd"     # gspmd | ep (shard_map all-to-all dispatch)
    moe_weight_dtype: str = ""   # "int8" = quantized expert FFs (serving)

    # --- MLA (deepseek-v2) ---------------------------------------------------
    mla_kv_lora: int = 0         # kv compression rank; 0 => standard GQA
    mla_q_lora: int = 0
    mla_qk_nope_dim: int = 128
    mla_qk_rope_dim: int = 64
    mla_v_head_dim: int = 128

    # --- SSM (mamba) -----------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    mamba_version: int = 1       # 1 => S6 selective scan, 2 => SSD
    ssm_head_dim: int = 64       # mamba2 heads
    ssm_chunk: int = 256         # seq chunk for the scan/SSD formulation
    ssm_scan_dtype: str = "float32"  # dtype of materialized scan elements
                                 # (bf16 halves the S6 HBM traffic; the
                                 # Pallas kernel keeps fp32 in VMEM)

    # --- hybrid (zamba2): shared attention block every k SSM blocks -------------
    shared_attn_every: int = 0

    # --- encoder-decoder (whisper) ------------------------------------------------
    encoder_layers: int = 0
    audio_frames: int = 1500     # stub conv-frontend output length (whisper)

    # --- VLM stub -------------------------------------------------------------------
    vision_tokens: int = 0       # stub ViT patch embeddings prepended to text

    # --- misc ------------------------------------------------------------------------
    act: str = "silu"            # silu | relu2 | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"          # none | dots | full
    loss_chunk: int = 512       # seq chunk for cross-entropy (memory)
    opt_dtype: str = "float32"   # AdamW moment dtype (bf16 for 200B+ archs)
    source: str = ""             # provenance tag [source; verified-tier]

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing => long_500k applies."""
        return self.family in ("ssm", "hybrid")

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ---------------
    def param_count(self) -> int:
        return _param_count(self)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)


def _attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    if cfg.mla_kv_lora:
        q = (d * cfg.mla_q_lora + cfg.mla_q_lora * cfg.num_heads *
             (cfg.mla_qk_nope_dim + cfg.mla_qk_rope_dim)) if cfg.mla_q_lora else \
            d * cfg.num_heads * (cfg.mla_qk_nope_dim + cfg.mla_qk_rope_dim)
        kv = d * (cfg.mla_kv_lora + cfg.mla_qk_rope_dim)
        kv += cfg.mla_kv_lora * cfg.num_heads * (cfg.mla_qk_nope_dim +
                                                 cfg.mla_v_head_dim)
        o = cfg.num_heads * cfg.mla_v_head_dim * d
        return q + kv + o
    q = d * cfg.num_heads * hd
    kv = 2 * d * cfg.num_kv_heads * hd
    o = cfg.num_heads * hd * d
    return q + kv + o


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    mult = 3 if cfg.act == "silu" else 2     # gated MLPs have w1,w3,w2
    return mult * cfg.d_model * d_ff


def _ssm_params(cfg: ModelConfig) -> int:
    d, e = cfg.d_model, cfg.ssm_expand
    d_in = e * d
    if cfg.mamba_version == 1:
        dt_rank = max(1, (d + 15) // 16)
        return (d * 2 * d_in                    # in_proj
                + d_in * cfg.ssm_conv           # conv1d
                + d_in * (dt_rank + 2 * cfg.ssm_state)  # x_proj
                + dt_rank * d_in                # dt_proj
                + d_in * cfg.ssm_state          # A_log
                + d_in                          # D
                + d_in * d)                     # out_proj
    n_heads = d_in // cfg.ssm_head_dim
    return (d * (2 * d_in + 2 * cfg.ssm_state + n_heads)  # in_proj (zxBCdt)
            + (d_in + 2 * cfg.ssm_state) * cfg.ssm_conv   # conv1d
            + 3 * n_heads                        # A_log, D, dt_bias
            + d_in * d)                          # out_proj


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    total = cfg.vocab * d                        # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab * d                   # lm head
    per_layer = 2 * d                            # norms
    if cfg.family == "ssm":
        per_layer += _ssm_params(cfg)
        total += cfg.num_layers * per_layer
        return total + d
    if cfg.family == "hybrid":
        total += cfg.num_layers * (2 * d + _ssm_params(cfg))
        n_shared = (cfg.num_layers + cfg.shared_attn_every - 1) \
            // cfg.shared_attn_every if cfg.shared_attn_every else 0
        total += _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + 2 * d
        del n_shared  # shared block counted once (weights reused)
        return total + d
    attn = _attn_params(cfg)
    if cfg.moe_experts:
        experts = cfg.moe_top_k if active_only else cfg.moe_experts
        mlp = (experts + cfg.moe_shared_experts) * _mlp_params(cfg, cfg.moe_d_ff)
        mlp += d * cfg.moe_experts               # router
    else:
        mlp = _mlp_params(cfg, cfg.d_ff)
    total += cfg.num_layers * (per_layer + attn + mlp)
    if cfg.family == "encdec":
        enc = cfg.encoder_layers * (2 * d + attn + _mlp_params(cfg, cfg.d_ff))
        dec_cross = cfg.num_layers * (attn + d)  # cross-attention + norm
        total += enc + dec_cross
    return total + d                             # final norm


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
