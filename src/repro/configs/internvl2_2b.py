"""internvl2-2b [vlm] — InternViT (stub) + InternLM2 backbone, GQA kv=8.
[arXiv:2404.16821; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab=92553,
    vision_tokens=256,
    source="arXiv:2404.16821; hf",
)


def smoke() -> ModelConfig:
    return CONFIG.with_overrides(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab=256, vision_tokens=8, loss_chunk=16, remat="none")
