"""REPRO_SANITIZE=1 runtime invariant sanitizer (DESIGN.md §14).

Cheap post-condition wrappers over the three stateful planes whose
invariants carry the paper's correctness argument:

  RingState (paper §IV, EDRA)
    * ``version`` / ``active_version`` are monotonically non-decreasing
      and ``active_version <= version`` — owner_diff cursors and the
      device-table caches key off them;
    * the live id slab ``_ids[:n]`` stays strictly sorted (every
      successor walk is a searchsorted over it);
    * quarantined peers never appear in ``active_ids()`` (§V: a masked
      peer owns nothing);
    * ``lookup`` agrees with the flat numpy oracle
      ``act[searchsorted(act, key) % n]`` on a sampled sub-batch —
      the directory/bucket path can never silently diverge from the
      definition of "successor".

  BlockStore (paper §V + Leslie's replication invariants)
    * after ``put``: exactly ``min(r, live)`` fresh copies on reachable,
      non-quarantined holders, and the key's tombstone is gone;
    * after ``sync``: every placed key has ``min(r, live)`` live
      checksum-valid up-to-date copies (``replica_counts``);
    * tombstoned keys are never placed (no resurrection).

  Replica (serve plane)
    * slot conservation: ``free + active-sessions + pending-prefills ==
      slots`` with pairwise-disjoint slot sets, and the ``active`` mask
      matches ``sessions`` exactly — checked even on exception paths
      (rollback bugs are exactly the ones that leak slots).

``install()`` monkeypatches the wrappers in (idempotent);
``uninstall()`` restores the originals.  ``tests/conftest.py`` installs
when ``REPRO_SANITIZE`` is truthy, so the whole tier-1 suite runs
sanitized in the dedicated CI job.  The wrappers are O(state-size) at
worst and O(1)-ish on the serve path — cheap enough for tests, not
meant for benches (``benchmarks/common.py`` records the flag in
provenance so a sanitizer-taxed number can never masquerade as a real
one).
"""
from __future__ import annotations

import os
from typing import Callable, Dict, List, Tuple

import numpy as np

__all__ = ["SanitizeError", "enabled", "install", "uninstall", "stats"]

_LOOKUP_SAMPLE = 8       # keys per lookup batch twin-checked vs the oracle
_SYNC_SAMPLE = 64        # keys per sync checked for replica cardinality


class SanitizeError(AssertionError):
    """A runtime invariant the paper (or the serve plane) relies on was
    violated.  Always a bug — never catch and continue."""


def enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "").lower() in (
        "1", "true", "on", "yes")


_checks: Dict[str, int] = {}
_originals: List[Tuple[type, str, Callable]] = []


def stats() -> Dict[str, int]:
    """Invariant-check counters (name -> times run); for tests asserting
    the sanitizer actually engaged."""
    return dict(_checks)


def _count(name: str) -> None:
    _checks[name] = _checks.get(name, 0) + 1


def _fail(msg: str) -> None:
    raise SanitizeError(f"REPRO_SANITIZE: {msg}")


# ---------------------------------------------------------------------------
# RingState
# ---------------------------------------------------------------------------

def _check_ringstate(st, prev_version: int, prev_active: int,
                     where: str) -> None:
    _count("ringstate")
    if st.version < prev_version or st.active_version < prev_active:
        _fail(f"RingState.{where}: version went backwards "
              f"({prev_version}->{st.version}, "
              f"active {prev_active}->{st.active_version})")
    if st.active_version > st.version:
        _fail(f"RingState.{where}: active_version {st.active_version} "
              f"> version {st.version}")
    n = st._n
    ids = st._ids[:n]
    if n > 1 and not bool(np.all(ids[:-1] < ids[1:])):
        _fail(f"RingState.{where}: live id slab not strictly sorted")
    quar = st._quar[:n]
    if quar.any():
        act = st.active_ids()
        bad = np.intersect1d(act, ids[quar])
        if bad.size:
            _fail(f"RingState.{where}: quarantined peer(s) "
                  f"{bad[:4].tolist()} present in active_ids (paper §V)")


def _wrap_ring_mutator(cls, name: str) -> None:
    orig = getattr(cls, name)

    def wrapper(self, *args, **kwargs):
        pv, pa = self.version, self.active_version
        try:
            return orig(self, *args, **kwargs)
        finally:
            _check_ringstate(self, pv, pa, name)

    _install_one(cls, name, orig, wrapper)


def _wrap_ring_lookup(cls) -> None:
    orig = cls.lookup

    def wrapper(self, keys, **kwargs):
        out = orig(self, keys, **kwargs)
        _count("ringstate.lookup")
        act = self.active_ids()
        keys = np.asarray(keys, np.uint64)
        k = min(_LOOKUP_SAMPLE, keys.size)
        if k and act.size:
            sample = keys[:k]
            oracle = act[np.searchsorted(act, sample) % act.size]
            got = np.asarray(out)[:k]
            if not bool(np.array_equal(got, oracle)):
                i = int(np.nonzero(got != oracle)[0][0])
                _fail("RingState.lookup disagrees with the flat numpy "
                      f"oracle at key {int(sample[i])}: got "
                      f"{int(got[i])}, oracle {int(oracle[i])} "
                      "(directory/bucket path diverged)")
        return out

    _install_one(cls, "lookup", orig, wrapper)


# ---------------------------------------------------------------------------
# BlockStore
# ---------------------------------------------------------------------------

def _check_tombs_disjoint(store, where: str) -> None:
    both = set(store._tombs) & set(store._placement)
    if both:
        _fail(f"BlockStore.{where}: tombstoned key(s) "
              f"{sorted(both)[:4]} still placed (resurrection hazard)")


def _wrap_store_put(cls) -> None:
    orig = cls.put

    def wrapper(self, name, value, **kwargs):
        meta = orig(self, name, value, **kwargs)
        _count("blockstore.put")
        key = self.key_of(name)
        live = self.state.active_ids()
        group = self._placement.get(key, ())
        want = min(self.replication, int(live.size))
        if len(group) != want:
            _fail(f"BlockStore.put({name!r}): placed on {len(group)} "
                  f"nodes, expected min(r={self.replication}, "
                  f"live={int(live.size)}) = {want}")
        for node in group:
            if self.state.is_quarantined(node):
                _fail(f"BlockStore.put({name!r}): replica {node} is "
                      "quarantined (paper §V: masked peers own nothing)")
            entry = self._nodes.get(node, {}).get(key)
            if entry is None or entry[0].version != meta.version:
                _fail(f"BlockStore.put({name!r}): holder {node} missing "
                      "the fresh copy")
        if key in self._tombs:
            _fail(f"BlockStore.put({name!r}): tombstone survived the put")
        _check_tombs_disjoint(self, "put")
        return meta

    _install_one(cls, "put", orig, wrapper)


def _wrap_store_sync(cls) -> None:
    orig = cls.sync

    def wrapper(self):
        out = orig(self)
        _count("blockstore.sync")
        live = self.state.active_ids()
        want_full = min(self.replication, int(live.size))
        counts = self.replica_counts()
        for key in sorted(counts)[:_SYNC_SAMPLE]:
            if counts[key] != want_full:
                _fail(f"BlockStore.sync: key {key} has {counts[key]} "
                      f"live up-to-date copies, expected {want_full} "
                      "after convergence")
        _check_tombs_disjoint(self, "sync")
        return out

    _install_one(cls, "sync", orig, wrapper)


def _wrap_store_remove(cls) -> None:
    orig = cls.remove

    def wrapper(self, name):
        out = orig(self, name)
        _count("blockstore.remove")
        key = self.key_of(name)
        if key in self._placement:
            _fail(f"BlockStore.remove({name!r}): key still placed")
        _check_tombs_disjoint(self, "remove")
        return out

    _install_one(cls, "remove", orig, wrapper)


# ---------------------------------------------------------------------------
# Replica slot conservation
# ---------------------------------------------------------------------------

def _check_slots(rep, where: str) -> None:
    _count("replica.slots")
    free = list(rep._free)
    sess = list(rep.sessions.values())
    pend = [st["slot"] for st in rep._pending.values()]
    total = len(free) + len(sess) + len(pend)
    if total != rep.slots:
        _fail(f"Replica.{where}: slot leak — free({len(free)}) + "
              f"sessions({len(sess)}) + pending({len(pend)}) = {total} "
              f"!= slots({rep.slots})")
    all_slots = free + sess + pend
    if len(set(all_slots)) != len(all_slots):
        _fail(f"Replica.{where}: slot double-booked across "
              "free/sessions/pending")
    active = set(np.nonzero(rep.active)[0].tolist())
    if active != set(sess):
        _fail(f"Replica.{where}: active mask {sorted(active)} != "
              f"session slots {sorted(set(sess))}")


def _wrap_replica(cls, name: str) -> None:
    orig = getattr(cls, name)

    def wrapper(self, *args, **kwargs):
        try:
            return orig(self, *args, **kwargs)
        finally:
            # conservation must hold on exception paths too: admit/
            # prefill rollback bugs are exactly the ones that leak slots
            _check_slots(self, name)

    _install_one(cls, name, orig, wrapper)


# ---------------------------------------------------------------------------
# install / uninstall
# ---------------------------------------------------------------------------

def _install_one(cls: type, name: str, orig: Callable,
                 wrapper: Callable) -> None:
    wrapper.__name__ = orig.__name__
    wrapper.__qualname__ = orig.__qualname__
    wrapper.__doc__ = orig.__doc__
    wrapper.__repro_sanitized__ = True  # type: ignore[attr-defined]
    _originals.append((cls, name, orig))
    setattr(cls, name, wrapper)


def install() -> bool:
    """Wrap the invariant checks in (idempotent).  Returns True if this
    call did the installation."""
    if _originals:
        return False
    from repro.core.ringstate import RingState
    from repro.dht.data import BlockStore
    from repro.serve.server import Replica

    for name in ("add", "remove", "set_quarantined", "apply_events"):
        _wrap_ring_mutator(RingState, name)
    _wrap_ring_lookup(RingState)
    _wrap_store_put(BlockStore)
    _wrap_store_sync(BlockStore)
    _wrap_store_remove(BlockStore)
    for name in ("admit", "admit_from_blocks", "begin_admit",
                 "advance_prefills", "evict", "decode_round"):
        _wrap_replica(Replica, name)
    return True


def uninstall() -> None:
    """Restore every wrapped method (idempotent)."""
    while _originals:
        cls, name, orig = _originals.pop()
        setattr(cls, name, orig)
    _checks.clear()
