"""CLI for repro-lint.

  python -m repro.analysis                     # lint + gate vs baseline
  python -m repro.analysis --update-baseline   # re-record the ratchet
  python -m repro.analysis path/to/file.py     # lint specific paths
  python -m repro.analysis --no-baseline       # raw findings, no ratchet

Exit codes: 0 clean (or everything baselined), 1 new findings, 2 usage.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .baseline import Baseline
from .lint import RULES, run_lint

_DEFAULT_PATHS = ["src/repro", "benchmarks", "examples"]
_DEFAULT_BASELINE = "src/repro/analysis/baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: codebase-specific static analysis "
                    "(rules RL001-RL005, see DESIGN.md §14)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {_DEFAULT_PATHS})")
    ap.add_argument("--root", default=".",
                    help="path findings are reported relative to "
                         "(baseline keys; default: cwd)")
    ap.add_argument("--baseline", default=None,
                    help=f"ratchet file (default: {_DEFAULT_BASELINE} "
                         "under --root)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the ratchet: any finding fails")
    ap.add_argument("--update-baseline", action="store_true",
                    help="re-record the ratchet from current findings "
                         "(prunes fixed entries) and exit 0")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset, e.g. RL001,RL003")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on stdout")
    args = ap.parse_args(argv)

    root = Path(args.root).resolve()
    paths = [Path(p) for p in (args.paths or [])]
    if not paths:
        paths = [root / p for p in _DEFAULT_PATHS if (root / p).exists()]
    rules = None
    if args.rules:
        rules = {r.strip().upper() for r in args.rules.split(",")}
        unknown = rules - set(RULES)
        if unknown:
            print(f"unknown rule(s): {sorted(unknown)}", file=sys.stderr)
            return 2

    report = run_lint(paths, root=root, rules=rules)

    bl_path = Path(args.baseline) if args.baseline \
        else root / _DEFAULT_BASELINE
    if args.update_baseline:
        Baseline.from_findings(report.findings).save(bl_path)
        print(f"baseline re-recorded: {len(report.findings)} finding(s) "
              f"-> {bl_path}")
        return 0

    baseline = Baseline() if args.no_baseline else Baseline.load(bl_path)
    diff = baseline.diff(report.findings)

    if args.as_json:
        print(json.dumps({
            "files": report.files,
            "new": [vars(f) | {"key": f.key} for f in diff.new],
            "baselined": [f.key for f in diff.baselined],
            "stale": diff.stale,
            "suppressed": [f.key for f in report.suppressed],
            "ok": diff.ok,
        }, indent=2))
        return 0 if diff.ok else 1

    for f in diff.new:
        print(f"NEW  {f}")
    for f in diff.baselined:
        print(f"OLD  {f}")
    tally = ", ".join(f"{r}={n}" for r, n in
                      sorted(report.by_rule().items())) or "none"
    print(f"repro-lint: {report.files} file(s), findings: {tally} "
          f"({len(diff.new)} new, {len(diff.baselined)} baselined, "
          f"{len(report.suppressed)} suppressed)")
    if diff.stale:
        print(f"note: {len(diff.stale)} stale baseline entr(y/ies) — "
              "offenders fixed; run --update-baseline to prune:")
        for k in diff.stale:
            print(f"  STALE {k}")
    if diff.new:
        print("FAIL: new findings above the baseline ratchet. Fix them, "
              "or (deliberately) re-record with --update-baseline.")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
