"""Allowlisted metering sites for repro-lint RL003.

Some host syncs are the *point* of the code: one-time route calibration
timings, benchmark harness readbacks, admission-time cost probes.  The
``@metered`` decorator marks such a function as a sanctioned metering
site — repro-lint's RL003 (host-sync-in-hot-path) skips any function
whose decorator name contains ``metered``.

The decorator is intentionally almost-nothing at runtime: it tags the
function and counts calls, so tests (and future budget gates) can assert
that metering sites stay out of per-round loops — a metering site called
O(rounds) times is a bug even if each sync is cheap.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, TypeVar

__all__ = ["metered", "is_metered", "meter_count", "reset_meters"]

F = TypeVar("F", bound=Callable[..., Any])

_counts: dict = {}


def metered(fn: F) -> F:
    """Mark ``fn`` as a sanctioned host-sync metering site (RL003)."""

    @functools.wraps(fn)
    def wrapper(*args: Any, **kwargs: Any) -> Any:
        key = f"{fn.__module__}.{fn.__qualname__}"
        _counts[key] = _counts.get(key, 0) + 1
        return fn(*args, **kwargs)

    wrapper.__repro_metered__ = True  # type: ignore[attr-defined]
    return wrapper  # type: ignore[return-value]


def is_metered(fn: Callable) -> bool:
    return bool(getattr(fn, "__repro_metered__", False))


def meter_count(fn: Callable) -> int:
    inner = getattr(fn, "__wrapped__", fn)
    key = f"{inner.__module__}.{inner.__qualname__}"
    return _counts.get(key, 0)


def reset_meters() -> None:
    _counts.clear()
