"""Baseline ratchet for repro-lint (DESIGN.md §14).

The committed ``baseline.json`` records the multiset of finding keys
(``rule:path:scope:message`` — deliberately line-free so unrelated edits
never churn it) that existed when the gate was introduced.  Semantics:

  * a finding whose key is in the baseline (within its recorded count)
    is **baselined**: reported, but does not fail the gate;
  * a finding whose key is absent (or exceeds its count) is **new** and
    fails the gate;
  * a baseline entry with no matching finding is **stale** — the
    offender was fixed; ``--update-baseline`` prunes it, so the baseline
    only ever shrinks unless a human deliberately re-records it.

This is the same ratchet discipline as the BENCH_* CI gates: the bar
never silently moves backwards.
"""
from __future__ import annotations

import json
from collections import Counter
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List

from .lint import Finding

__all__ = ["Baseline", "Diff"]


@dataclass
class Diff:
    new: List[Finding] = field(default_factory=list)
    baselined: List[Finding] = field(default_factory=list)
    stale: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.new


@dataclass
class Baseline:
    counts: Counter = field(default_factory=Counter)

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        path = Path(path)
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        entries: Dict[str, int] = data.get("findings", {})
        return cls(counts=Counter({k: int(v) for k, v in entries.items()}))

    def save(self, path: Path) -> None:
        payload = {
            "comment": "repro-lint ratchet: legacy findings allowed, new "
                       "findings fail CI. Keys are line-free "
                       "(rule:path:scope:message). Regenerate with "
                       "`python -m repro.analysis --update-baseline` — "
                       "only after deciding a finding is a keeper.",
            "findings": {k: v for k, v in sorted(self.counts.items())},
        }
        Path(path).write_text(json.dumps(payload, indent=2) + "\n")

    @classmethod
    def from_findings(cls, findings: List[Finding]) -> "Baseline":
        return cls(counts=Counter(f.key for f in findings))

    def diff(self, findings: List[Finding]) -> Diff:
        budget = Counter(self.counts)
        d = Diff()
        for f in findings:
            if budget[f.key] > 0:
                budget[f.key] -= 1
                d.baselined.append(f)
            else:
                d.new.append(f)
        d.stale = sorted(k for k, v in budget.items() if v > 0)
        return d
