"""repro-lint — codebase-specific static analysis (DESIGN.md §14).

Five rules encode hazard classes this codebase has actually been bitten
by (tracer leaks, silent recompiles, hidden host↔device syncs) plus the
structural conventions the kernel and simulator planes depend on:

  RL001  tracer-leak        Python ``if``/``while``/``bool()``/``int()``
                            /``float()``/``.item()`` on a jnp value
                            inside a function reachable from a
                            ``@jax.jit`` / ``_jitted`` / ``pallas_call``
                            entry.  Params listed in ``static_argnames``
                            / ``static_argnums`` are exempt, as are
                            ``x is None`` identity tests.
  RL002  recompile-hazard   A dynamically-sized array (size derived from
                            ``len()``/``.size``/``.shape``) crossing a
                            jit boundary (``jnp.asarray``/``jnp.array``/
                            ``jax.device_put`` or a known jit entry)
                            without passing through a pow2 bucketing
                            idiom (any ``*bucket*`` helper, e.g.
                            ``shape_bucket``/``_decode_bucket``); also a
                            dynamic scalar flowing into a jit entry's
                            ``static_argnames`` keyword.
  RL003  host-sync          ``np.asarray``/``jax.device_get``/
                            ``jax.block_until_ready``/``int()``/
                            ``float()``/``.item()`` on device values
                            inside the serve hot path (``decode_round``,
                            ``step``, ``submit``, ``*fused*``, and
                            anything they call) outside the allowlisted
                            ``@metered`` decorator or a
                            ``# repro-lint: allow(RL003)`` pragma.
  RL004  kernel-contract    Every ``kernels/<name>/`` directory keeps
                            the ``kernel.py``/``ref.py``/``ops.py``
                            triple, ``ref.py`` never imports pallas, and
                            the pallas side resolves tiles via
                            ``autotune.tiles_for`` (never hard-coded).
  RL005  determinism        No unseeded ``random.*`` module calls, no
                            global ``np.random.*`` samplers, and no
                            ``datetime.now``-family wall-clock reads in
                            the ``dht/`` / ``core/`` simulation planes
                            (the DES↔vectorized twin checks replay off
                            seeds; a wall-clock read silently unpins
                            them).

All analysis is stdlib ``ast`` — no new dependencies.  The rules are
deliberately codebase-specific heuristics, not a general JAX linter:
precision comes from knowing this repo's idioms (``tiles_for``,
``shape_bucket``, ``_jitted``, the serve hot-path names), and the
committed ``baseline.json`` ratchet (see ``baseline.py``) absorbs the
residue: legacy findings are allowed to exist, NEW findings fail CI.

Suppression:

  * ``# repro-lint: allow(RL003)`` (or ``allow(RL001, RL003)`` /
    ``allow(*)``) on the flagged line — or the line above it —
    suppresses those rules there.  Suppressions are counted in the
    report, never silent.
  * a decorator whose name contains ``metered`` marks a function as an
    allowlisted metering site for RL003 (see ``metering.metered``).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "LintReport", "RULES", "run_lint", "collect_files"]

RULES: Dict[str, str] = {
    "RL001": "tracer-leak: Python control flow / coercion on a traced value",
    "RL002": "recompile-hazard: unbucketed dynamic size crossing a jit "
             "boundary",
    "RL003": "host-sync: device materialization inside the serve hot path",
    "RL004": "kernel-contract: kernels/<name>/ triple or tiles_for broken",
    "RL005": "determinism: unseeded randomness / wall-clock in a sim plane",
}

_HINTS: Dict[str, str] = {
    "RL001": "use jnp.where/lax.cond/lax.select, or mark the argument "
             "static via static_argnames",
    "RL002": "round the size through a pow2 bucket helper "
             "(kernels.autotune.shape_bucket / _decode_bucket) so the "
             "jit sees a bounded shape set",
    "RL003": "keep the sync out of the round loop, fuse it into the "
             "jitted program, or mark a metering site with @metered / "
             "'# repro-lint: allow(RL003) <why>'",
    "RL004": "keep kernel.py (pallas) / ref.py (oracle, pallas-free) / "
             "ops.py (jit wrapper); resolve tiles via autotune.tiles_for",
    "RL005": "thread a seeded random.Random / np.random.default_rng / "
             "jax.random key through the caller instead",
}

# serve hot-path roots (RL003): the per-round / per-request functions a
# hidden host sync taxes on EVERY call
_HOT_ROOTS = {"decode_round", "step", "submit"}
# names assigned from jax.jit in serve's Replica: results are device vals
_DEVICE_ATTR_RE = re.compile(r"^_?(decode|prefill)")
_PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*allow\(([^)]*)\)")
# module-level `random.<fn>` calls that consume the global (unseeded) RNG
_RANDOM_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "expovariate",
    "betavariate", "gammavariate", "lognormvariate", "vonmisesvariate",
    "paretovariate", "weibullvariate", "triangular", "getrandbits",
    "seed",
}
_NP_RANDOM_OK = {"default_rng", "Generator", "RandomState", "SeedSequence",
                 "PRNGKey"}
_WALLCLOCK = {"now", "utcnow", "today"}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # scan-root-relative posix path
    line: int
    scope: str         # enclosing function qualname, or "<module>"
    message: str
    hint: str = ""

    @property
    def key(self) -> str:
        """Baseline-ratchet identity: line-free, so unrelated edits that
        shift line numbers never churn the baseline."""
        return f"{self.rule}:{self.path}:{self.scope}:{self.message}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.scope}] " \
               f"{self.message}" + (f"  (fix: {self.hint})" if self.hint
                                    else "")


@dataclass
class LintReport:
    findings: List[Finding] = field(default_factory=list)
    suppressed: List[Finding] = field(default_factory=list)
    files: int = 0

    def by_rule(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out


# ---------------------------------------------------------------------------
# module indexing
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> Optional[str]:
    """'jnp.argmax' for Attribute chains rooted at a Name, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _root(node: ast.AST) -> Optional[str]:
    d = _dotted(node)
    return d.split(".", 1)[0] if d else None


@dataclass
class FuncInfo:
    qualname: str
    node: ast.AST                        # FunctionDef | AsyncFunctionDef
    module: "ModuleInfo"
    params: List[str]
    static_params: Set[str]              # via static_argnames/argnums
    is_entry: bool = False
    metered: bool = False
    calls: Set[str] = field(default_factory=set)   # simple-name targets


@dataclass
class ModuleInfo:
    path: Path                           # absolute
    rel: str                             # scan-root-relative posix
    tree: ast.Module
    lines: List[str]
    funcs: Dict[str, FuncInfo] = field(default_factory=dict)  # simple name
    imports: Dict[str, Tuple[str, str]] = field(default_factory=dict)
    # local name -> (resolved module key, original name)

    def pragma_allows(self, line: int, rule: str) -> bool:
        for ln in (line, line - 1):
            if 1 <= ln <= len(self.lines):
                m = _PRAGMA_RE.search(self.lines[ln - 1])
                if m:
                    allowed = {s.strip() for s in m.group(1).split(",")}
                    if "*" in allowed or rule in allowed:
                        return True
        return False


def _static_params_of(fn: ast.AST) -> Set[str]:
    """Params pinned static by a partial(jax.jit, static_arg...) deco."""
    out: Set[str] = set()
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    for deco in fn.decorator_list:
        if not (isinstance(deco, ast.Call)
                and _root(deco.func) in ("partial", "functools")):
            continue
        for kw in deco.keywords:
            if kw.arg == "static_argnames":
                for el in ast.walk(kw.value):
                    if isinstance(el, ast.Constant) and \
                            isinstance(el.value, str):
                        out.add(el.value)
            elif kw.arg == "static_argnums":
                for el in ast.walk(kw.value):
                    if isinstance(el, ast.Constant) and \
                            isinstance(el.value, int) and \
                            el.value < len(names):
                        out.add(names[el.value])
    return out


def _is_jit_decorated(fn: ast.AST) -> bool:
    for deco in fn.decorator_list:
        target = deco.func if isinstance(deco, ast.Call) else deco
        d = _dotted(target)
        if d in ("jax.jit", "jit"):
            return True
        if isinstance(deco, ast.Call) and _root(deco.func) in (
                "partial", "functools"):
            for arg in deco.args:
                if _dotted(arg) in ("jax.jit", "jit"):
                    return True
    return False


def _is_metered(fn: ast.AST) -> bool:
    for deco in fn.decorator_list:
        target = deco.func if isinstance(deco, ast.Call) else deco
        d = _dotted(target)
        if d and "metered" in d.split(".")[-1]:
            return True
    return False


class _Indexer(ast.NodeVisitor):
    """One pass per module: functions, imports, call edges, jit entries."""

    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.stack: List[str] = []
        self.fn_stack: List[FuncInfo] = []

    # -- imports ---------------------------------------------------------
    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        key = f"{'.' * node.level}{mod}"
        for alias in node.names:
            self.mod.imports[alias.asname or alias.name] = (key, alias.name)
        self.generic_visit(node)

    # -- functions -------------------------------------------------------
    def _visit_func(self, node) -> None:
        qual = ".".join(self.stack + [node.name]) if self.stack else node.name
        args = node.args
        params = [a.arg for a in
                  args.posonlyargs + args.args + args.kwonlyargs]
        info = FuncInfo(qual, node, self.mod, params,
                        _static_params_of(node),
                        is_entry=_is_jit_decorated(node),
                        metered=_is_metered(node))
        # functions defined inside a `*_jitted*` factory are jit bodies
        if any("_jitted" in s for s in self.stack):
            info.is_entry = True
        self.mod.funcs.setdefault(node.name, info)
        self.stack.append(node.name)
        self.fn_stack.append(info)
        self.generic_visit(node)
        self.fn_stack.pop()
        self.stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    # -- calls -----------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        d = _dotted(node.func)
        name = None
        if isinstance(node.func, ast.Name):
            name = node.func.id
        elif isinstance(node.func, ast.Attribute) and \
                isinstance(node.func.value, ast.Name) and \
                node.func.value.id in ("self", "cls"):
            name = node.func.attr
        if name and self.fn_stack:
            self.fn_stack[-1].calls.add(name)
        # jax.jit(f) / pallas_call(body) / shard_map(body): f is an entry
        if d in ("jax.jit", "jit") or (d and (
                d.split(".")[-1] in ("pallas_call", "shard_map",
                                     "shard_map_compat"))):
            for arg in node.args[:1]:
                if isinstance(arg, ast.Name):
                    fi = self.mod.funcs.get(arg.id)
                    if fi is not None:
                        fi.is_entry = True
                    else:       # forward ref: mark after full pass
                        self.mod._late_entries.add(arg.id)  # type: ignore
        self.generic_visit(node)


def _index_module(path: Path, rel: str) -> Optional[ModuleInfo]:
    try:
        src = path.read_text()
        tree = ast.parse(src)
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    mod = ModuleInfo(path=path, rel=rel, tree=tree,
                     lines=src.splitlines())
    mod._late_entries = set()            # type: ignore[attr-defined]
    _Indexer(mod).visit(tree)
    for name in mod._late_entries:       # type: ignore[attr-defined]
        if name in mod.funcs:
            mod.funcs[name].is_entry = True
    return mod


# ---------------------------------------------------------------------------
# cross-module reachability
# ---------------------------------------------------------------------------

def _module_key(rel: str) -> str:
    return rel[:-3].replace("/", ".") if rel.endswith(".py") else rel


def _resolve_import(mods: Dict[str, ModuleInfo], cur: ModuleInfo,
                    spec: str) -> Optional[str]:
    """Best-effort: map an import spec to a scanned module key."""
    if spec.startswith("."):
        level = len(spec) - len(spec.lstrip("."))
        base = _module_key(cur.rel).split(".")
        base = base[:-level] if level <= len(base) else []
        tail = spec.lstrip(".")
        parts = base + (tail.split(".") if tail else [])
        cand = ".".join(parts)
    else:
        cand = spec
    if cand in mods:
        return cand
    # absolute imports may carry a prefix the scan root strips (e.g.
    # `repro.kernels.x` scanned as `src.repro.kernels.x`): suffix-match
    for key in mods:
        if key == cand or key.endswith("." + cand) or \
                cand.endswith("." + key):
            return key
    return None


def _reachable(mods: Dict[str, ModuleInfo],
               roots: Iterable[FuncInfo]) -> Set[int]:
    """BFS over the (module-resolved) simple-name call graph; returns
    id()s of reachable FuncInfos."""
    seen: Set[int] = set()
    work = list(roots)
    while work:
        fi = work.pop()
        if id(fi) in seen:
            continue
        seen.add(id(fi))
        for callee in fi.calls:
            target = fi.module.funcs.get(callee)
            if target is None and callee in fi.module.imports:
                spec, orig = fi.module.imports[callee]
                mkey = _resolve_import(mods, fi.module, spec)
                if mkey is not None:
                    target = mods[mkey].funcs.get(orig)
            if target is not None and id(target) not in seen:
                work.append(target)
    return seen


# ---------------------------------------------------------------------------
# taint engine (shared by RL001 / RL003)
# ---------------------------------------------------------------------------

_JNP_ROOTS = ("jnp", "jax", "lax")


def _is_jnp_call(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and _root(node.func) in _JNP_ROOTS


# attribute reads on a tracer that yield STATIC python values during a
# trace (aval metadata) — branching on them never leaks the tracer
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "weak_type"}


class _Taint:
    """Forward, order-of-statements taint over one function body."""

    def __init__(self, tainted: Set[str]):
        self.tainted = set(tainted)

    def expr_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if _is_jnp_call(node):
            return True
        if isinstance(node, ast.Call) and _dotted(node.func) == "len":
            return False        # len(tracer) is the static leading dim
        return any(self.expr_tainted(c)
                   for c in ast.iter_child_nodes(node))

    def bind(self, target: ast.AST, tainted: bool) -> None:
        for sub in ast.walk(target):
            if isinstance(sub, ast.Name):
                if tainted:
                    self.tainted.add(sub.id)
                else:
                    self.tainted.discard(sub.id)


def _is_none_check(test: ast.AST) -> bool:
    """`x is None` / `x is not None`: identity on a tracer is safe."""
    return isinstance(test, ast.Compare) and all(
        isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops)


def _iter_body_stmts(fn: ast.AST):
    """Statements of fn in SOURCE order (DFS pre-order — taint binding
    must see a definition before its uses), skipping nested function/
    class bodies (they are analyzed as their own FuncInfo)."""

    def walk(stmts):
        for stmt in stmts:
            yield stmt
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            for name in ("body", "orelse", "finalbody"):
                yield from walk(getattr(stmt, name, []) or [])
            for h in getattr(stmt, "handlers", []) or []:
                yield from walk(h.body)

    yield from walk(fn.body)


def _shallow_walk(stmt: ast.AST):
    """Walk the statement's OWN expressions only — nested statement
    lists are visited by ``_iter_body_stmts`` in their own right, and
    walking them here would double-count every finding."""
    for fname, value in ast.iter_fields(stmt):
        if fname in ("body", "orelse", "finalbody", "handlers"):
            continue
        vals = value if isinstance(value, list) else [value]
        for v in vals:
            if isinstance(v, ast.AST):
                yield from ast.walk(v)


# ---------------------------------------------------------------------------
# RL001 — tracer leak
# ---------------------------------------------------------------------------

_RL001_EXCLUDED_PARAMS = {"self", "cls", "model", "cfg", "config", "mesh"}


def _resolve_callee(mods: Dict[str, ModuleInfo], fi: FuncInfo,
                    name: str) -> Optional[FuncInfo]:
    target = fi.module.funcs.get(name)
    if target is None and name in fi.module.imports:
        spec, orig = fi.module.imports[name]
        mkey = _resolve_import(mods, fi.module, spec)
        if mkey is not None:
            target = mods[mkey].funcs.get(orig)
    return target


def _rl001(mods: Dict[str, ModuleInfo], emit) -> None:
    entries = [fi for m in mods.values() for fi in m.funcs.values()
               if fi.is_entry]
    reach = _reachable(mods, entries)
    infos = [fi for m in mods.values() for fi in m.funcs.values()
             if id(fi) in reach]

    def seedable(fi: FuncInfo, p: str) -> bool:
        return p not in _RL001_EXCLUDED_PARAMS and \
            p not in fi.static_params and p in fi.params

    # interprocedural taint, two layers:
    #   * an ENTRY's params are tracers by definition;
    #   * a reachable helper's param is a tracer only if some call site
    #     inside traced code passes it a tainted argument (blanket param
    #     taint would flag every host-scalar helper the trace consults —
    #     tile pickers, activation-name switches).
    # fixpoint over call sites, then one emitting pass.
    param_taint: Dict[int, Set[str]] = {
        id(fi): ({p for p in fi.params if seedable(fi, p)}
                 if fi.is_entry else set())
        for fi in infos}

    def analyze(fi: FuncInfo, check) -> None:
        t = _Taint(set(param_taint[id(fi)]))
        for stmt in _iter_body_stmts(fi.node):
            check(fi, t, stmt)
            if isinstance(stmt, ast.Assign):
                tainted = t.expr_tainted(stmt.value)
                for tgt in stmt.targets:
                    t.bind(tgt, tainted)
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)) and \
                    stmt.value is not None:
                t.bind(stmt.target, t.expr_tainted(stmt.value))

    def propagate(fi: FuncInfo, t: _Taint, stmt: ast.AST) -> None:
        for sub in _shallow_walk(stmt):
            if not isinstance(sub, ast.Call):
                continue
            name = None
            if isinstance(sub.func, ast.Name):
                name = sub.func.id
            elif isinstance(sub.func, ast.Attribute) and \
                    isinstance(sub.func.value, ast.Name) and \
                    sub.func.value.id in ("self", "cls"):
                name = sub.func.attr
            callee = _resolve_callee(mods, fi, name) if name else None
            if callee is None or id(callee) not in param_taint:
                continue
            # method resolution: skip a leading self/cls param
            params = callee.params
            if params and params[0] in ("self", "cls") and \
                    isinstance(sub.func, ast.Attribute):
                params = params[1:]
            for i, arg in enumerate(sub.args):
                if i < len(params) and t.expr_tainted(arg) and \
                        seedable(callee, params[i]):
                    if params[i] not in param_taint[id(callee)]:
                        param_taint[id(callee)].add(params[i])
                        propagate.changed = True
            for kw in sub.keywords:
                if kw.arg and t.expr_tainted(kw.value) and \
                        seedable(callee, kw.arg):
                    if kw.arg not in param_taint[id(callee)]:
                        param_taint[id(callee)].add(kw.arg)
                        propagate.changed = True

    for _ in range(6):                     # call-graph-depth fixpoint
        propagate.changed = False
        for fi in infos:
            analyze(fi, propagate)
        if not propagate.changed:
            break

    def check(fi: FuncInfo, t: _Taint, stmt: ast.AST) -> None:
        m = fi.module

        def flag(node, what):
            emit(Finding("RL001", m.rel, node.lineno, fi.qualname,
                         f"{what} on traced value "
                         f"`{ast.unparse(node)[:60]}`",
                         _HINTS["RL001"]), m)

        if isinstance(stmt, (ast.If, ast.While)) and \
                not _is_none_check(stmt.test) and \
                t.expr_tainted(stmt.test):
            flag(stmt.test, type(stmt).__name__.lower() + " branch")
        if isinstance(stmt, ast.Assert) and t.expr_tainted(stmt.test):
            flag(stmt.test, "assert")
        for sub in _shallow_walk(stmt):
            if isinstance(sub, ast.IfExp) and \
                    not _is_none_check(sub.test) and \
                    t.expr_tainted(sub.test):
                flag(sub.test, "conditional-expression test")
            if isinstance(sub, ast.Call):
                fname = _dotted(sub.func)
                if fname in ("bool", "int", "float") and sub.args \
                        and t.expr_tainted(sub.args[0]):
                    flag(sub, f"{fname}() coercion")
                if isinstance(sub.func, ast.Attribute) and \
                        sub.func.attr == "item" and \
                        t.expr_tainted(sub.func.value):
                    flag(sub, ".item() materialization")

    for fi in infos:
        analyze(fi, check)


# ---------------------------------------------------------------------------
# RL002 — recompile hazard
# ---------------------------------------------------------------------------

_DYN_SOURCES = {"len"}
_SIZE_ATTRS = {"size", "nbytes"}
_CONSTRUCTORS = {"zeros", "ones", "full", "empty", "arange", "linspace"}
_BOUNDARIES = {"jnp.asarray", "jnp.array", "jax.device_put"}


def _is_round_to_multiple(node: ast.AST) -> bool:
    """`(s + c - 1) // c * c` — the round-up-to-multiple idiom.  Like a
    `*bucket*` helper it bounds the shape set the jit sees (the chunked
    prefill loop only ever dispatches length-c segments), so a value
    computed this way is treated as bucketed."""
    return isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult) \
        and isinstance(node.left, ast.BinOp) \
        and isinstance(node.left.op, ast.FloorDiv)


def _dyn_expr(node: ast.AST, dyn: Set[str], bucketed: Set[str]) -> bool:
    """Does the expression carry an unbucketed dynamic size?"""
    if _is_round_to_multiple(node):
        return False
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            d = _dotted(sub.func)
            if d and "bucket" in d.split(".")[-1]:
                return False              # routed through the idiom
            if d in _DYN_SOURCES:
                return True
        if isinstance(sub, ast.Attribute) and sub.attr in _SIZE_ATTRS:
            return True
        if isinstance(sub, ast.Subscript) and \
                isinstance(sub.value, ast.Attribute) and \
                sub.value.attr == "shape":
            return True
        if isinstance(sub, ast.Name) and sub.id in dyn and \
                sub.id not in bucketed:
            return True
    return False


def _rl002(mods: Dict[str, ModuleInfo], emit) -> None:
    for m in mods.values():
        jit_names = {n for n, fi in m.funcs.items() if fi.is_entry}
        for alias, (spec, orig) in m.imports.items():
            mkey = _resolve_import(mods, m, spec)
            if mkey and orig in mods[mkey].funcs and \
                    mods[mkey].funcs[orig].is_entry:
                jit_names.add(alias)
        for fi in m.funcs.values():
            dyn: Set[str] = set()        # unbucketed dynamic scalars
            bucketed: Set[str] = set()
            dyn_arrays: Set[str] = set()  # arrays with dynamic shapes
            for stmt in _iter_body_stmts(fi.node):
                for sub in _shallow_walk(stmt):
                    if not isinstance(sub, ast.Call):
                        continue
                    d = _dotted(sub.func) or ""
                    tail = d.split(".")[-1]
                    # boundary crossing?
                    target_fi = m.funcs.get(tail) if tail in jit_names \
                        else None
                    is_boundary = d in _BOUNDARIES or tail in jit_names
                    if is_boundary:
                        for arg in list(sub.args) + \
                                [k.value for k in sub.keywords]:
                            names = {n.id for n in ast.walk(arg)
                                     if isinstance(n, ast.Name)}
                            if names & dyn_arrays:
                                emit(Finding(
                                    "RL002", m.rel, sub.lineno,
                                    fi.qualname,
                                    "dynamically-shaped array "
                                    f"`{ast.unparse(arg)[:50]}` crosses "
                                    f"jit boundary `{d or tail}` "
                                    "unbucketed", _HINTS["RL002"]), m)
                        # dynamic scalar into a static argname: retrace
                        # per distinct value
                        statics = target_fi.static_params if target_fi \
                            else set()
                        for kw in sub.keywords:
                            if kw.arg in statics and _dyn_expr(
                                    kw.value, dyn, bucketed):
                                emit(Finding(
                                    "RL002", m.rel, sub.lineno,
                                    fi.qualname,
                                    f"dynamic scalar flows into static "
                                    f"argname `{kw.arg}` of `{tail}`",
                                    _HINTS["RL002"]), m)
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name):
                    name = stmt.targets[0].id
                    v = stmt.value
                    d = (_dotted(v.func) or "") if isinstance(v, ast.Call) \
                        else ""
                    tail = d.split(".")[-1]
                    if (isinstance(v, ast.Call) and "bucket" in tail) or \
                            _is_round_to_multiple(v):
                        bucketed.add(name)
                        dyn.discard(name)
                        dyn_arrays.discard(name)
                    elif isinstance(v, ast.Call) and \
                            tail in _CONSTRUCTORS and v.args and \
                            _dyn_expr(v.args[0], dyn, bucketed):
                        dyn_arrays.add(name)
                    elif _dyn_expr(v, dyn, bucketed):
                        dyn.add(name)
                        dyn_arrays.discard(name)
                    else:
                        dyn.discard(name)
                        dyn_arrays.discard(name)


# ---------------------------------------------------------------------------
# RL003 — host sync in the serve hot path
# ---------------------------------------------------------------------------

def _rl003(mods: Dict[str, ModuleInfo], emit) -> None:
    serve_mods = {k: m for k, m in mods.items()
                  if "serve/" in m.rel or "/serve" in m.rel.rsplit("/", 1)[0]}
    if not serve_mods:
        return
    roots = [fi for m in serve_mods.values() for n, fi in m.funcs.items()
             if n in _HOT_ROOTS or "fused" in n]
    hot = _reachable(serve_mods, roots)
    # kernel wrappers imported into serve return device values
    for m in serve_mods.values():
        kernel_imports = {alias for alias, (spec, _) in m.imports.items()
                          if "kernel" in spec}
        for fi in m.funcs.values():
            if id(fi) not in hot or fi.metered:
                continue
            t = _Taint(set())

            def device_expr(node: ast.AST) -> bool:
                if isinstance(node, ast.Call):
                    d = _dotted(node.func) or ""
                    parts = d.split(".")
                    # np.* / device_get results live on the HOST — the
                    # sync is the call itself (flagged by `check`), not
                    # later uses of its result
                    if parts[0] in ("np", "numpy") or \
                            parts[-1] == "device_get":
                        return False
                    if _is_jnp_call(node):
                        return True
                    if isinstance(node.func, ast.Name) and \
                            node.func.id in kernel_imports:
                        return True
                    if isinstance(node.func, ast.Attribute) and \
                            _DEVICE_ATTR_RE.match(node.func.attr) and \
                            isinstance(node.func.value, ast.Name):
                        return True
                if isinstance(node, ast.Name):
                    return node.id in t.tainted
                return any(device_expr(c)
                           for c in ast.iter_child_nodes(node))

            def check(sub: ast.Call) -> Optional[str]:
                d = _dotted(sub.func) or ""
                tail = d.split(".")[-1]
                if tail == "block_until_ready":
                    return "jax.block_until_ready"
                if tail == "device_get":
                    return "jax.device_get"
                if d in ("np.asarray", "numpy.asarray", "np.array",
                         "numpy.array") and sub.args and \
                        device_expr(sub.args[0]):
                    return d
                if d in ("int", "float") and sub.args and \
                        device_expr(sub.args[0]):
                    return f"{d}()"
                if isinstance(sub.func, ast.Attribute) and \
                        sub.func.attr == "item" and \
                        device_expr(sub.func.value):
                    return ".item()"
                return None

            for stmt in _iter_body_stmts(fi.node):
                for sub in _shallow_walk(stmt):
                    if isinstance(sub, ast.Call):
                        what = check(sub)
                        if what:
                            emit(Finding(
                                "RL003", m.rel, sub.lineno, fi.qualname,
                                f"host sync `{what}` in serve hot path "
                                f"(`{ast.unparse(sub)[:60]}`)",
                                _HINTS["RL003"]), m)
                if isinstance(stmt, ast.Assign):
                    value_dev = device_expr(stmt.value)
                    for tgt in stmt.targets:
                        t.bind(tgt, value_dev)


# ---------------------------------------------------------------------------
# RL004 — kernel directory contract
# ---------------------------------------------------------------------------

def _imports_pallas(tree: ast.Module) -> Optional[int]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if "pallas" in a.name:
                    return node.lineno
        elif isinstance(node, ast.ImportFrom):
            if "pallas" in (node.module or ""):
                return node.lineno
            for a in node.names:
                if "pallas" in a.name:
                    return node.lineno
    return None


def _mentions_tiles_for(tree: ast.Module) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and node.id == "tiles_for":
            return True
        if isinstance(node, ast.Attribute) and node.attr == "tiles_for":
            return True
        if isinstance(node, (ast.Import, ast.ImportFrom)) and any(
                a.name == "tiles_for" or a.asname == "tiles_for"
                for a in node.names):
            return True
    return False


def _rl004(mods: Dict[str, ModuleInfo], emit) -> None:
    by_dir: Dict[Path, Dict[str, ModuleInfo]] = {}
    for m in mods.values():
        parent = m.path.parent
        if parent.parent.name == "kernels" and \
                parent.name != "__pycache__":
            by_dir.setdefault(parent, {})[m.path.name] = m
    for d, files in sorted(by_dir.items()):
        rel_dir = next(iter(files.values())).rel.rsplit("/", 1)[0]
        anchor = next(iter(files.values()))
        missing = {"kernel.py", "ref.py", "ops.py"} - set(files)
        if missing:
            emit(Finding("RL004", rel_dir, 1, "<dir>",
                         f"kernel dir missing {sorted(missing)} of the "
                         "kernel/ref/ops triple", _HINTS["RL004"]), anchor)
        ref = files.get("ref.py")
        if ref is not None:
            ln = _imports_pallas(ref.tree)
            if ln is not None:
                emit(Finding("RL004", ref.rel, ln, "<module>",
                             "ref.py imports pallas — the oracle must "
                             "run without the kernel toolchain",
                             _HINTS["RL004"]), ref)
        impl = [files[n] for n in ("kernel.py", "ops.py") if n in files]
        if impl and not any(_mentions_tiles_for(m.tree) for m in impl):
            emit(Finding("RL004", impl[0].rel, 1, "<module>",
                         "kernel tiles not resolved via "
                         "autotune.tiles_for", _HINTS["RL004"]), impl[0])


# ---------------------------------------------------------------------------
# RL005 — determinism in the simulation planes
# ---------------------------------------------------------------------------

def _rl005(mods: Dict[str, ModuleInfo], emit) -> None:
    for m in mods.values():
        if not ("/dht/" in f"/{m.rel}" or "/core/" in f"/{m.rel}"):
            continue
        scope = "<module>"
        for node in ast.walk(m.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scope = node.name        # coarse but stable
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if not d:
                continue
            parts = d.split(".")
            if parts[0] == "random" and len(parts) == 2 and \
                    parts[1] in _RANDOM_FNS:
                emit(Finding("RL005", m.rel, node.lineno, scope,
                             f"unseeded global RNG call `{d}()`",
                             _HINTS["RL005"]), m)
            elif len(parts) >= 3 and parts[0] in ("np", "numpy") and \
                    parts[1] == "random" and parts[2] not in _NP_RANDOM_OK:
                emit(Finding("RL005", m.rel, node.lineno, scope,
                             f"global numpy RNG call `{d}()`",
                             _HINTS["RL005"]), m)
            elif parts[-1] in _WALLCLOCK and "datetime" in parts or \
                    (len(parts) == 2 and parts[0] in ("datetime", "date")
                     and parts[1] in _WALLCLOCK):
                emit(Finding("RL005", m.rel, node.lineno, scope,
                             f"wall-clock read `{d}()` in a sim plane",
                             _HINTS["RL005"]), m)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def collect_files(paths: Sequence[Path]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_file() and p.suffix == ".py":
            out.append(p)
        elif p.is_dir():
            out.extend(f for f in sorted(p.rglob("*.py"))
                       if "__pycache__" not in f.parts)
    return out


def run_lint(paths: Sequence, root: Optional[Path] = None,
             rules: Optional[Set[str]] = None) -> LintReport:
    """Lint ``paths`` (files or directories); findings carry paths
    relative to ``root`` (default: cwd) so baseline keys are stable
    across checkouts."""
    root = Path(root) if root is not None else Path.cwd()
    files = collect_files([Path(p) for p in paths])
    mods: Dict[str, ModuleInfo] = {}
    for f in files:
        try:
            rel = f.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        mod = _index_module(f, rel)
        if mod is not None:
            mods[_module_key(rel)] = mod
    report = LintReport(files=len(mods))

    def emit(finding: Finding, mod: ModuleInfo) -> None:
        if rules is not None and finding.rule not in rules:
            return
        if mod.pragma_allows(finding.line, finding.rule):
            report.suppressed.append(finding)
        else:
            report.findings.append(finding)

    _rl001(mods, emit)
    _rl002(mods, emit)
    _rl003(mods, emit)
    _rl004(mods, emit)
    _rl005(mods, emit)
    report.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return report
