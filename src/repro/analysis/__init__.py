"""Correctness tooling: repro-lint static analysis + runtime sanitizer.

* ``python -m repro.analysis`` — run the RL001–RL005 lint over the tree
  and gate against the committed ``baseline.json`` ratchet (DESIGN.md
  §14).
* ``REPRO_SANITIZE=1`` + ``sanitize.install()`` — runtime invariant
  wrappers over RingState / BlockStore / Replica (installed by
  ``tests/conftest.py`` for the tier-1 suite).
"""
from .baseline import Baseline, Diff
from .lint import RULES, Finding, LintReport, run_lint
from .metering import is_metered, metered
from .sanitize import SanitizeError

__all__ = [
    "Baseline", "Diff", "Finding", "LintReport", "RULES", "run_lint",
    "metered", "is_metered", "SanitizeError",
]
