"""Measured request-latency plane — D1HT vs a directory server under
load (paper §VII-D, Figs 5-6).

``repro.dht.latency`` keeps the closed-form oracle; this module MEASURES
the same experiment from the repo's own components instead of
hand-calibrated constants:

  * **routing cost** — timed batched ``RingState.lookup`` calls through
    the real ``ring_lookup_bucketed`` Pallas kernel (the origin peer's
    local table walk; the flat ``ring_lookup64`` scan below the bucket
    threshold);
  * **directory-server capacity** — one local ``DirectoryWorker``
    (socket-backed recv -> SHA-1 hash -> successor bisect -> reply loop)
    saturated until its completion rate is service-bound, reproducing
    the paper's Cluster-B 1,600-client saturation methodology instead of
    hardcoding ``DSERVER_SAT_CLIENTS``;
  * **single-hop target service** — the same saturation measurement for
    a ``PeerWorker`` (the owner answers from its local store);
  * **stale-table retries** — the f' fraction is NOT a free parameter:
    it is the ``stale_fraction`` (1 - one-hop fraction) the PR-4 churn
    plane measures for the same ring size and §VII session dynamics
    (``repro.core.jax_sim.simulate_churn``), per protocol.

A vectorized closed-loop load generator then plays the experiment in
simulated time: n clients, each thinking Exp(1/lookup_rate) between
lookups over a ``window_s``-second measurement window; network legs are
sampled from the DES ``LanDelay`` shape (10 us floor + exponential
tail, 70 us one-way mean = the 0.14 ms measured hop); the directory
server is an explicit FCFS queue over the measured service time.  Past
saturation the closed population bounds the backlog — sojourns converge
to n*S - Z by Little's law with a permanently busy server — which is
exactly the regime the closed-form ``dserver_ms`` caps with its
finite-window term, so measured and model stay comparable on BOTH sides
of the knee.
"""
from __future__ import annotations

import math
import socket
import struct
import time
from bisect import bisect_left
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.ring import hash_id
from repro.core.ringstate import RingState

from .latency import (DSERVER_WINDOW_S, HOP_MS_IDLE, LOOKUPS_PER_SEC,
                      RETRY_PENALTY_MS, busy_factor, latency_sweep)

# Network legs share the DES LanDelay shape: a 10 us switching/NIC floor
# plus an exponential tail, total one-way mean 70 us (= 0.14 ms RTT, the
# paper's measured one-hop latency that HOP_MS_IDLE encodes).
HOP_ONE_WAY_S = HOP_MS_IDLE * 1e-3 / 2.0
HOP_FLOOR_S = 10e-6

PASTRY_BASE = 4              # Chimera routes with base-4 digits


# ---------------------------------------------------------------------------
# Local workers + the saturation measurement (§VII-D methodology)
# ---------------------------------------------------------------------------

class DirectoryWorker:
    """The directory server's request handler.

    A lookup datagram carries the session id as the key VALUE (a
    string): the server must hash it onto the ring (SHA-1, as every peer
    would), resolve the successor on its full sorted peer table and
    reply (key, owner).  Deliberately the paper's baseline — one
    single-threaded process with a plain sorted table — NOT our
    device-resident lookup plane; the comparison is the point."""

    def __init__(self, ids: Sequence[int]):
        self.ids: List[int] = sorted(int(i) for i in ids)

    def handle(self, datagram: bytes) -> bytes:
        key = hash_id(f"session/{datagram.decode()}")
        i = bisect_left(self.ids, key)
        owner = self.ids[i % len(self.ids)]
        return struct.pack("!QQ", key, owner)


class PeerWorker:
    """The single-hop target: the owner peer holds the key locally and
    answers from its in-memory store (one hashtable get)."""

    def __init__(self, entries: int = 4096):
        self.store: Dict[str, int] = {f"s{i}": i for i in range(entries)}
        self.entries = entries

    def handle(self, datagram: bytes) -> bytes:
        sid = datagram.decode()
        return struct.pack("!Q", self.store.get(sid, 0))


def measure_worker_service_us(worker, *, requests: int = 20_000,
                              repeats: int = 5, chunk: int = 48) -> float:
    """Service time of one saturated local worker (microseconds/request).

    The paper saturated the directory server by ramping clients until
    its completion rate stopped rising; locally the equivalent is
    keeping the worker's inbound socket non-empty and timing ONLY the
    worker loop (recv -> handle -> send): ``chunk`` datagrams are
    pre-queued, the drain is timed, replies are drained outside the
    timed region.  Best-of-``repeats`` — a loaded host can only slow
    the worker down, never speed it up, so several shortish repeats
    sampling different time windows beat one long one under noisy
    neighbours.  Falls back to a socketless handler loop on platforms
    without AF_UNIX datagram pairs."""
    reqs = [f"client-{i}-session-{i % 997}".encode() for i in range(2048)]
    if not hasattr(socket, "AF_UNIX"):        # pragma: no cover
        best = math.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            for i in range(requests):
                worker.handle(reqs[i % len(reqs)])
            best = min(best, time.perf_counter() - t0)
        return best / requests * 1e6

    best = math.inf
    for _ in range(repeats):
        a, b = socket.socketpair(socket.AF_UNIX, socket.SOCK_DGRAM)
        sink_rx, sink_tx = socket.socketpair(socket.AF_UNIX,
                                             socket.SOCK_DGRAM)
        try:
            busy = 0.0
            done = 0
            while done < requests:
                k = min(chunk, requests - done)
                for i in range(k):
                    a.send(reqs[(done + i) % len(reqs)])
                t0 = time.perf_counter()      # k requests queued: the
                for _ in range(k):            # worker never idles here
                    sink_tx.send(worker.handle(b.recv(512)))
                busy += time.perf_counter() - t0
                for _ in range(k):
                    sink_rx.recv(512)         # drain outside the timing
                done += k
            best = min(best, busy / requests)
        finally:
            for s in (a, b, sink_rx, sink_tx):
                s.close()
    return best * 1e6


def _random_ring(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.unique(rng.integers(1, 2**63, n * 9 // 8 + 8,
                                  dtype=np.uint64))[:n]


def measure_route_us_per_key(n: int, *, batch: int = 2048,
                             repeats: int = 3, seed: int = 0) -> float:
    """Per-key cost of the origin's LOCAL table walk: batched
    ``RingState.lookup`` (``ring_lookup_bucketed`` at scale), timed
    best-of-``repeats`` after a warmup call absorbs trace + upload."""
    state = RingState(_random_ring(n, seed))
    rng = np.random.default_rng(seed + 1)
    keys = rng.integers(0, 2**63, batch, dtype=np.uint64)
    state.lookup(keys)                         # warmup: trace + upload
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        state.lookup(keys)
        best = min(best, time.perf_counter() - t0)
    return best / batch * 1e6


@dataclass(frozen=True)
class ServiceProfile:
    """Everything the load generator needs that was MEASURED, not
    assumed, on this host."""

    route_us_per_key: float       # batched ring_lookup per-key walk
    dserver_service_us: float     # saturated DirectoryWorker
    peer_service_us: float        # saturated PeerWorker
    table_n: int                  # directory table size measured against
    requests: int                 # saturation requests per worker

    @property
    def dserver_mu(self) -> float:
        """Directory-server service rate (requests/s)."""
        return 1e6 / self.dserver_service_us

    def saturation_clients(self,
                           lookup_rate: float = LOOKUPS_PER_SEC) -> float:
        """The measured twin of DSERVER_SAT_CLIENTS: how many closed-loop
        clients at ``lookup_rate`` saturate the measured worker."""
        return self.dserver_mu / lookup_rate


def measure_profile(*, table_n: int = 4000, requests: int = 20_000,
                    repeats: int = 5, seed: int = 0,
                    route_batch: int = 2048) -> ServiceProfile:
    # workers before the route timing: the kernel warmup spins up jax
    # thread pools that can perturb a concurrent socket-loop sample
    dserver_us = measure_worker_service_us(
        DirectoryWorker(_random_ring(table_n, seed)),
        requests=requests, repeats=repeats)
    peer_us = measure_worker_service_us(
        PeerWorker(), requests=requests, repeats=repeats)
    return ServiceProfile(
        route_us_per_key=measure_route_us_per_key(
            table_n, batch=route_batch, repeats=repeats, seed=seed),
        dserver_service_us=dserver_us,
        peer_service_us=peer_us,
        table_n=table_n, requests=requests)


# ---------------------------------------------------------------------------
# Churn-emergent retry fraction (PR-4 plane)
# ---------------------------------------------------------------------------

def measured_retry_fraction(n: int, *, protocol: str = "d1ht",
                            s_avg: float = 174 * 60.0,
                            duration: float = 600.0, warmup: float = 120.0,
                            seed: int = 0,
                            volatile_fraction: float = 0.0) -> float:
    """f' for ``protocol`` at ring size n, emergent from the vectorized
    churn plane: the expected stale-routing-entry fraction a random
    lookup hits (1 - one-hop fraction) under live EDRA dissemination —
    NOT the 0.01 free parameter of the closed form."""
    from repro.core.churn import ChurnConfig
    from repro.core.jax_sim import simulate_churn
    r = simulate_churn(ChurnConfig(
        n=n, s_avg=s_avg, protocol=protocol, duration=duration,
        warmup=warmup, seed=seed, volatile_fraction=volatile_fraction))
    return r.stale_fraction


# ---------------------------------------------------------------------------
# Vectorized closed-loop generator
# ---------------------------------------------------------------------------

def _one_way(rng, size: int) -> np.ndarray:
    """One-way network leg, LanDelay-shaped (seconds)."""
    return HOP_FLOOR_S + rng.exponential(HOP_ONE_WAY_S - HOP_FLOOR_S, size)


def closed_loop_fcfs(rng, *, clients: int, think_s: float, service_s: float,
                     window_s: float, slice_s: Optional[float] = None,
                     max_requests: int = 5_000_000) -> np.ndarray:
    """Time-sliced vectorized closed-loop FCFS single server.

    Every client cycles think -> request -> (queue + service) -> think;
    service is the measured deterministic time.  Time advances in
    slices much shorter than the think time: a slice's arrivals are
    served in exact FCFS order with a vectorized Lindley recursion
    (``d_j = max(d_{j-1}, a_j) + S`` unrolled as a running max), and the
    server's busy horizon carries across slices, so cross-slice order is
    exact too.  The single approximation: a client whose think time
    expires INSIDE the current slice re-arrives at the slice boundary —
    an arrival-time shift bounded by ``slice_s`` (default think/16),
    which biases neither the sojourn measurement nor the offered load.

    Returns the sojourn time (queue wait + service, seconds) of every
    request that arrived inside the window."""
    slice_s = slice_s if slice_s is not None else think_s / 16.0
    t = rng.exponential(think_s, clients)      # desynchronized arrivals
    free = 0.0
    out: List[np.ndarray] = []
    total = 0
    t0 = 0.0
    while t0 < window_s and total < max_requests:
        t1 = t0 + slice_s
        idx = np.nonzero((t >= t0) & (t < t1))[0]
        if idx.size:
            sel = idx[np.argsort(t[idx], kind="stable")]
            a = t[sel]
            k = np.arange(a.size)
            d = service_s * (k + 1) + np.maximum.accumulate(
                np.maximum(a, free) - k * service_s)
            out.append(d - a)
            total += a.size
            free = float(d[-1])
            # re-arrivals that would land inside this slice defer to its
            # boundary (they were not in ``idx`` and must not be lost)
            t[sel] = np.maximum(d + rng.exponential(think_s, a.size), t1)
        t0 = t1
    return np.concatenate(out) if out else np.zeros(0)


def measured_route_samples(state: RingState, rng, requests: int,
                           batch: int = 4096) -> np.ndarray:
    """Per-request route times (seconds) from driving REAL batched
    lookups through ``state`` — ``ring_lookup_bucketed`` on-device at
    scale — with the measured per-batch wall time spread across the
    batch.  Measured once per experiment row and shared by every
    single-hop protocol (the route walk does not depend on f')."""
    route_s = np.empty(requests)
    keys = rng.integers(0, 2**63, requests, dtype=np.uint64)
    state.lookup(keys[:min(batch, requests)])  # warmup: trace + upload
    for lo in range(0, requests, batch):
        hi = min(lo + batch, requests)
        t0 = time.perf_counter()
        state.lookup(keys[lo:hi])
        route_s[lo:hi] = (time.perf_counter() - t0) / (hi - lo)
    return route_s


def simulate_single_hop(rng, *, requests: int, retry_fraction: float,
                        service_us: float, busy_mult: float,
                        route_us_per_key: float = 0.0,
                        route_s: Optional[np.ndarray] = None,
                        state: Optional[RingState] = None,
                        batch: int = 4096) -> np.ndarray:
    """D1HT / 1h-Calot: local table walk + one acked network hop, retry
    (timeout + second hop) for the stale-table fraction.

    ``route_s`` carries pre-measured per-request route times (see
    ``measured_route_samples``); with ``state`` instead, the generator
    measures them here; otherwise the profiled ``route_us_per_key``
    stands in (model-extended rows)."""
    r = requests
    if route_s is not None:
        assert route_s.size == r
    elif state is not None:
        route_s = measured_route_samples(state, rng, r, batch)
    else:
        route_s = np.full(r, route_us_per_key * 1e-6)
    svc = service_us * 1e-6 * busy_mult
    lat = route_s + (_one_way(rng, r) + _one_way(rng, r)) * busy_mult + svc
    retry = np.nonzero(rng.random(r) < retry_fraction)[0]
    lat[retry] += RETRY_PENALTY_MS * 1e-3 + svc + (
        _one_way(rng, retry.size) + _one_way(rng, retry.size)) * busy_mult
    return lat


def simulate_pastry(rng, *, requests: int, n: int, service_us: float,
                    busy_mult: float, base: int = PASTRY_BASE) -> np.ndarray:
    """Multi-hop baseline: log_base(n) chained acked exchanges (Chimera
    acks per overlay hop), each a full request-hop: two network legs
    plus the hop peer's processing."""
    h = max(1.0, math.log(max(n, 2)) / math.log(base))
    hops = np.full(requests, int(h), np.int64)
    hops += rng.random(requests) < (h - int(h))   # mean exactly h
    lat = np.zeros(requests)
    svc = service_us * 1e-6 * busy_mult
    for i in range(int(np.max(hops))):
        m = np.nonzero(hops > i)[0]
        lat[m] += (_one_way(rng, m.size) + _one_way(rng, m.size)) \
            * busy_mult + svc
    return lat


def simulate_dserver(rng, *, clients: int, service_us: float,
                     busy_mult: float, window_s: float = DSERVER_WINDOW_S,
                     lookup_rate: float = LOOKUPS_PER_SEC) -> np.ndarray:
    """Directory server: closed-loop FCFS queue at the measured service
    rate plus the request/reply legs.  The server runs on its own node;
    the busy co-scheduling penalty hits the client-side network stack
    (exactly what the closed form applies it to)."""
    soj = closed_loop_fcfs(rng, clients=clients, think_s=1.0 / lookup_rate,
                           service_s=service_us * 1e-6, window_s=window_s)
    return soj + (_one_way(rng, soj.size) + _one_way(rng, soj.size)) \
        * busy_mult


# ---------------------------------------------------------------------------
# The experiment driver (Figs 5-6 rows)
# ---------------------------------------------------------------------------

def stats_ms(lat_s: np.ndarray) -> Dict[str, float]:
    ms = np.asarray(lat_s) * 1e3
    return {
        "mean_ms": round(float(ms.mean()), 4),
        "p50_ms": round(float(np.percentile(ms, 50)), 4),
        "p99_ms": round(float(np.percentile(ms, 99)), 4),
        "p999_ms": round(float(np.percentile(ms, 99.9)), 4),
        "requests": int(ms.size),
    }


def latency_point(n: int, *, busy: bool, profile: ServiceProfile,
                  fprime: Dict[str, float], nodes: int = 400,
                  window_s: float = DSERVER_WINDOW_S,
                  lookup_rate: float = LOOKUPS_PER_SEC,
                  requests: int = 200_000, seed: int = 0,
                  drive_kernel: bool = True) -> dict:
    """One measured Figs-5/6 row: all four systems at ring size n, plus
    the closed-form oracle evaluated AT the measured parameters and the
    per-system measured/model ratio."""
    rng = np.random.default_rng((seed << 8) ^ n ^ (1 << 20 if busy else 0))
    ppn = n / nodes
    bf = busy_factor(busy, ppn)
    # one set of real kernel drives per row, shared by both single-hop
    # protocols: the route walk is identical, only f' differs
    route_s = measured_route_samples(
        RingState(_random_ring(n, seed)), rng, requests) \
        if drive_kernel else None

    model = latency_sweep(
        [n], busy=busy, nodes=nodes, mu=profile.dserver_mu,
        window_s=window_s, lookup_rate=lookup_rate,
        d1ht_f=fprime["d1ht"], calot_f=fprime["calot"])[n]
    measured = {
        "d1ht": simulate_single_hop(
            rng, requests=requests, retry_fraction=fprime["d1ht"],
            service_us=profile.peer_service_us, busy_mult=bf,
            route_us_per_key=profile.route_us_per_key, route_s=route_s),
        "calot": simulate_single_hop(
            rng, requests=requests, retry_fraction=fprime["calot"],
            service_us=profile.peer_service_us, busy_mult=bf,
            route_us_per_key=profile.route_us_per_key, route_s=route_s),
        "pastry": simulate_pastry(
            rng, requests=requests, n=n,
            service_us=profile.peer_service_us, busy_mult=bf),
        "dserver": simulate_dserver(
            rng, clients=n, service_us=profile.dserver_service_us,
            busy_mult=bf, window_s=window_s, lookup_rate=lookup_rate),
    }
    util = n * lookup_rate / profile.dserver_mu
    row = {
        "n": n, "busy": busy, "peers_per_node": round(ppn, 2),
        "mode": "measured",
        "retry_fraction": {k: round(v, 5) for k, v in fprime.items()},
        "dserver_util": round(util, 4),
        "sub_saturation": bool(util < 0.9),
        "systems": {},
    }
    for name, lat in measured.items():
        model_ms = getattr(model, f"{name}_ms")
        st = stats_ms(lat)
        st["model_ms"] = round(model_ms, 4)
        st["ratio_measured_over_model"] = round(
            st["mean_ms"] / max(model_ms, 1e-9), 3)
        row["systems"][name] = st
    return row


def model_extended_point(n: int, *, busy: bool, profile: ServiceProfile,
                         fprime: Dict[str, float], nodes: int = 400,
                         window_s: float = DSERVER_WINDOW_S,
                         lookup_rate: float = LOOKUPS_PER_SEC) -> dict:
    """Closed-form-only row for the n = 10^4..10^6 extension (the paper
    could only model this regime too), evaluated at the MEASURED worker
    rate and churn-emergent f' so the extension is anchored to the same
    parameters as the measured rows."""
    pt = latency_sweep([n], busy=busy, nodes=nodes, mu=profile.dserver_mu,
                       window_s=window_s, lookup_rate=lookup_rate,
                       d1ht_f=fprime["d1ht"], calot_f=fprime["calot"])[n]
    util = n * lookup_rate / profile.dserver_mu
    return {
        "n": n, "busy": busy, "peers_per_node": round(n / nodes, 2),
        "mode": "model-extended",
        "retry_fraction": {k: round(v, 5) for k, v in fprime.items()},
        "dserver_util": round(util, 4),
        "sub_saturation": bool(util < 0.9),
        "systems": {name: {"model_ms": round(getattr(pt, f"{name}_ms"), 4)}
                    for name in ("d1ht", "calot", "pastry", "dserver")},
    }


def latency_experiment(sizes: Sequence[int], *, busy: bool,
                       profile: Optional[ServiceProfile] = None,
                       nodes: int = 400,
                       window_s: float = DSERVER_WINDOW_S,
                       lookup_rate: float = LOOKUPS_PER_SEC,
                       requests: int = 200_000, seed: int = 0,
                       churn: bool = True, churn_duration: float = 600.0,
                       churn_warmup: float = 120.0,
                       fprime: Optional[Dict[str, float]] = None,
                       drive_kernel: bool = True) -> List[dict]:
    """The full measured sweep for one regime (idle or busy).

    ``churn=True`` measures f' per (n, protocol) from the vectorized
    churn plane; ``fprime`` overrides it (tests inject known values).
    """
    profile = profile if profile is not None else measure_profile()
    rows = []
    for n in sizes:
        if fprime is not None:
            fp = dict(fprime)
        elif churn:
            fp = {p: measured_retry_fraction(
                n, protocol=p, duration=churn_duration,
                warmup=churn_warmup, seed=seed) for p in ("d1ht", "calot")}
        else:
            fp = {"d1ht": 0.01, "calot": 0.012}
        rows.append(latency_point(
            n, busy=busy, profile=profile, fprime=fp, nodes=nodes,
            window_s=window_s, lookup_rate=lookup_rate, requests=requests,
            seed=seed, drive_kernel=drive_kernel))
    return rows
