"""Live D1HT peer over real UDP sockets (asyncio, loopback-friendly).

The DES (repro.dht.des) gives deterministic, byte-accounted experiments;
this node is the deployment path: the same EDRA state machine speaking
actual datagrams. Wire format follows Fig. 2 — a fixed header
(type, seqno, port, system id) followed by 4-byte IPv4 events (6-byte
with port; here: 6-byte ip+port for loopback multi-port testing).

Used by tests/test_udp_cluster.py to spin up a small live ring on
127.0.0.1, kill a peer, and watch EDRA converge over real sockets.
"""
from __future__ import annotations

import asyncio
import socket
import struct
import time
from typing import Dict, List, Optional, Set, Tuple

from repro.core.edra import Event, EventBuffer
from repro.core.ring import RoutingTable, in_interval, peer_id
from repro.core.tuning import EdraParams

MAGIC = 0xD147
T_MAINT, T_PROBE, T_PROBE_R, T_JOIN_REQ, T_TABLE, T_LEAVING, \
    T_FWD_JOIN = range(7)
HDR = struct.Struct("!HBHI")          # magic, type, port, seqno
EV = struct.Struct("!B4sHQ")          # kind, ip4, port, seq


def encode_events(events: List[Event]) -> bytes:
    out = b""
    for e in events:
        ip, port = e.addr
        out += EV.pack(1 if e.kind == "join" else 0,
                       socket.inet_aton(ip), port, e.seq)
    return out


def decode_events(buf: bytes) -> List[Event]:
    out = []
    for off in range(0, len(buf) - EV.size + 1, EV.size):
        kind, ip4, port, seq = EV.unpack_from(buf, off)
        addr = (socket.inet_ntoa(ip4), port)
        out.append(Event(subject_id=peer_id(*addr),
                         kind="join" if kind else "leave",
                         addr=addr, seq=seq))
    return out


class UdpD1HTPeer(asyncio.DatagramProtocol):
    def __init__(self, host: str, port: int, params: EdraParams):
        self.addr = (host, port)
        self.id = peer_id(host, port)
        self.params = params
        self.theta = max(params.theta, 0.2)
        self.rho = params.rho
        self.table = RoutingTable([self.id])
        self.addr_of: Dict[int, Tuple[str, int]] = {self.id: self.addr}
        self.buffer = EventBuffer(self.rho)
        self.seen: Set[Tuple[int, str, int]] = set()
        self.dead: Set[int] = set()          # leave tombstones (anti-entropy)
        self.last_pred = time.monotonic()
        self.probing: Optional[int] = None
        self.seq = 0
        self.transport: Optional[asyncio.DatagramTransport] = None
        self._task: Optional[asyncio.Task] = None
        self.running = False

    # -- lifecycle ----------------------------------------------------------
    async def start(self) -> None:
        loop = asyncio.get_running_loop()
        self.transport, _ = await loop.create_datagram_endpoint(
            lambda: self, local_addr=self.addr)
        self.running = True
        self._task = asyncio.create_task(self._interval_loop())

    async def join(self, bootstrap: Tuple[str, int]) -> None:
        await self.start()
        self._send(bootstrap, T_JOIN_REQ, b"")

    async def stop(self) -> None:
        self.running = False
        if self._task:
            self._task.cancel()
        if self.transport:
            self.transport.close()

    # -- transport ------------------------------------------------------------
    def _send(self, addr: Tuple[str, int], mtype: int, payload: bytes,
              seqno: int = 0) -> None:
        if self.transport is None or self.transport.is_closing():
            return
        self.transport.sendto(HDR.pack(MAGIC, mtype, self.addr[1], seqno)
                              + payload, addr)

    def datagram_received(self, data: bytes, addr) -> None:
        if len(data) < HDR.size:
            return
        magic, mtype, sport, seqno = HDR.unpack_from(data)
        if magic != MAGIC:
            return    # SystemID check (Fig. 2): drop foreign systems
        src = (addr[0], sport)
        src_id = peer_id(*src)
        body = data[HDR.size:]
        if mtype == T_MAINT:
            ttl = body[0]
            self._learn(src_id, src)
            if ttl == 0:
                pred = self._pred()
                if pred is None or src_id == pred:
                    self.last_pred = time.monotonic()
                    self.probing = None
                elif self.probing is None and pred is not None:
                    self.probing = pred
                    self._send(self.addr_of[pred], T_PROBE, b"")
            for ev in decode_events(body[1:]):
                self._acknowledge(ev, ttl)
        elif mtype == T_PROBE:
            self._send(src, T_PROBE_R, b"")
        elif mtype == T_PROBE_R:
            if self.probing == src_id:
                self.probing = None
                self.last_pred = time.monotonic()
        elif mtype == T_JOIN_REQ:
            self._handle_join(src_id, src)
        elif mtype == T_TABLE:
            for ev in decode_events(body):
                self._learn(ev.subject_id, ev.addr)
        elif mtype == T_LEAVING:
            for ev in decode_events(body):
                self._acknowledge(ev, self.rho)
        elif mtype == T_FWD_JOIN:
            for ev in decode_events(body):
                self._handle_join(ev.subject_id, ev.addr)

    # -- EDRA ----------------------------------------------------------------
    def _pred(self) -> Optional[int]:
        if len(self.table) <= 1:
            return None
        return self.table.pred(self.id, 1)

    def _learn(self, pid: int, addr: Tuple[str, int]) -> None:
        if pid in self.dead:
            return
        self.addr_of[pid] = addr
        self.table.add(pid)

    def _make_event(self, pid: int, kind: str) -> Event:
        self.seq += 1
        return Event(subject_id=pid, kind=kind,
                     addr=self.addr_of.get(pid, ("0.0.0.0", 0)),
                     seq=int(time.monotonic() * 1000) * 64 + self.seq % 64)

    def _acknowledge(self, ev: Event, ttl: int) -> None:
        k = ev.dedup_key()
        if k in self.seen:
            return
        self.seen.add(k)
        if ev.kind == "join":
            self.dead.discard(ev.subject_id)
            self._learn(ev.subject_id, ev.addr)
        else:
            self.dead.add(ev.subject_id)
            self.table.remove(ev.subject_id)
            self.addr_of.pop(ev.subject_id, None)
        self.buffer.acknowledge(ev, ttl)

    def _handle_join(self, new_id: int, addr: Tuple[str, int]) -> None:
        # single-hop routing of the join (paper §VI): only the NEW PEER'S
        # SUCCESSOR admits it — anyone else forwards the request one hop.
        owner = self.table.successor_of(new_id)
        if owner != self.id and owner in self.addr_of:
            self._send(self.addr_of[owner], T_FWD_JOIN,
                       encode_events([Event(subject_id=new_id, kind="join",
                                            addr=addr, seq=0)]))
            return
        # §VI: ship our routing table (not maintenance traffic), then
        # announce the join through EDRA with TTL = rho (Rule 6)
        entries = [Event(subject_id=p, kind="join",
                         addr=self.addr_of[p], seq=0)
                   for p in self.table.ids if p in self.addr_of]
        self._send(addr, T_TABLE, encode_events(entries))
        self._learn(new_id, addr)
        self._acknowledge(self._make_event(new_id, "join"), self.rho)

    async def _interval_loop(self) -> None:
        k = 0
        while self.running:
            await asyncio.sleep(self.theta)
            self._flush()
            self._check_pred()
            k += 1
            if k % 10 == 0:
                self._anti_entropy()

    def _anti_entropy(self) -> None:
        """§IV-C: EDRA is exactly-once, so peers that were mid-join when an
        event finished disseminating can stay stale; the paper points to
        re-announcements/gossip as the standard remedy.  Every ~10
        intervals we ship our member view to the successor (learning-only;
        leaves keep authority via EDRA + tombstones)."""
        if len(self.table) <= 1:
            return
        succ = self.table.succ(self.id, 1)
        if succ in self.addr_of:
            entries = [Event(subject_id=p, kind="join",
                             addr=self.addr_of[p], seq=0)
                       for p in self.table.ids if p in self.addr_of]
            self._send(self.addr_of[succ], T_TABLE, encode_events(entries))

    def _flush(self) -> None:
        per_ttl = self.buffer.flush()
        for l in range(self.rho):
            if 2 ** l >= len(self.table):
                continue
            target = self.table.succ(self.id, 2 ** l)
            if target == self.id or target not in self.addr_of:
                continue
            events = [e for e in per_ttl.get(l, [])
                      if not in_interval(e.subject_id, self.id, target)]
            if l == 0 or events:
                self._send(self.addr_of[target], T_MAINT,
                           bytes([l]) + encode_events(events))

    def _check_pred(self) -> None:
        pred = self._pred()
        if pred is None:
            return
        if self.probing == pred:
            self.probing = None
            addr = self.addr_of.get(pred, ("0.0.0.0", 0))
            self.table.remove(pred)
            self.addr_of.pop(pred, None)
            ev = Event(subject_id=pred, kind="leave", addr=addr,
                       seq=self._make_event(pred, "leave").seq)
            self._acknowledge(ev, self.rho)
            self.last_pred = time.monotonic()
        elif time.monotonic() - self.last_pred > self.theta:
            self.probing = pred
            if pred in self.addr_of:
                self._send(self.addr_of[pred], T_PROBE, b"")
