"""Lookup-latency models for the §VII-D comparison (Figs. 5-6).

Four systems, as in the paper:
  * D1HT      — 1 hop for a (1-f') fraction, retry (timeout + 2nd hop) else
  * 1h-Calot  — same single-hop model, slightly different f'
  * Pastry    — log_b(n) hops (Chimera uses base 4)
  * Dserver   — a single directory server: one hop + M/D/1 queueing; the
                paper observed one Cluster-B node saturating at 1,600
                clients, which pins the service rate.

Latencies are per-lookup expectations; "busy" mode (nodes at 100% CPU,
Fig. 5b/6) inflates per-message processing time by a load factor that
grows with the number of peers co-located per physical node, which is
what the paper's 200- vs 400-node experiment isolated.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

HOP_MS_IDLE = 0.14          # measured one-hop latency, §VII-D
RETRY_PENALTY_MS = 2.0      # timeout + retry upon routing failure
# The latency runs used a Cluster-F node after the Cluster-B node saturated
# at 1,600 peers; its capacity is calibrated so the curve matches Fig. 5a:
# indistinguishable at <=1,600, ~120% over single-hop at 3,200, an order of
# magnitude at 4,000 (right at saturation).
DSERVER_SAT_CLIENTS = 3280
LOOKUPS_PER_SEC = 30.0      # §VII-D latency-experiment lookup rate


@dataclass
class LatencyPoint:
    n: int
    d1ht_ms: float
    calot_ms: float
    pastry_ms: float
    dserver_ms: float


def _busy_factor(busy: bool, peers_per_node: float) -> float:
    """100%-CPU co-scheduling penalty; calibrated to Fig. 6 (0.15 ms at 4
    peers/node -> 0.23-0.24 ms at 8 peers/node, independent of n)."""
    if not busy:
        return 1.0
    return 1.0 + 0.12 * peers_per_node


def single_hop_ms(*, busy: bool, peers_per_node: float,
                  failure_fraction: float = 0.01) -> float:
    base = HOP_MS_IDLE * _busy_factor(busy, peers_per_node)
    return (1.0 - failure_fraction) * base + failure_fraction * (
        base + RETRY_PENALTY_MS)


def pastry_ms(n: int, *, busy: bool, peers_per_node: float,
              base: int = 4) -> float:
    hops = max(1.0, math.log(max(n, 2)) / math.log(base))
    return hops * HOP_MS_IDLE * _busy_factor(busy, peers_per_node)


def dserver_ms(n: int, *, busy: bool, peers_per_node: float,
               lookup_rate: float = LOOKUPS_PER_SEC) -> float:
    """M/D/1 queue at the directory server.

    Service rate mu is pinned by the observed saturation point: a node
    saturates when n*lookup_rate == mu  =>  mu = 1600 peers * 30 lkp/s.
    """
    mu = DSERVER_SAT_CLIENTS * lookup_rate
    lam = n * lookup_rate
    rho_q = min(lam / mu, 0.999)
    service_ms = 1000.0 / mu
    wait_ms = service_ms * rho_q / (2.0 * (1.0 - rho_q))
    net_ms = HOP_MS_IDLE * _busy_factor(busy, peers_per_node)
    return net_ms + service_ms + wait_ms


def latency_sweep(n_values, *, busy: bool, nodes: int = 400) -> Dict[int, LatencyPoint]:
    out = {}
    for n in n_values:
        ppn = n / nodes
        out[n] = LatencyPoint(
            n=n,
            d1ht_ms=single_hop_ms(busy=busy, peers_per_node=ppn),
            calot_ms=single_hop_ms(busy=busy, peers_per_node=ppn,
                                   failure_fraction=0.012),
            pastry_ms=pastry_ms(n, busy=busy, peers_per_node=ppn),
            dserver_ms=dserver_ms(n, busy=busy, peers_per_node=ppn),
        )
    return out
