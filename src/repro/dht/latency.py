"""Lookup-latency models for the §VII-D comparison (Figs. 5-6).

Four systems, as in the paper:
  * D1HT      — 1 hop for a (1-f') fraction, retry (timeout + 2nd hop) else
  * 1h-Calot  — same single-hop model, slightly different f'
  * Pastry    — log_b(n) hops (Chimera uses base 4)
  * Dserver   — a single directory server: one hop + FCFS queueing at a
                single worker whose service rate is pinned by a measured
                saturation point.

Latencies are per-lookup expectations; "busy" mode (nodes at 100% CPU,
Fig. 5b/6) inflates per-message processing time by a load factor that
grows with the number of peers co-located per physical node, which is
what the paper's 200- vs 400-node experiment isolated.

This module is the CLOSED-FORM oracle.  The measured twin lives in
``repro.dht.latency_sim``: it times the real ``ring_lookup_bucketed``
kernel, saturates a real local directory worker to measure mu instead
of assuming ``DSERVER_SAT_CLIENTS``, and lets the stale-table retry
fraction f' emerge from the churn plane.  ``latency_sweep`` accepts the
measured parameters (``mu``, ``window_s``, per-protocol f') so the two
planes stay point-by-point comparable (BENCH_latency.json asserts the
measured/model ratio per sub-saturation point).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

HOP_MS_IDLE = 0.14          # measured one-hop latency, §VII-D
RETRY_PENALTY_MS = 2.0      # timeout + retry upon routing failure
# §VII-D saturation methodology: one Cluster-B node saturated at 1,600
# clients x 30 lookups/s.  The latency runs themselves used a faster
# Cluster-F node; ITS capacity — calibrated so the closed-form curve
# matches Fig. 5a (indistinguishable at <= 1,600, ~120% over single-hop
# at 3,200, an order of magnitude at 4,000) — is the 3,280-client
# default below.  ``latency_sim.measure_profile`` replaces this default
# with the saturation point of OUR directory worker, measured the same
# way the paper measured Cluster-B's.
DSERVER_SAT_CLIENTS = 3280
LOOKUPS_PER_SEC = 30.0      # §VII-D latency-experiment lookup rate
DSERVER_WINDOW_S = 10.0     # measurement window the queue is observed over


@dataclass
class LatencyPoint:
    n: int
    d1ht_ms: float
    calot_ms: float
    pastry_ms: float
    dserver_ms: float


def busy_factor(busy: bool, peers_per_node: float) -> float:
    """100%-CPU co-scheduling penalty; calibrated to Fig. 6 (0.15 ms at 4
    peers/node -> 0.23-0.24 ms at 8 peers/node, independent of n).
    Shared with the measured plane so the measured/model ratio validates
    queueing and service measurements, not the busy calibration."""
    if not busy:
        return 1.0
    return 1.0 + 0.12 * peers_per_node


def single_hop_ms(*, busy: bool, peers_per_node: float,
                  failure_fraction: float = 0.01) -> float:
    base = HOP_MS_IDLE * busy_factor(busy, peers_per_node)
    return (1.0 - failure_fraction) * base + failure_fraction * (
        base + RETRY_PENALTY_MS)


def pastry_ms(n: int, *, busy: bool, peers_per_node: float,
              base: int = 4) -> float:
    hops = max(1.0, math.log(max(n, 2)) / math.log(base))
    return hops * HOP_MS_IDLE * busy_factor(busy, peers_per_node)


def dserver_ms(n: int, *, busy: bool, peers_per_node: float,
               lookup_rate: float = LOOKUPS_PER_SEC,
               mu: Optional[float] = None,
               window_s: float = DSERVER_WINDOW_S) -> float:
    """Single directory server: one network hop + an FCFS queue at one
    worker of service rate ``mu`` (requests/s; default pins it to the
    calibrated ``DSERVER_SAT_CLIENTS`` saturation point, the measured
    plane passes its own measured rate).

    The old model clamped utilization at ``min(lam/mu, 0.999)``, which
    flattened every past-saturation point onto the same ~5 ms — Fig 5a's
    order-of-magnitude blow-up at n=4000 was unrepresentable and n=4000
    was indistinguishable from n=10^6.  The queue is now observed over a
    finite measurement window of ``window_s`` seconds with a CLOSED
    population of n clients, like the measured plane observes it
    (``latency_sim.closed_loop_fcfs`` is the calibration target):

      * below saturation: steady-state M/D/1 wait, with a slack floor —
        ``sqrt(1/(mu*window_s))`` (closer to saturation than that, the
        queue cannot relax within the window) and ``1/sqrt(n)`` (a
        closed population's critical fluctuations are sqrt(n)-limited);
      * past saturation: fluid backlog growth ``(rho-1)*window/2``,
        capped by the closed-loop fixed point — with the server
        permanently busy, Little's law pins the wait at exactly
        ``n*S - Z - S`` (the generator matches it to <1%) — with the
        ``sqrt(n)*S/2`` fluctuation floor carrying the knee itself.
    """
    mu = mu if mu is not None else DSERVER_SAT_CLIENTS * lookup_rate
    lam = n * lookup_rate
    rho = lam / mu
    service_s = 1.0 / mu
    think_s = 1.0 / lookup_rate
    slack = max(1.0 - rho,
                math.sqrt(1.0 / (mu * window_s)),   # window relaxation
                1.0 / math.sqrt(max(n, 1)))         # population limit
    w_open = service_s * rho / (2.0 * slack) \
        + max(rho - 1.0, 0.0) * window_s / 2.0
    w_closed = max(n * service_s - think_s - service_s,   # Little's law
                   service_s * math.sqrt(max(n, 1)) / 2.0,
                   0.0)
    wait_ms = 1000.0 * min(w_open, w_closed)
    net_ms = HOP_MS_IDLE * busy_factor(busy, peers_per_node)
    return net_ms + 1000.0 * service_s + wait_ms


def latency_sweep(n_values, *, busy: bool, nodes: int = 400,
                  mu: Optional[float] = None,
                  window_s: float = DSERVER_WINDOW_S,
                  lookup_rate: float = LOOKUPS_PER_SEC,
                  d1ht_f: float = 0.01,
                  calot_f: float = 0.012) -> Dict[int, LatencyPoint]:
    """Closed-form Figs 5-6 sweep.  The keyword knobs exist so the
    measured plane can evaluate the oracle AT its measured parameters
    (worker rate ``mu``, queue observation ``window_s``, churn-emergent
    per-protocol failure fractions)."""
    out = {}
    for n in n_values:
        ppn = n / nodes
        out[n] = LatencyPoint(
            n=n,
            d1ht_ms=single_hop_ms(busy=busy, peers_per_node=ppn,
                                  failure_fraction=d1ht_f),
            calot_ms=single_hop_ms(busy=busy, peers_per_node=ppn,
                                   failure_fraction=calot_f),
            pastry_ms=pastry_ms(n, busy=busy, peers_per_node=ppn),
            dserver_ms=dserver_ms(n, busy=busy, peers_per_node=ppn,
                                  lookup_rate=lookup_rate, mu=mu,
                                  window_s=window_s),
        )
    return out
