"""Churn experiment harness reproducing the paper's §VII methodology.

Two-phase runs: a growth/warmup phase (unmetered) followed by a metered
measurement window (the paper uses 30 min).  Churn is driven by per-peer
session lengths (Eq III.1 emerges from S_avg); half of the leaves are
crashes (SIGKILL — no warning, buffered events lost) and leaving peers
rejoin after 3 minutes with the same ID, exactly as in §VII-A.

Lookup correctness is sampled against the ground-truth ring: a lookup is
solved with one hop iff the origin's routing table maps the key to the
true current owner (stale entries => routing failure => extra hops).
"""
from __future__ import annotations

import random

from repro.core.analysis import calot_bandwidth, d1ht_bandwidth
# Shared run shapes (DESIGN.md §8): this DES and the vectorized plane in
# repro.core.jax_sim consume the SAME config and produce the SAME result
# type, so the twin tests compare them field by field.
from repro.core.churn import ChurnConfig, ChurnResult, SessionDist
from repro.core.ring import RoutingTable, build_ring
from repro.core.tuning import EdraParams
from .calot_node import CalotPeer
from .d1ht_node import D1HTPeer
from .des import LanDelay, SimNet
from .messages import V_A_BITS

__all__ = ["ChurnConfig", "ChurnResult", "SessionDist", "run_churn"]


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_churn(cfg: ChurnConfig) -> ChurnResult:
    rng = random.Random(cfg.seed + 7)
    net = SimNet(cfg.delay or LanDelay(), seed=cfg.seed)
    params = EdraParams.derive(cfg.n, cfg.s_avg, cfg.f)
    sessions = SessionDist(cfg.s_avg, cfg.volatile_fraction,
                           cfg.quarantine_tq or 600.0)

    ring = build_ring(cfg.n, seed=cfg.seed)
    ids = list(ring.ids)
    make = (lambda pid: D1HTPeer(pid, net, params)) if cfg.protocol == "d1ht" \
        else (lambda pid: CalotPeer(pid, net, params))
    for pid in ids:
        net.add_peer(make(pid))
    net.ring = RoutingTable(ids)

    # start everyone with the full table and randomized interval phases
    for pid in ids:
        peer = net.peers[pid]
        peer.table = RoutingTable(ids)
        phase = rng.random() * max(params.theta, 1.0)
        net.schedule(phase, lambda p=peer: p.start())

    stats = {"events": 0, "lookups": 0, "one_hop": 0,
             "q_admit": 0, "q_skip": 0}

    # -- churn driver ---------------------------------------------------------
    def schedule_leave(pid: int, session: float) -> None:
        net.schedule(session, lambda: do_leave(pid))

    def do_leave(pid: int) -> None:
        peer = net.peers[pid]
        if not peer.alive:
            return
        crash = rng.random() < cfg.crash_fraction
        peer.stop(crash=crash)
        if pid in net.ring:
            net.ring.remove(pid)
            if net.metering:
                stats["events"] += 1
        net.schedule(cfg.rejoin_delay, lambda: do_join(pid))

    def do_join(pid: int) -> None:
        session = sessions.sample(rng)
        if cfg.quarantine_tq is not None:
            if session <= cfg.quarantine_tq:
                # volatile peer: never admitted, no events, rejoin later (§V)
                stats["q_skip"] += 1
                net.schedule(session + cfg.rejoin_delay, lambda: do_join(pid))
                return
            stats["q_admit"] += 1
            net.schedule(cfg.quarantine_tq, lambda: admit(pid, session))
            return
        admit(pid, session)

    def admit(pid: int, session: float) -> None:
        try:
            succ_id = net.ring.successor_of(pid)
        except LookupError:
            return
        net.send(pid, succ_id, V_A_BITS, "join-request", None)
        net.ring.add(pid)
        if net.metering:
            stats["events"] += 1
        remaining = session - (cfg.quarantine_tq or 0.0)
        schedule_leave(pid, max(remaining, 1.0))

    for pid in ids:
        schedule_leave(pid, max(1.0, sessions.sample(rng)))

    # -- lookup sampling ---------------------------------------------------------
    lookup_dt = cfg.duration / cfg.lookup_samples

    def do_lookup() -> None:
        alive = [p for p in net.ring if net.is_alive(p)]
        if len(alive) >= 2:
            origin = net.peers[rng.choice(alive)]
            kid = rng.getrandbits(60)
            try:
                local = origin.table.successor_of(kid)
                true = net.ring.successor_of(kid)
                stats["lookups"] += 1
                if local == true and net.is_alive(true):
                    stats["one_hop"] += 1
            except LookupError:
                pass
        net.schedule(lookup_dt, do_lookup)

    # -- run -----------------------------------------------------------------------
    net.run_until(cfg.warmup)
    net.reset_meters()
    net.metering = True
    net.schedule(lookup_dt, do_lookup)
    net.run_until(cfg.warmup + cfg.duration)
    net.metering = False

    total_bits = net.total_maint_out_bits()
    sum_bps = total_bits / cfg.duration
    mean_bps = sum_bps / cfg.n
    analytical = (d1ht_bandwidth(cfg.n, cfg.s_avg, cfg.f)
                  if cfg.protocol == "d1ht"
                  else calot_bandwidth(cfg.n, cfg.s_avg))
    return ChurnResult(
        cfg=cfg, params=params, events=stats["events"],
        one_hop_fraction=stats["one_hop"] / max(stats["lookups"], 1),
        sum_out_bps=sum_bps, mean_out_bps=mean_bps,
        analytical_bps=analytical,
        quarantine_admitted=stats["q_admit"],
        quarantine_skipped=stats["q_skip"],
    )
