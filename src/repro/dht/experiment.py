"""Churn experiment harness reproducing the paper's §VII methodology.

Two-phase runs: a growth/warmup phase (unmetered) followed by a metered
measurement window (the paper uses 30 min).  Churn is driven by per-peer
session lengths (Eq III.1 emerges from S_avg); half of the leaves are
crashes (SIGKILL — no warning, buffered events lost) and leaving peers
rejoin after 3 minutes with the same ID, exactly as in §VII-A.

Lookup correctness is sampled against the ground-truth ring: a lookup is
solved with one hop iff the origin's routing table maps the key to the
true current owner (stale entries => routing failure => extra hops).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.analysis import calot_bandwidth, d1ht_bandwidth
from repro.core.ring import RoutingTable, build_ring
from repro.core.tuning import EdraParams
from .calot_node import CalotPeer
from .d1ht_node import D1HTPeer
from .des import DelayModel, LanDelay, SimNet
from .messages import V_A_BITS


# ---------------------------------------------------------------------------
# Session-length distributions (§V: P2P sessions are heavy-tailed)
# ---------------------------------------------------------------------------

class SessionDist:
    """Exponential by default; ``volatile_fraction`` mixes in short
    (< t_q) sessions to model the heavy tail head (24% KAD / 31% Gnutella
    sessions under 10 min)."""

    def __init__(self, s_avg: float, volatile_fraction: float = 0.0,
                 t_q: float = 600.0):
        self.s_avg = s_avg
        self.vol = volatile_fraction
        self.t_q = t_q
        if volatile_fraction > 0.0:
            short_mean = t_q / 2.0
            self.long_mean = (s_avg - volatile_fraction * short_mean) / (
                1.0 - volatile_fraction)
        else:
            self.long_mean = s_avg

    def sample(self, rng: random.Random) -> float:
        if self.vol > 0.0 and rng.random() < self.vol:
            return rng.uniform(0.0, self.t_q)
        return rng.expovariate(1.0 / self.long_mean)


# ---------------------------------------------------------------------------
# Experiment config / result
# ---------------------------------------------------------------------------

@dataclass
class ChurnConfig:
    n: int
    s_avg: float                  # seconds
    protocol: str = "d1ht"        # "d1ht" | "calot"
    duration: float = 1800.0      # metered window (paper: 30 min)
    warmup: float = 300.0
    delay: Optional[DelayModel] = None
    seed: int = 0
    rejoin_delay: float = 180.0   # paper: rejoin in 3 minutes, same ID
    crash_fraction: float = 0.5   # paper: half the leaves are SIGKILL
    lookup_samples: int = 4000
    quarantine_tq: Optional[float] = None
    volatile_fraction: float = 0.0
    f: float = 0.01


@dataclass
class ChurnResult:
    cfg: ChurnConfig
    params: EdraParams
    events: int
    one_hop_fraction: float
    sum_out_bps: float            # Σ over peers (Figs 3-4 plot the sum)
    mean_out_bps: float
    analytical_bps: float         # per-peer model prediction
    quarantine_admitted: int = 0
    quarantine_skipped: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "n": self.cfg.n,
            "protocol": self.cfg.protocol,
            "events": self.events,
            "one_hop_fraction": round(self.one_hop_fraction, 5),
            "mean_out_bps": round(self.mean_out_bps, 1),
            "sum_out_kbps": round(self.sum_out_bps / 1000.0, 1),
            "analytical_bps": round(self.analytical_bps, 1),
            "ratio_sim_over_model": round(
                self.mean_out_bps / max(self.analytical_bps, 1e-9), 3),
        }


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_churn(cfg: ChurnConfig) -> ChurnResult:
    rng = random.Random(cfg.seed + 7)
    net = SimNet(cfg.delay or LanDelay(), seed=cfg.seed)
    params = EdraParams.derive(cfg.n, cfg.s_avg, cfg.f)
    sessions = SessionDist(cfg.s_avg, cfg.volatile_fraction,
                           cfg.quarantine_tq or 600.0)

    ring = build_ring(cfg.n, seed=cfg.seed)
    ids = list(ring.ids)
    make = (lambda pid: D1HTPeer(pid, net, params)) if cfg.protocol == "d1ht" \
        else (lambda pid: CalotPeer(pid, net, params))
    for pid in ids:
        net.add_peer(make(pid))
    net.ring = RoutingTable(ids)

    # start everyone with the full table and randomized interval phases
    for pid in ids:
        peer = net.peers[pid]
        peer.table = RoutingTable(ids)
        phase = rng.random() * max(params.theta, 1.0)
        net.schedule(phase, lambda p=peer: p.start())

    stats = {"events": 0, "lookups": 0, "one_hop": 0,
             "q_admit": 0, "q_skip": 0}

    # -- churn driver ---------------------------------------------------------
    def schedule_leave(pid: int, session: float) -> None:
        net.schedule(session, lambda: do_leave(pid))

    def do_leave(pid: int) -> None:
        peer = net.peers[pid]
        if not peer.alive:
            return
        crash = rng.random() < cfg.crash_fraction
        peer.stop(crash=crash)
        if pid in net.ring:
            net.ring.remove(pid)
            if net.metering:
                stats["events"] += 1
        net.schedule(cfg.rejoin_delay, lambda: do_join(pid))

    def do_join(pid: int) -> None:
        session = sessions.sample(rng)
        if cfg.quarantine_tq is not None:
            if session <= cfg.quarantine_tq:
                # volatile peer: never admitted, no events, rejoin later (§V)
                stats["q_skip"] += 1
                net.schedule(session + cfg.rejoin_delay, lambda: do_join(pid))
                return
            stats["q_admit"] += 1
            net.schedule(cfg.quarantine_tq, lambda: admit(pid, session))
            return
        admit(pid, session)

    def admit(pid: int, session: float) -> None:
        try:
            succ_id = net.ring.successor_of(pid)
        except LookupError:
            return
        net.send(pid, succ_id, V_A_BITS, "join-request", None)
        net.ring.add(pid)
        if net.metering:
            stats["events"] += 1
        remaining = session - (cfg.quarantine_tq or 0.0)
        schedule_leave(pid, max(remaining, 1.0))

    for pid in ids:
        schedule_leave(pid, max(1.0, sessions.sample(rng)))

    # -- lookup sampling ---------------------------------------------------------
    lookup_dt = cfg.duration / cfg.lookup_samples

    def do_lookup() -> None:
        alive = [p for p in net.ring if net.is_alive(p)]
        if len(alive) >= 2:
            origin = net.peers[rng.choice(alive)]
            kid = rng.getrandbits(60)
            try:
                local = origin.table.successor_of(kid)
                true = net.ring.successor_of(kid)
                stats["lookups"] += 1
                if local == true and net.is_alive(true):
                    stats["one_hop"] += 1
            except LookupError:
                pass
        net.schedule(lookup_dt, do_lookup)

    # -- run -----------------------------------------------------------------------
    net.run_until(cfg.warmup)
    net.reset_meters()
    net.metering = True
    net.schedule(lookup_dt, do_lookup)
    net.run_until(cfg.warmup + cfg.duration)
    net.metering = False

    total_bits = net.total_maint_out_bits()
    sum_bps = total_bits / cfg.duration
    mean_bps = sum_bps / cfg.n
    analytical = (d1ht_bandwidth(cfg.n, cfg.s_avg, cfg.f)
                  if cfg.protocol == "d1ht"
                  else calot_bandwidth(cfg.n, cfg.s_avg))
    return ChurnResult(
        cfg=cfg, params=params, events=stats["events"],
        one_hop_fraction=stats["one_hop"] / max(stats["lookups"], 1),
        sum_out_bps=sum_bps, mean_out_bps=mean_bps,
        analytical_bps=analytical,
        quarantine_admitted=stats["q_admit"],
        quarantine_skipped=stats["q_skip"],
    )
