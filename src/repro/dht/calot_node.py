"""1h-Calot peer for the discrete-event simulator (paper §II, §VII-A).

1h-Calot [52] differs from D1HT in exactly the three ways the paper lists:
  1. event-propagation trees based on peer-ID intervals (we build the same
     binomial split over the live table — cost-equivalent),
  2. explicit heartbeats (4/min to the successor, unacknowledged) for
     failure detection, instead of piggybacking on maintenance traffic,
  3. NO event aggregation: every maintenance message carries exactly one
     event (fixed 48-byte message, Fig. 2b) and is sent immediately —
     peers cannot buffer without sacrificing the one-hop guarantee.

Per-peer bandwidth therefore follows Eq VII.1:
    B = r*(v_c + v_a) + 4*v_h/60.
"""
from __future__ import annotations

import bisect
from typing import Optional

from repro.core.edra import Event
from repro.core.ring import RoutingTable
from repro.core.tuning import EdraParams
from .des import SimNet, SimPeer
from .messages import V_A_BITS, V_H_BITS, calot_maintenance_size

HEARTBEAT_PERIOD = 15.0           # four per minute (§VII-A)


class CalotPeer(SimPeer):
    def __init__(self, pid: int, net: SimNet, params: EdraParams):
        super().__init__(pid, net)
        self.params = params
        self.table = RoutingTable([])
        self.seen: dict = {}
        self.last_pred_beat = 0.0
        self.probing: Optional[int] = None
        self._epoch = 0

    # -- lifecycle ---------------------------------------------------------
    def start(self, table_from: Optional["CalotPeer"] = None) -> None:
        self.alive = True
        self._epoch += 1
        if table_from is not None:
            self.table = RoutingTable(list(table_from.table.ids))
        self.table.add(self.id)
        self.last_pred_beat = self.net.now
        self._schedule_heartbeat()

    def stop(self, *, crash: bool) -> None:
        if not crash and self.alive:
            succ = self._succ_peer()
            if succ is not None:
                ev = self._make_event(self.id, "leave")
                self.net.send(self.id, succ, V_A_BITS, "leaving", ev)
        self.alive = False
        self._epoch += 1

    def _make_event(self, subject: int, kind: str) -> Event:
        self.net.event_seq += 1
        return Event(subject_id=subject, kind=kind, seq=self.net.event_seq)

    def _succ_peer(self, i: int = 1) -> Optional[int]:
        if len(self.table) <= 1:
            return None
        return self.table.succ(self.id, i)

    # -- heartbeats (failure detection) --------------------------------------
    def _schedule_heartbeat(self) -> None:
        epoch = self._epoch

        def fire() -> None:
            if not self.alive or self._epoch != epoch:
                return
            succ = self._succ_peer()
            if succ is not None:
                self.net.send(self.id, succ, V_H_BITS, "heartbeat", None,
                              acked=False)
            self._check_predecessor()
            self._schedule_heartbeat()

        self.net.schedule(HEARTBEAT_PERIOD, fire)

    def _check_predecessor(self) -> None:
        if len(self.table) <= 1:
            return
        pred = self.table.pred(self.id, 1)
        if (self.probing is None
                and self.net.now - self.last_pred_beat > 1.5 * HEARTBEAT_PERIOD):
            self.probing = pred
            self.net.send(self.id, pred, V_A_BITS, "probe", None, acked=False)
            self.net.schedule(5.0, lambda: self._probe_timeout(pred))

    def _probe_timeout(self, pred: int) -> None:
        if not self.alive or self.probing != pred or pred not in self.table:
            return
        # probe unanswered => confirmed dead
        self.probing = None
        self.table.remove(pred)
        ev = self._make_event(pred, "leave")
        self._propagate(ev, full_range=True)
        self._apply(ev)
        self.last_pred_beat = self.net.now

    # -- event dissemination: ID-interval tree, one event per message ----------
    def _count_in(self, hi_id: int) -> int:
        """Number of table entries clockwise in (self.id, hi_id]."""
        if len(self.table) <= 1:
            return 0
        try:
            last = self.table.predecessor_of((hi_id + 1) % (1 << 64))
        except LookupError:
            return 0
        if last == self.id:
            return 0
        ids = self.table.ids
        pos_me = bisect.bisect_left(ids, self.id)
        pos_last = bisect.bisect_left(ids, last)
        return (pos_last - pos_me) % len(ids)

    def _propagate(self, ev: Event, *, full_range: bool = False,
                   hi_id: Optional[int] = None) -> None:
        """Forward ``ev`` over 1h-Calot's peer-ID-interval tree (§II).

        The sender is responsible for informing every peer in the clockwise
        ID interval (self, hi_id].  It hands the far half (mid, hi_id] to
        the peer at the midpoint and keeps halving its own share.  Each
        receiver re-derives coverage from *its own* table, so the tree is
        robust to transient routing-table divergence.  One event per
        message, no aggregation (the paper's key contrast with EDRA).
        """
        if full_range:
            if len(self.table) <= 1:
                return
            hi_id = self.table.pred(self.id, 1)
        while True:
            k = self._count_in(hi_id)
            if k <= 0:
                return
            half = (k + 1) // 2
            mid = self.table.succ(self.id, half)
            if mid == self.id:
                return
            if not self.net.is_alive(mid):
                # ack timeout: one wasted transmission, learn, re-route so
                # the subtree is not silently lost (messages acked, Eq VII.1)
                self.net.send(self.id, mid, calot_maintenance_size(),
                              "event", (ev, mid))
                self.table.remove(mid)
                continue
            self.net.send(self.id, mid, calot_maintenance_size(),
                          "event", (ev, hi_id))
            if half == 1:
                return                       # near half is empty
            hi_id = self.table.pred(mid, 1)  # keep (self, pred(mid)]

    def _apply(self, ev: Event) -> None:
        k = ev.dedup_key()
        if k in self.seen:
            return
        self.seen[k] = self.net.now
        if ev.kind == "join":
            self.table.add(ev.subject_id)
        else:
            self.table.remove(ev.subject_id)

    # -- datagrams -------------------------------------------------------------
    def on_datagram(self, src: int, kind: str, payload) -> None:
        if kind == "heartbeat":
            try:
                if len(self.table) > 1:
                    pred = self.table.pred(self.id, 1)
                    if src == pred:
                        self.last_pred_beat = self.net.now
                        self.probing = None
                    elif self.probing is None:
                        # heartbeat from a non-predecessor: the ring changed
                        # nearby — verify pred(1) instead of trusting it
                        self.probing = pred
                        self.net.send(self.id, pred, V_A_BITS, "probe", None,
                                      acked=False)
                        self.net.schedule(5.0,
                                          lambda: self._probe_timeout(pred))
            except LookupError:
                pass
        elif kind == "probe":
            self.net.send(self.id, src, V_A_BITS, "probe-reply", None,
                          acked=False)
        elif kind == "probe-reply":
            if self.probing == src:
                self.probing = None
                self.last_pred_beat = self.net.now
        elif kind == "event":
            ev, hi_id = payload
            first_time = ev.dedup_key() not in self.seen
            self._apply(ev)
            if first_time and hi_id != self.id:
                self._propagate(ev, hi_id=hi_id)
        elif kind == "leaving":
            ev = payload
            if ev.dedup_key() not in self.seen:
                self._propagate(ev, full_range=True)
                self._apply(ev)
        elif kind == "join-request":
            newcomer = self.net.peers.get(src)
            if newcomer is not None and isinstance(newcomer, CalotPeer):
                newcomer.start(table_from=self)
                self.table.add(src)
                ev = self._make_event(src, "join")
                self._propagate(ev, full_range=True)
                self._apply(ev)
