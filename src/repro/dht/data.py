"""Replicated KV-block data plane over the ring (DESIGN.md §11).

Six PRs in, the repo resolved owners but stored nothing — a hash ring,
not a hash *table*.  ``BlockStore`` closes that gap: a versioned,
checksummed block store whose placement IS ``RingState.replica_set`` —
every block lives on the r active successors of its key (Leslie,
*Reliable Data Storage in Distributed Hash Tables*; ``put/get/remove``
interface shape after the DFTHT exemplar).

Design points:

  * **r-way successor replication.**  ``put`` writes the block to every
    member of the key's current replica set and meters the upload bytes
    (value bytes x replicas), the same accounting discipline as the
    routing plane's delta tables (§7).
  * **Versioned metadata.**  Every stored copy carries a ``BlockMeta``
    (monotonic version, size, CRC32).  The version is coordinator-
    assigned per key (read-before-write), so replicas are totally
    ordered and a reader can always tell fresh from stale.
  * **Read-repair.**  ``get`` consults every reachable copy, returns the
    highest-version checksum-valid one, and overwrites stale or missing
    copies on the key's CURRENT replica set in passing — placement drift
    (a joiner that slid into the middle of a replica set) heals on the
    read path without any sweep.
  * **Churn-driven re-replication.**  ``sync`` asks ``owner_diff`` which
    key arcs moved and unions that with the keys whose recorded holders
    died — only THOSE keys are re-placed, so a leave/crash triggers
    O(affected blocks) copy traffic, metered through ``repair_bytes``.
  * **Tombstones.**  ``remove`` records the deleted version so a stale
    copy surfacing later (a 3-min same-ID rejoin with its disk intact)
    can never resurrect a deleted block through repair.

The store models node-local storage as one dict per peer id (the
dict-of-dicts the invariant tests twin-check against): a *leave* keeps
the dict (the peer is gone but its disk may come back with a rejoin), a
*crash* (``drop_node``) destroys it.  Reachability follows the ring
state: a peer is readable while it is tracked (active or §V-quarantined)
and its physical store still exists.

``PrefixCache`` rides on top: content-addressed prompt-prefix chunks
(key = hash of the token prefix itself), so any session sharing a system
prompt imports the prefix KV instead of re-prefilling it — admission
FLOPs for the shared part drop to a block fetch.
"""
from __future__ import annotations

import hashlib
import struct
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.ring import key_id
from repro.core.ringstate import RingState

__all__ = ["BlockMeta", "BlockStore", "PrefixCache",
           "pack_array", "unpack_array"]


# ---------------------------------------------------------------------------
# array <-> bytes framing (KV blocks travel as plain bytes through the DHT)
# ---------------------------------------------------------------------------

_MAGIC = b"KVB1"


def pack_array(arr: np.ndarray) -> bytes:
    """Self-describing little header + raw bytes: the store itself only
    ever sees opaque ``bytes`` (like any DHT), so shape/dtype must ride
    inside the value."""
    arr = np.ascontiguousarray(arr)
    dt = arr.dtype.str.encode()
    head = _MAGIC + struct.pack("<BB", len(dt), arr.ndim) + dt \
        + struct.pack(f"<{arr.ndim}q", *arr.shape)
    return head + arr.tobytes()


def unpack_array(data: bytes) -> np.ndarray:
    if data[:4] != _MAGIC:
        raise ValueError("not a packed array block")
    dtl, ndim = struct.unpack_from("<BB", data, 4)
    off = 6
    dt = np.dtype(data[off:off + dtl].decode())
    off += dtl
    shape = struct.unpack_from(f"<{ndim}q", data, off)
    off += 8 * ndim
    return np.frombuffer(data, dt, offset=off).reshape(shape).copy()


# ---------------------------------------------------------------------------
# block store
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BlockMeta:
    """Per-copy metadata: total order (version) + integrity (crc)."""

    version: int
    size: int
    crc: int

    @staticmethod
    def of(version: int, value: bytes) -> "BlockMeta":
        return BlockMeta(version, len(value), zlib.crc32(value))

    def valid(self, value: bytes) -> bool:
        return len(value) == self.size and zlib.crc32(value) == self.crc


class BlockStore:
    """r-way replicated block store placed by the ring's successor lists.

    ``policy`` is an optional ``repro.runtime.placement.PlacementPolicy``
    (duck-typed so the pure-Python DES users never import the runtime
    package): it RANKS each key's replica set — which copy a read
    prefers, which member a co-located consumer treats as primary — but
    never changes the SET (the successor list stays the canonical,
    policy-independent location of the copies, so ``sync``'s vectorized
    re-replication resolves placement through ``replica_sets`` under any
    policy).  ``None`` is exactly ring-successor order.

    ``put(..., at=key)`` overrides the PLACEMENT key: the block is
    stored under its own name but placed on ``at``'s replica set.  The
    serve plane places every session KV block ``at`` the session's ring
    key, so a session's blocks and the session itself land on the SAME
    replica set — the migration target already holds the handoff blocks
    locally instead of fetching them from wherever the block-name hash
    happened to scatter them (and churn can no longer re-home the
    session and its blocks to different replicas).
    """

    def __init__(self, state: RingState, *, replication: int = 2,
                 policy=None):
        if replication < 1:
            raise ValueError("replication must be >= 1")
        self.state = state
        self.replication = replication
        self.policy = policy
        # physical per-node stores: node id -> {key id -> (meta, value)}.
        # THIS is the ground truth the invariant suite twin-checks; the
        # indexes below are derived bookkeeping a real deployment would
        # hold per-node anyway (what do I store? what version did the
        # coordinator last hand out?).
        self._nodes: Dict[int, Dict[int, Tuple[BlockMeta, bytes]]] = {}
        self._placement: Dict[int, Tuple[int, ...]] = {}   # key -> holders
        self._pkey: Dict[int, int] = {}    # key -> placement-key override
        self._names: Dict[int, str] = {}                   # key -> debug name
        self._vclock: Dict[int, int] = {}    # coordinator version counter
        self._tombs: Dict[int, int] = {}     # key -> version buried at
        # churn cursor for owner_diff-driven repair
        state.track_owner_diffs()
        self._seen_version = state.active_version
        # metering (same observability discipline as RingState's
        # upload_bytes/delta_uploads)
        self.puts = 0
        self.gets = 0
        self.removes = 0
        self.read_repairs = 0
        self.repair_syncs = 0
        self.upload_bytes = 0        # put-path replica writes
        self.repair_bytes = 0        # read-repair + re-replication copies
        self.corrupt_copies = 0      # torn copies detected and discarded
        self.lost_blocks = 0         # keys with zero surviving copies

    # -- key space -----------------------------------------------------------
    @staticmethod
    def key_of(name) -> int:
        """Ring key of a block: ints pass through, strings hash (SHA-1
        truncation, the same keyspace peers live in)."""
        return int(name) if isinstance(name, (int, np.integer)) \
            else key_id(name)

    # -- reachability --------------------------------------------------------
    def _reachable(self, node: int) -> bool:
        """Readable/writable: tracked by the ring (active or quarantined
        — a §V-masked peer owns nothing but still answers) AND its
        physical store was not destroyed by a crash."""
        return (node in self.state or self.state.is_quarantined(node))

    def _copy(self, node: int, key: int) -> Optional[Tuple[BlockMeta, bytes]]:
        """The node's checksum-verified copy, or None (missing, torn, or
        buried under the key's tombstone)."""
        entry = self._nodes.get(node, {}).get(key)
        if entry is None:
            return None
        meta, value = entry
        if not meta.valid(value):
            self.corrupt_copies += 1
            del self._nodes[node][key]
            return None
        if meta.version <= self._tombs.get(key, 0):
            return None
        return entry

    def _pkey_of(self, key: int) -> int:
        return self._pkey.get(key, key)

    def _group(self, key: int) -> List[int]:
        pk = self._pkey_of(key)
        if self.policy is None:
            return [int(p) for p in self.state.replica_set(
                pk, self.replication)]
        return self.policy.replica_group(self.state, pk, self.replication)

    # -- core interface ------------------------------------------------------
    def put(self, name, value: bytes, *, at=None) -> BlockMeta:
        """Store ``value`` on every member of the key's replica set.
        The new version supersedes every copy (and any tombstone).
        ``at`` (a key id or name) overrides the placement key — the
        block keeps its own identity but lives on ``at``'s replica set
        (session-KV co-location; see the class docstring)."""
        if not isinstance(value, bytes):
            raise TypeError("BlockStore values are bytes")
        key = self.key_of(name)
        if at is not None:
            self._pkey[key] = self.key_of(at)
        else:
            self._pkey.pop(key, None)
        group = self._group(key)
        version = max(self._vclock.get(key, 0), self._tombs.get(key, 0)) + 1
        meta = BlockMeta.of(version, value)
        for node in group:
            self._nodes.setdefault(node, {})[key] = (meta, value)
        # drop copies parked on reachable ex-holders (placement moved)
        for node in self._placement.get(key, ()):
            if node not in group and self._reachable(node):
                self._nodes.get(node, {}).pop(key, None)
        self._vclock[key] = version
        self._tombs.pop(key, None)
        self._placement[key] = tuple(group)
        if isinstance(name, str):
            self._names[key] = name
        self.puts += 1
        self.upload_bytes += len(value) * len(group)
        return meta

    def get(self, name) -> Optional[bytes]:
        """Read the freshest checksum-valid copy; ``None`` on a miss.

        Consults the key's CURRENT replica set first, falling back to
        the last recorded holders (placement drift), then read-repairs:
        every live replica-set member ends up holding the winning
        version before the value is returned."""
        key = self.key_of(name)
        self.gets += 1
        group = self._group(key)
        seen = list(group)
        seen += [n for n in self._placement.get(key, ()) if n not in group]
        best: Optional[Tuple[BlockMeta, bytes]] = None
        for node in seen:
            if not self._reachable(node):
                continue
            entry = self._copy(node, key)
            if entry is not None and (best is None
                                      or entry[0].version > best[0].version):
                best = entry
        if best is None:
            return None
        meta, value = best
        repaired = False
        for node in group:
            cur = self._copy(node, key)
            if cur is None or cur[0].version < meta.version:
                self._nodes.setdefault(node, {})[key] = (meta, value)
                self.repair_bytes += meta.size
                repaired = True
        if repaired:
            self.read_repairs += 1
            self._placement[key] = tuple(group)
        self._vclock[key] = max(self._vclock.get(key, 0), meta.version)
        return value

    def get_array(self, name) -> Optional[np.ndarray]:
        data = self.get(name)
        return None if data is None else unpack_array(data)

    def put_array(self, name, arr: np.ndarray) -> BlockMeta:
        return self.put(name, pack_array(arr))

    def contains(self, name) -> bool:
        """Placement-index probe (no repair, no version race): does the
        store believe it holds a live copy of this key?"""
        key = self.key_of(name)
        if key in self._tombs or key not in self._placement:
            return False
        return any(self._reachable(n) and self._copy(n, key) is not None
                   for n in self._placement[key])

    def remove(self, name) -> bool:
        """Delete from every reachable holder and bury the version: a
        stale copy rejoining later can never resurrect the block."""
        key = self.key_of(name)
        version = self._vclock.get(key, 0)
        found = False
        for node in set(self._placement.pop(key, ())) | set(self._group(key)):
            if self._reachable(node) and \
                    self._nodes.get(node, {}).pop(key, None) is not None:
                found = True
        if version:
            self._tombs[key] = version
        self._pkey.pop(key, None)
        self._names.pop(key, None)
        self.removes += 1
        return found

    # -- churn ---------------------------------------------------------------
    def drop_node(self, node: int) -> None:
        """Crash semantics: the node's physical store is destroyed (a
        graceful leave keeps it — the disk may rejoin within T_detach)."""
        self._nodes.pop(node, None)

    def sync(self) -> Dict[str, int]:
        """Churn-driven re-replication: restore r live copies for exactly
        the keys the membership batches since the last sync affected.

        Affected = keys inside the ``owner_diff`` arcs (a joiner/leaver
        moved their primary) UNION keys with a dead or unreachable
        recorded holder (the leaver was a non-primary replica).  Copy
        traffic — and the per-key placement recompute — is O(affected
        blocks), never O(blocks): the arc test is one vectorized pass
        and the holder test is a set probe per key."""
        target = self.state.active_version
        stats = {"checked": 0, "repaired": 0, "copied_bytes": 0, "lost": 0}
        if not self._placement:
            self._seen_version = target
            return stats
        diff = self.state.owner_diff(self._seen_version, target)
        keys = np.fromiter(self._placement, np.uint64, len(self._placement))
        # arc membership is tested on the PLACEMENT keys: a co-located
        # block moves exactly when its anchor's replica set moved
        pkeys = np.fromiter((self._pkey_of(int(k)) for k in keys),
                            np.uint64, keys.size) if self._pkey else keys
        arc_hit = diff.affected(pkeys)
        live = set(int(x) for x in self.state.active_ids())
        affected: List[int] = []
        for k, hit in zip(keys.tolist(), arc_hit):
            holders = self._placement[k]
            if hit or any(h not in live or
                          k not in self._nodes.get(h, ())
                          for h in holders):
                affected.append(k)
        stats["checked"] = len(affected)
        if affected:
            # replica_sets is policy-independent by the set-preserving
            # invariant: a policy ranks within the successor set, so the
            # repair target SET is the same under any policy
            groups = self.state.replica_sets(
                np.asarray([self._pkey_of(k) for k in affected], np.uint64),
                self.replication)
            for k, group_row in zip(affected, groups):
                group = [int(g) for g in group_row]
                self._replace(k, group, stats)
        self._seen_version = target
        self.repair_syncs += 1
        self.lost_blocks += stats["lost"]
        self.repair_bytes += stats["copied_bytes"]
        return stats

    def _replace(self, key: int, group: List[int],
                 stats: Dict[str, int]) -> None:
        """Re-place one key onto ``group``: freshest surviving copy wins,
        missing/stale members are rewritten, reachable ex-holders are
        trimmed back to exactly the replica set."""
        candidates = set(group) | set(self._placement.get(key, ()))
        best: Optional[Tuple[BlockMeta, bytes]] = None
        for node in candidates:
            if not self._reachable(node):
                continue
            entry = self._copy(node, key)
            if entry is not None and (best is None
                                      or entry[0].version > best[0].version):
                best = entry
        if best is None:
            # every copy died between syncs (more simultaneous failures
            # than replicas) — surface it, never serve a resurrected
            # tombstone or hang the placement index on a ghost
            del self._placement[key]
            self._pkey.pop(key, None)
            self._names.pop(key, None)
            stats["lost"] += 1
            return
        meta, value = best
        repaired = False
        for node in group:
            cur = self._copy(node, key)
            if cur is None or cur[0].version < meta.version:
                self._nodes.setdefault(node, {})[key] = (meta, value)
                stats["copied_bytes"] += meta.size
                repaired = True
        for node in self._placement.get(key, ()):
            if node not in group and self._reachable(node):
                self._nodes.get(node, {}).pop(key, None)
        self._placement[key] = tuple(group)
        if repaired:
            stats["repaired"] += 1

    # -- observability / invariants ------------------------------------------
    def replica_counts(self) -> Dict[int, int]:
        """key -> number of LIVE, checksum-valid, up-to-date copies (the
        invariant suite asserts this equals min(r, live peers) for every
        key after convergence)."""
        live = set(int(x) for x in self.state.active_ids())
        out: Dict[int, int] = {}
        for key in self._placement:
            newest = 0
            copies: List[int] = []
            for node in self._placement[key]:
                if node not in live:
                    continue
                entry = self._copy(node, key)
                if entry is None:
                    continue
                if entry[0].version > newest:
                    newest = entry[0].version
                    copies = [node]
                elif entry[0].version == newest:
                    copies.append(node)
            out[key] = len(copies)
        return out

    def stats(self) -> Dict[str, int]:
        return {
            "blocks": len(self._placement),
            "replication": self.replication,
            "puts": self.puts,
            "gets": self.gets,
            "removes": self.removes,
            "read_repairs": self.read_repairs,
            "repair_syncs": self.repair_syncs,
            "upload_bytes": self.upload_bytes,
            "repair_bytes": self.repair_bytes,
            "corrupt_copies": self.corrupt_copies,
            "lost_blocks": self.lost_blocks,
        }


# ---------------------------------------------------------------------------
# content-addressed prefix cache
# ---------------------------------------------------------------------------

class PrefixCache:
    """Cross-session prompt-prefix KV cache over a ``BlockStore``.

    Keys are content-addressed: chunk j of a prompt is stored under the
    hash of the token prefix ``tokens[:(j+1)*chunk]`` (plus a salt naming
    the model — KV from another checkpoint must never hit).  Because KV
    at a position depends on the WHOLE prefix, hashing the full prefix —
    not the chunk — is what makes a hit bit-exact: two sessions sharing
    a system prompt share every full chunk inside it, and the importing
    session skips those chunks' prefill FLOPs entirely.

    ``match`` stops one segment short of the prompt end: the final
    (possibly padded) segment must be computed anyway to produce the
    last-token logits the admit returns.
    """

    def __init__(self, store: BlockStore, *, chunk: int, salt: str = ""):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.store = store
        self.chunk = chunk
        self.salt = salt
        self.hits = 0          # chunks imported instead of computed
        self.misses = 0        # chunks computed (and then inserted)
        self.tokens_saved = 0  # prefill token-positions skipped

    def _name(self, tokens: np.ndarray, end: int) -> str:
        h = hashlib.sha1(self.salt.encode())
        h.update(np.ascontiguousarray(tokens[:end], np.int32).tobytes())
        return f"prefix/{h.hexdigest()}"

    def max_cover(self, length: int) -> int:
        """Longest importable prefix for a prompt of ``length`` tokens:
        whole chunks only, and never the final segment."""
        return max(((length - 1) // self.chunk) * self.chunk, 0)

    def chunk_name(self, tokens: np.ndarray, end: int) -> Optional[str]:
        """Public content-address of the chunk ending at ``end`` — what
        admission affinity keys warm-replica lookups on (None when the
        prompt has no importable chunk there)."""
        if end < self.chunk or end > self.max_cover(len(tokens)):
            return None
        return self._name(np.asarray(tokens, np.int32), end)

    def match(self, tokens: np.ndarray) -> Tuple[int, List[np.ndarray]]:
        """Longest contiguous run of cached prefix chunks: returns
        (covered token count, the chunk blocks to import)."""
        tokens = np.asarray(tokens, np.int32)
        blocks: List[np.ndarray] = []
        covered = 0
        cap = self.max_cover(len(tokens))
        while covered + self.chunk <= cap:
            end = covered + self.chunk
            data = self.store.get(self._name(tokens, end))
            if data is None:
                break
            blocks.append(unpack_array(data))
            covered = end
        self.hits += len(blocks)
        self.tokens_saved += covered
        return covered, blocks

    def insert(self, tokens: np.ndarray, off: int, block: np.ndarray) -> None:
        """Offer the freshly computed chunk ``[off, off+chunk)`` of a
        prompt; no-ops when an equal-content block is already stored."""
        tokens = np.asarray(tokens, np.int32)
        end = off + self.chunk
        if end > len(tokens):
            return                      # padded final segment: never cached
        name = self._name(tokens, end)
        self.misses += 1
        if self.store.contains(name):
            return
        self.store.put(name, pack_array(block))
