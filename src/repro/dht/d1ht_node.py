"""D1HT peer for the discrete-event simulator (paper §IV, §VI).

Implements the full EDRA state machine:
  * Rules 1-8 message emission at (asynchronous) Theta-interval boundaries,
  * Rule 5 predecessor monitoring (missed TTL-0 -> probe -> leave event),
  * Rule 6 detection acknowledgment with TTL = rho,
  * Rule 8 range discharge via ID-interval tests on the local table,
  * Eq IV.4 early interval close when the buffer exceeds E events,
  * the §VI joining protocol (table from successor, join announced by
    EDRA, successor streams events to the newcomer),
  * voluntary leave = flush-then-notify; crash = buffer lost (§IV-C),
  * routing-table learning from received messages (§IV-C),
  * optional Quarantine admission (§V).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from repro.core.edra import Event, EventBuffer
from repro.core.ring import RoutingTable, in_interval
from repro.core.tuning import EdraParams
from .des import SimNet, SimPeer
from .messages import V_A_BITS, d1ht_maintenance_size


class D1HTPeer(SimPeer):
    def __init__(self, pid: int, net: SimNet, params: EdraParams,
                 *, adaptive_theta: bool = False):
        super().__init__(pid, net)
        self.params = params
        self.theta = params.theta
        self.rho = params.rho
        self.table = RoutingTable([])
        self.buffer = EventBuffer(self.rho)
        self.seen: Dict[Tuple[int, str, int], float] = {}
        self.last_pred_msg = 0.0
        self.probing: Optional[int] = None
        self.probe_sent_at = 0.0
        self.adaptive_theta = adaptive_theta
        self._events_observed = 0
        self._epoch = 0          # invalidates timers of dead incarnations
        self._interval_open = 0.0

    # -- lifecycle -------------------------------------------------------------
    def start(self, table_from: Optional["D1HTPeer"] = None) -> None:
        self.alive = True
        self._epoch += 1
        self.buffer = EventBuffer(self.rho)
        if table_from is not None:
            # §VI: the new peer gets the routing table from its successor.
            # Transfer traffic is NOT maintenance traffic (§VII-A).
            self.table = RoutingTable(list(table_from.table.ids))
        self.table.add(self.id)
        self.last_pred_msg = self.net.now
        self._schedule_interval()

    def stop(self, *, crash: bool) -> None:
        if not self.alive:
            return
        if not crash:
            # voluntary leave: flush buffered events, then tell the successor
            self._flush_interval()
            succ = self._succ_peer()
            if succ is not None and succ != self.id:
                ev = self._make_event(self.id, "leave")
                self.net.send(self.id, succ, V_A_BITS, "leaving", ev)
        self.alive = False
        self._epoch += 1

    # -- helpers ----------------------------------------------------------------
    def _make_event(self, subject: int, kind: str) -> Event:
        self.net.event_seq += 1
        return Event(subject_id=subject, kind=kind, seq=self.net.event_seq)

    def _succ_peer(self, i: int = 1) -> Optional[int]:
        if len(self.table) <= 1:
            return None
        return self.table.succ(self.id, i)

    def _pred_peer(self) -> Optional[int]:
        if len(self.table) <= 1:
            return None
        return self.table.pred(self.id, 1)

    def _n_estimate(self) -> int:
        return max(2, len(self.table))

    def _max_buffered(self) -> float:
        # Eq IV.4: E = 8 f n / (16 + 3 rho)
        n = self._n_estimate()
        return 8.0 * self.params.f * n / (16.0 + 3.0 * self.rho)

    # -- Theta intervals ----------------------------------------------------------
    def _schedule_interval(self) -> None:
        epoch = self._epoch
        self._interval_open = self.net.now

        def fire() -> None:
            if self.alive and self._epoch == epoch:
                self._end_interval()

        self.net.schedule(self.theta, fire)

    def _end_interval(self) -> None:
        self._flush_interval()
        self._check_predecessor()
        if self.adaptive_theta:
            self._retune()
        self._schedule_interval()

    def _early_close_check(self) -> None:
        """Eq IV.4 robustness: close the interval early under event bursts."""
        if len(self.buffer) >= max(2.0, math.ceil(self._max_buffered())):
            self._epoch += 1     # cancel the pending timer
            self._end_interval()

    def _flush_interval(self) -> None:
        per_ttl = self.buffer.flush()
        for l in range(self.rho):
            events = per_ttl.get(l, [])
            if 2 ** l >= len(self.table):
                continue  # target would wrap past the reporter (Rule 8)
            target = self._succ_peer(2 ** l)
            if target is None or target == self.id:
                continue
            # Rule 8: discharge events whose subject lies in stretch(p, 2^l)
            events = [e for e in events
                      if not in_interval(e.subject_id, self.id, target)]
            if l == 0 or events:   # Rule 4: M(0) always goes out, even empty
                self._send_maint(l, target, events)

    def _send_maint(self, l: int, target: int, events: List[Event]) -> None:
        """Reliable maintenance send: unacked datagrams are retransmitted;
        after the retransmit cycle times out the sender *learns* the target
        left (§IV-C routing-failure learning — no leave event is generated,
        that is the successor's job per Rule 5) and re-routes to the next
        live successor so the dissemination chain never silently breaks."""
        for _ in range(4):
            if target is None or target == self.id:
                return
            bits = d1ht_maintenance_size(events)
            if self.net.is_alive(target):
                self.net.send(self.id, target, bits, "maint", (l, events))
                return
            # ack timeout: one wasted transmission, then local learning
            self.net.send(self.id, target, bits, "maint", (l, events))
            self.table.remove(target)
            if 2 ** l >= len(self.table):
                return
            target = self._succ_peer(2 ** l)
            events = [e for e in events
                      if not in_interval(e.subject_id, self.id, target)]

    def _retune(self) -> None:
        """§IV-D self-tuning: re-derive Theta from locally observed r, n."""
        window = max(self.net.now - 1.0, 1.0)
        observed_r = self._events_observed / window if window > 0 else 0.0
        if observed_r > 0:
            p = self.params.retune(self._n_estimate(), observed_r)
            self.theta = max(0.25, p.theta)

    # -- event intake ---------------------------------------------------------------
    def _acknowledge(self, ev: Event, ttl: int) -> None:
        k = ev.dedup_key()
        if k in self.seen:
            return
        self.seen[k] = self.net.now
        self._events_observed += 1
        if ev.kind == "join":
            self.table.add(ev.subject_id)
        else:
            self.table.remove(ev.subject_id)
        self.buffer.acknowledge(ev, ttl)
        self._early_close_check()

    # -- datagram handling -------------------------------------------------------------
    def on_datagram(self, src: int, kind: str, payload) -> None:
        if kind == "maint":
            l, events = payload
            if src not in self.table:
                self.table.add(src)      # learn from messages (§IV-C)
            pred = self._pred_peer()
            if l == 0:
                if pred is None or src == pred:
                    self.last_pred_msg = self.net.now
                    self.probing = None
                elif pred is not None and self.probing is None:
                    # §IV-A stabilization: TTL-0 from someone other than our
                    # predecessor means the ring changed nearby — verify that
                    # pred(1) is still alive instead of trusting the stream.
                    self.probing = pred
                    self.probe_sent_at = self.net.now
                    self.net.send(self.id, pred, V_A_BITS, "probe", None,
                                  acked=False)
            for ev in events:
                self._acknowledge(ev, l)
        elif kind == "leaving":
            ev: Event = payload
            self._acknowledge(ev, self.rho)   # Rule 6 (voluntary, no probe)
        elif kind == "join-request":
            self._handle_join(src)
        elif kind == "probe":
            self.net.send(self.id, src, V_A_BITS, "probe-reply", None,
                          acked=False)
        elif kind == "probe-reply":
            if self.probing == src:
                self.probing = None
                self.last_pred_msg = self.net.now

    # -- Rule 5: predecessor failure detection ----------------------------------------
    def _check_predecessor(self) -> None:
        pred = self._pred_peer()
        if pred is None:
            return
        silent = self.net.now - self.last_pred_msg
        if (self.probing == pred
                and self.net.now - self.probe_sent_at > self.theta / 4.0):
            # probe outstanding with no reply => confirmed dead (Rule 5)
            self.table.remove(pred)
            self.probing = None
            ev = self._make_event(pred, "leave")
            self._acknowledge(ev, self.rho)   # Rule 6
            self.last_pred_msg = self.net.now
        elif self.probing is None and silent > self.theta:
            self.probing = pred
            self.probe_sent_at = self.net.now
            self.net.send(self.id, pred, V_A_BITS, "probe", None, acked=False)

    # -- §VI joining protocol ------------------------------------------------------------
    def _handle_join(self, new_id: int) -> None:
        """We are (about to be) the successor of ``new_id``."""
        newcomer = self.net.peers.get(new_id)
        if newcomer is None or not isinstance(newcomer, D1HTPeer):
            return
        newcomer.start(table_from=self)
        self.table.add(new_id)
        ev = self._make_event(new_id, "join")
        self._acknowledge(ev, self.rho)       # Rule 6: join detected by successor
        # stream our buffered knowledge so the newcomer misses nothing (§VI)
        for k, (bev, ttl) in list(self.buffer.acked.items()):
            newcomer._acknowledge(bev, ttl)


