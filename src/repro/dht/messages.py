"""Wire formats (paper Fig. 2) and traffic accounting.

All sizes in *bits* and including the 28-byte IPv4+UDP headers, exactly as
the paper counts them:

  D1HT / OneHop maintenance message: 40-byte fixed part (v_m = 320) +
      4 bytes per default-port event (m = 32) + 6 bytes otherwise (m = 48).
  1h-Calot maintenance message: fixed 48 bytes (v_c = 384), one event each.
  ack / heartbeat: 36 bytes (v_a = v_h = 288).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.core.edra import Event

V_M_BITS = 320
V_C_BITS = 384
V_A_BITS = 288
V_H_BITS = 288
DEFAULT_PORT = 1117  # the "default IPv4 port" of our D1HT instance (§VI)


@dataclass(frozen=True)
class Message:
    """Base simulated datagram."""

    src: int                  # peer ring ID
    dst: int
    kind: str                 # "maint" | "ack" | "heartbeat" | "lookup" | ...
    size_bits: int
    payload: tuple = ()
    ttl: int = -1             # EDRA TTL for maint messages
    seq: int = 0


def d1ht_maintenance_size(events: Sequence[Event]) -> int:
    """v_m + Σ m_i (Fig 2a)."""
    return V_M_BITS + sum(e.wire_bits for e in events)


def calot_maintenance_size() -> int:
    """Fixed 48 bytes — one event per message, counters make no sense (§VII-A)."""
    return V_C_BITS


def ack_size() -> int:
    return V_A_BITS


def heartbeat_size() -> int:
    return V_H_BITS


@dataclass
class TrafficMeter:
    """Per-peer byte accounting, split by direction and class."""

    out_bits: float = 0.0
    in_bits: float = 0.0
    out_msgs: int = 0
    in_msgs: int = 0
    maint_out_bits: float = 0.0   # routing-table maintenance + failure detection

    def send(self, bits: int, maintenance: bool = True) -> None:
        self.out_bits += bits
        self.out_msgs += 1
        if maintenance:
            self.maint_out_bits += bits

    def recv(self, bits: int) -> None:
        self.in_bits += bits
        self.in_msgs += 1

    def out_bps(self, seconds: float) -> float:
        return self.maint_out_bits / max(seconds, 1e-9)
