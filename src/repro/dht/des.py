"""Deterministic discrete-event network simulator for the DHT protocols.

Message-level fidelity: every maintenance datagram (with its Fig.-2 byte
size), ack, probe and heartbeat is individually delivered with a sampled
network delay; per-peer traffic is metered exactly as §VII-A counts it
(routing-table maintenance + failure detection only; lookups and
routing-table transfers excluded).

The two experimental environments of the paper map to delay models:
  * ``LanDelay``  — HPC datacenter (§VII-C/D): ~70 us one-way.
  * ``WanDelay``  — PlanetLab (§VII-B): lognormal, ~60 ms median one-way.
"""
from __future__ import annotations

import heapq
import math
import random
from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.ring import RoutingTable, ring_distance
from .messages import V_A_BITS, TrafficMeter


# ---------------------------------------------------------------------------
# Delay models
# ---------------------------------------------------------------------------

class DelayModel(ABC):
    @abstractmethod
    def sample(self, rng: random.Random) -> float: ...


class LanDelay(DelayModel):
    """HPC datacenter: measured one-hop lookup ~0.14 ms RTT => ~70 us one-way.

    Shifted exponential: a 10 us switching/NIC floor plus an exponential
    tail whose mean is chosen so the TOTAL mean is exactly ``mean`` —
    the floor used to be added on top of an Exp(mean) draw, which
    silently inflated the realized mean to ~80 us and skewed the
    §VII-C/D delay accounting against the documented 70 us."""

    def __init__(self, mean: float = 70e-6, floor: float = 10e-6):
        if mean <= floor:
            raise ValueError(f"mean {mean} must exceed the {floor} floor")
        self.mean = mean
        self.floor = floor

    def sample(self, rng: random.Random) -> float:
        return self.floor + rng.expovariate(1.0 / (self.mean - self.floor))


class WanDelay(DelayModel):
    """PlanetLab-like WAN: lognormal one-way delay, median ~60 ms."""

    def __init__(self, median: float = 0.060, sigma: float = 0.6):
        self.mu = math.log(median)
        self.sigma = sigma

    def sample(self, rng: random.Random) -> float:
        return rng.lognormvariate(self.mu, self.sigma)


# ---------------------------------------------------------------------------
# Network
# ---------------------------------------------------------------------------

@dataclass(order=True)
class _Scheduled:
    t: float
    seq: int
    fn: Callable[[], None] = field(compare=False)


class SimPeer(ABC):
    """Base class: a peer with an ID living in a SimNet."""

    def __init__(self, pid: int, net: "SimNet"):
        self.id = pid
        self.net = net
        self.alive = False

    @abstractmethod
    def start(self) -> None: ...

    @abstractmethod
    def stop(self, *, crash: bool) -> None: ...

    def on_datagram(self, src: int, kind: str, payload) -> None:  # pragma: no cover
        pass


class SimNet:
    def __init__(self, delay: DelayModel, seed: int = 0):
        self.delay = delay
        self.rng = random.Random(seed)
        self.now = 0.0
        self._heap: List[_Scheduled] = []
        self._seq = 0
        self.peers: Dict[int, SimPeer] = {}
        self.ring = RoutingTable([])          # ground truth: in-ring peers
        self.meters: Dict[int, TrafficMeter] = {}
        self.metering = False                 # warmup excluded (§VII-A phase 2)
        self.event_seq = 0                    # global event seq for dedup keys

    # -- scheduling ---------------------------------------------------------
    def schedule(self, dt: float, fn: Callable[[], None]) -> None:
        self.schedule_at(self.now + dt, fn)

    def schedule_at(self, t: float, fn: Callable[[], None]) -> None:
        self._seq += 1
        heapq.heappush(self._heap, _Scheduled(t, self._seq, fn))

    def run_until(self, t_end: float) -> None:
        while self._heap and self._heap[0].t <= t_end:
            item = heapq.heappop(self._heap)
            self.now = item.t
            item.fn()
        self.now = t_end

    # -- peers ---------------------------------------------------------------
    def add_peer(self, peer: SimPeer) -> None:
        self.peers[peer.id] = peer
        self.meters.setdefault(peer.id, TrafficMeter())

    def is_alive(self, pid: int) -> bool:
        p = self.peers.get(pid)
        return p is not None and p.alive

    # -- transport ------------------------------------------------------------
    def send(self, src: int, dst: int, bits: int, kind: str, payload=None,
             *, acked: bool = True, maintenance: bool = True) -> None:
        """UDP datagram with Fig-2 accounting.

        ``acked=True`` models the per-message acknowledgment (v_a bits from
        dst back to src) without a separate queue event.

        The metering decision is captured HERE, at send time, and applied
        to every leg of the exchange: a datagram in flight across the
        warmup->measurement boundary used to meter its recv and ack but
        not its send (and the converse at window close), biasing the
        §VII-A accounting at the window edges.  A datagram now counts
        all-or-nothing with its acks.
        """
        metered = self.metering
        if metered:
            m = self.meters[src]
            m.send(bits, maintenance)
        if not self.is_alive(dst):
            return  # datagram lost; retransmission is the sender's problem
        d = self.delay.sample(self.rng)

        def deliver() -> None:
            peer = self.peers.get(dst)
            if peer is None or not peer.alive:
                return
            if metered:
                self.meters[dst].recv(bits)
                if acked:
                    self.meters[dst].send(V_A_BITS, maintenance)
                    self.meters[src].recv(V_A_BITS)
            peer.on_datagram(src, kind, payload)

        self.schedule(d, deliver)

    # -- measurement -----------------------------------------------------------
    def reset_meters(self) -> None:
        for pid in self.meters:
            self.meters[pid] = TrafficMeter()

    def total_maint_out_bits(self) -> float:
        return sum(m.maint_out_bits for m in self.meters.values())
