"""Deterministic discrete-event network simulator for the DHT protocols.

Message-level fidelity: every maintenance datagram (with its Fig.-2 byte
size), ack, probe and heartbeat is individually delivered with a sampled
network delay; per-peer traffic is metered exactly as §VII-A counts it
(routing-table maintenance + failure detection only; lookups and
routing-table transfers excluded).

The two experimental environments of the paper map to delay models:
  * ``LanDelay``  — HPC datacenter (§VII-C/D): ~70 us one-way.
  * ``WanDelay``  — PlanetLab (§VII-B): lognormal, ~60 ms median one-way.
  * ``GeoDelay``  — multi-datacenter generalization of both: endpoint-
    aware, sampling each datagram around the per-region-pair medians of
    a ``runtime.placement.Topology`` (intra-region = the LanDelay
    regime, inter-region = the WanDelay lognormal regime).
"""
from __future__ import annotations

import heapq
import math
import random
from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import Callable, Dict, List

from repro.core.ring import RoutingTable
from .messages import V_A_BITS, TrafficMeter


# ---------------------------------------------------------------------------
# Delay models
# ---------------------------------------------------------------------------

class DelayModel(ABC):
    @abstractmethod
    def sample(self, rng: random.Random) -> float: ...

    def sample_pair(self, rng: random.Random, src: int, dst: int) -> float:
        """One-way delay for a specific (src, dst) datagram.  The base
        models are endpoint-oblivious, so the default ignores the pair;
        ``GeoDelay`` overrides it with per-region-pair distributions."""
        return self.sample(rng)


class LanDelay(DelayModel):
    """HPC datacenter: measured one-hop lookup ~0.14 ms RTT => ~70 us one-way.

    Shifted exponential: a 10 us switching/NIC floor plus an exponential
    tail whose mean is chosen so the TOTAL mean is exactly ``mean`` —
    the floor used to be added on top of an Exp(mean) draw, which
    silently inflated the realized mean to ~80 us and skewed the
    §VII-C/D delay accounting against the documented 70 us."""

    def __init__(self, mean: float = 70e-6, floor: float = 10e-6):
        if mean <= floor:
            raise ValueError(f"mean {mean} must exceed the {floor} floor")
        self.mean = mean
        self.floor = floor

    def sample(self, rng: random.Random) -> float:
        return self.floor + rng.expovariate(1.0 / (self.mean - self.floor))


class WanDelay(DelayModel):
    """PlanetLab-like WAN: lognormal one-way delay, median ~60 ms."""

    def __init__(self, median: float = 0.060, sigma: float = 0.6):
        self.mu = math.log(median)
        self.sigma = sigma

    def sample(self, rng: random.Random) -> float:
        return rng.lognormvariate(self.mu, self.sigma)


class GeoDelay(DelayModel):
    """Multi-datacenter delay keyed on a ``runtime.placement.Topology``
    (duck-typed — no import, so the DHT package stays free of the
    runtime package's accelerator deps).

    This is the stochastic twin of the topology's deterministic RTT
    estimator: each datagram samples around the SAME per-pair one-way
    median the placement policy ranks by, so what ``LatencyAware``
    optimizes is exactly what the DES measures.

      * intra-region: shifted exponential (the ``LanDelay`` regime) with
        mean = the topology's intra one-way estimate.  With
        ``Topology.single_region()`` (0.14 ms RTT) this reproduces the
        LanDelay default (70 us mean, 10 us floor) exactly.
      * inter-region: lognormal (the ``WanDelay``/PlanetLab regime) with
        median = the topology's inter-region one-way estimate.  A tighter
        default sigma than WanDelay's 0.6: per-pair spread is residual
        jitter, not the cross-pair spread the aggregate model folds in.
    """

    def __init__(self, topology, *, sigma: float = 0.25,
                 floor: float = 10e-6):
        self.topology = topology
        self.sigma = float(sigma)
        self.floor = float(floor)

    def _intra_mean(self) -> float:
        return max(self.topology.intra_rtt_ms * 0.5e-3, 2.0 * self.floor)

    @property
    def mean(self) -> float:
        """Expected one-way delay (s) over uniformly random region pairs
        — the hook ``core.churn.delay_mean_seconds`` duck-types on."""
        names = self.topology.names
        bump = math.exp(0.5 * self.sigma * self.sigma)  # lognormal mean/median
        tot = 0.0
        for a in names:
            for b in names:
                tot += (self._intra_mean() if a == b else
                        self.topology.one_way_ms(a, b) * 1e-3 * bump)
        return tot / (len(names) ** 2)

    def sample(self, rng: random.Random) -> float:
        # endpoint-oblivious fallback: a uniformly random region pair
        names = self.topology.names
        return self.sample_pair(rng, names[rng.randrange(len(names))],
                                names[rng.randrange(len(names))])

    def sample_pair(self, rng: random.Random, src, dst) -> float:
        topo = self.topology
        if topo._origin_index(src) == topo._origin_index(dst):
            m = self._intra_mean()
            return self.floor + rng.expovariate(1.0 / (m - self.floor))
        return rng.lognormvariate(math.log(topo.one_way_ms(src, dst) * 1e-3),
                                  self.sigma)


# ---------------------------------------------------------------------------
# Network
# ---------------------------------------------------------------------------

@dataclass(order=True)
class _Scheduled:
    t: float
    seq: int
    fn: Callable[[], None] = field(compare=False)


class SimPeer(ABC):
    """Base class: a peer with an ID living in a SimNet."""

    def __init__(self, pid: int, net: "SimNet"):
        self.id = pid
        self.net = net
        self.alive = False

    @abstractmethod
    def start(self) -> None: ...

    @abstractmethod
    def stop(self, *, crash: bool) -> None: ...

    def on_datagram(self, src: int, kind: str, payload) -> None:  # pragma: no cover
        pass


class SimNet:
    def __init__(self, delay: DelayModel, seed: int = 0):
        self.delay = delay
        self.rng = random.Random(seed)
        self.now = 0.0
        self._heap: List[_Scheduled] = []
        self._seq = 0
        self.peers: Dict[int, SimPeer] = {}
        self.ring = RoutingTable([])          # ground truth: in-ring peers
        self.meters: Dict[int, TrafficMeter] = {}
        self.metering = False                 # warmup excluded (§VII-A phase 2)
        self.event_seq = 0                    # global event seq for dedup keys

    # -- scheduling ---------------------------------------------------------
    def schedule(self, dt: float, fn: Callable[[], None]) -> None:
        self.schedule_at(self.now + dt, fn)

    def schedule_at(self, t: float, fn: Callable[[], None]) -> None:
        self._seq += 1
        heapq.heappush(self._heap, _Scheduled(t, self._seq, fn))

    def run_until(self, t_end: float) -> None:
        while self._heap and self._heap[0].t <= t_end:
            item = heapq.heappop(self._heap)
            self.now = item.t
            item.fn()
        self.now = t_end

    # -- peers ---------------------------------------------------------------
    def add_peer(self, peer: SimPeer) -> None:
        self.peers[peer.id] = peer
        self.meters.setdefault(peer.id, TrafficMeter())

    def is_alive(self, pid: int) -> bool:
        p = self.peers.get(pid)
        return p is not None and p.alive

    # -- transport ------------------------------------------------------------
    def send(self, src: int, dst: int, bits: int, kind: str, payload=None,
             *, acked: bool = True, maintenance: bool = True) -> None:
        """UDP datagram with Fig-2 accounting.

        ``acked=True`` models the per-message acknowledgment (v_a bits from
        dst back to src) without a separate queue event.

        The metering decision is captured HERE, at send time, and applied
        to every leg of the exchange: a datagram in flight across the
        warmup->measurement boundary used to meter its recv and ack but
        not its send (and the converse at window close), biasing the
        §VII-A accounting at the window edges.  A datagram now counts
        all-or-nothing with its acks.
        """
        metered = self.metering
        if metered:
            m = self.meters[src]
            m.send(bits, maintenance)
        if not self.is_alive(dst):
            return  # datagram lost; retransmission is the sender's problem
        d = self.delay.sample_pair(self.rng, src, dst)

        def deliver() -> None:
            peer = self.peers.get(dst)
            if peer is None or not peer.alive:
                return
            if metered:
                self.meters[dst].recv(bits)
                if acked:
                    self.meters[dst].send(V_A_BITS, maintenance)
                    self.meters[src].recv(V_A_BITS)
            peer.on_datagram(src, kind, payload)

        self.schedule(d, deliver)

    # -- measurement -----------------------------------------------------------
    def reset_meters(self) -> None:
        for pid in self.meters:
            self.meters[pid] = TrafficMeter()

    def total_maint_out_bits(self) -> float:
        return sum(m.maint_out_bits for m in self.meters.values())
