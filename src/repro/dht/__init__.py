"""Protocol-level DHT implementations: D1HT, 1h-Calot, latency models.

``des`` is a deterministic discrete-event network; ``experiment`` drives
the paper's §VII churn methodology over it.  ``latency`` is the
closed-form Figs-5/6 oracle; ``latency_sim`` is its measured twin
(DESIGN.md §9).
"""
from .calot_node import CalotPeer
from .d1ht_node import D1HTPeer
from .data import BlockMeta, BlockStore, PrefixCache, pack_array, unpack_array
from .des import GeoDelay, LanDelay, SimNet, WanDelay
from .experiment import ChurnConfig, ChurnResult, run_churn
from .latency_sim import (ServiceProfile, latency_experiment,
                          measure_profile, measured_retry_fraction)

__all__ = [
    "CalotPeer", "D1HTPeer", "GeoDelay", "LanDelay", "SimNet", "WanDelay",
    "BlockMeta", "BlockStore", "PrefixCache", "pack_array", "unpack_array",
    "ChurnConfig", "ChurnResult", "run_churn",
    "ServiceProfile", "latency_experiment", "measure_profile",
    "measured_retry_fraction",
]
