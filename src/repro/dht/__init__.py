"""Protocol-level DHT implementations: D1HT, 1h-Calot, latency models.

``des`` is a deterministic discrete-event network; ``experiment`` drives
the paper's §VII churn methodology over it.
"""
from .calot_node import CalotPeer
from .d1ht_node import D1HTPeer
from .des import LanDelay, SimNet, WanDelay
from .experiment import ChurnConfig, ChurnResult, run_churn

__all__ = [
    "CalotPeer", "D1HTPeer", "LanDelay", "SimNet", "WanDelay",
    "ChurnConfig", "ChurnResult", "run_churn",
]
