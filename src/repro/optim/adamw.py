"""AdamW + global-norm clipping + warmup-cosine schedule (pure JAX pytrees).

Moment dtype follows ModelConfig.opt_dtype: fp32 by default, bf16 for the
200B+ configs where fp32 moments would not fit a single pod (standard
large-scale practice; recorded in DESIGN.md).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    end_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.end_lr_frac + (1 - cfg.end_lr_frac)
                         * 0.5 * (1.0 + jnp.cos(math.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params: Params, cfg: OptConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)

    def zeros_like(p):
        if isinstance(p, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(p.shape, mdt)
        return jnp.zeros(p.shape, mdt)

    return {
        "m": jax.tree.map(zeros_like, params),
        "v": jax.tree.map(zeros_like, params),
        "step": (jax.ShapeDtypeStruct((), jnp.int32)
                 if isinstance(jax.tree.leaves(params)[0],
                               jax.ShapeDtypeStruct)
                 else jnp.zeros((), jnp.int32)),
    }


def state_pspecs(param_pspecs: Params) -> Dict[str, Any]:
    is_leaf = lambda x: isinstance(x, tuple)
    return {
        "m": jax.tree.map(lambda s: s, param_pspecs, is_leaf=is_leaf),
        "v": jax.tree.map(lambda s: s, param_pspecs, is_leaf=is_leaf),
        "step": (),
    }


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(params: Params, grads: Params, state: Dict[str, Any],
                  cfg: OptConfig) -> Tuple[Params, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # no weight decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in
            zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
