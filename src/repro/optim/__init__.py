from . import adamw
from .adamw import OptConfig
__all__ = ["adamw", "OptConfig"]
