from .step import TrainConfig, make_decode_step, make_prefill_step, make_train_step
__all__ = ["TrainConfig", "make_decode_step", "make_prefill_step", "make_train_step"]
