"""Training / prefill / decode step builders.

``make_train_step`` returns a pure (params, opt_state, batch) ->
(params, opt_state, metrics) function with:
  * optional gradient-accumulation microbatching (scan over micro-slices,
    fp32 grad accumulators) — both a memory knob for the 200B+ configs
    and a §Perf lever,
  * AdamW + clipping from repro.optim,
  * an optional gradient-compression hook (int8 quantize/dequantize around
    the DP reduction — beyond-paper distributed-optimization trick).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

import jax.numpy as jnp  # noqa: F811 (re-export convenience)

from repro.models import Model
from repro.optim.adamw import OptConfig, apply_updates, init_state
from repro.sharding import specs as sh_specs


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1            # grad-accumulation splits
    grad_compression: str = "none"   # none | int8


def _quantize_grads(grads):
    """int8 symmetric quantization (per-leaf scale) — dequantized right
    away; under GSPMD the quantized representation is what crosses the
    DP all-reduce boundary when compression is enabled."""
    def q(g):
        a = jnp.max(jnp.abs(g)) + 1e-12
        scale = a / 127.0
        qi = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return qi.astype(jnp.float32) * scale
    return jax.tree.map(q, grads)


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    def loss_fn(params, batch):
        return model.train_loss(params, batch)

    def constrain_to_params(grads):
        """Pin micro-step gradients to the parameter sharding so GSPMD
        reduce-scatters per micro-step instead of all-reducing the full
        gradient and re-slicing (order-of-magnitude collective saving on
        the FSDP axis)."""
        mesh = sh_specs.current_mesh()
        if mesh is None:
            return grads
        from jax.sharding import NamedSharding
        pspecs = jax.tree.map(
            lambda axes: NamedSharding(mesh, sh_specs.logical_spec(*axes)),
            model.param_pspecs(), is_leaf=lambda x: isinstance(x, tuple))
        return jax.tree.map(jax.lax.with_sharding_constraint, grads, pspecs)

    def single_grads(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def accumulated_grads(params, batch):
        n = tcfg.microbatches

        def resh(x):
            b = x.shape[0]
            return x.reshape(n, b // n, *x.shape[1:])

        micro = jax.tree.map(resh, batch)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            grads = constrain_to_params(grads)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(a.dtype), g_acc, grads)
            g_acc = constrain_to_params(g_acc)
            return (loss_acc + loss, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        g0 = constrain_to_params(g0)
        (loss_sum, g_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), g0), micro)
        grads = jax.tree.map(lambda g: g / n, g_sum)
        return loss_sum / n, grads

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            loss, grads = accumulated_grads(params, batch)
        else:
            loss, grads = single_grads(params, batch)
        if tcfg.grad_compression == "int8":
            grads = _quantize_grads(grads)
        params, opt_state, metrics = apply_updates(
            params, grads, opt_state, tcfg.opt)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def serve_step(params, cache, tokens, index):
        return model.decode_step(params, cache, tokens, index)
    return serve_step


def init_train_state(model: Model, rng, tcfg: TrainConfig):
    params = model.init(rng)
    opt_state = init_state(params, tcfg.opt)
    return params, opt_state
