"""Training loop with membership-driven fault tolerance.

Wires together: data pipeline -> train_step -> AdamW, checkpoint cadence
(FailoverManager), elastic re-mesh on membership events, straggler
eviction via step-time heartbeats (Rule-5 generalized).  Used by
examples/train_lm.py end-to-end and by the integration tests (which
inject failures and assert recovery).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.models import Model
from repro.optim import adamw
from repro.runtime import (ElasticController, FailoverConfig,
                           FailoverManager, Membership)
from .step import TrainConfig, make_train_step


@dataclass
class TrainerConfig:
    steps: int = 200
    log_every: int = 10
    train: TrainConfig = field(default_factory=TrainConfig)
    failover: Optional[FailoverConfig] = None


class Trainer:
    def __init__(self, model: Model, cfg: TrainerConfig, *,
                 membership: Optional[Membership] = None,
                 model_axis: int = 1):
        self.model = model
        self.cfg = cfg
        self.step_fn = jax.jit(make_train_step(model, cfg.train),
                               donate_argnums=(0, 1))
        self.membership = membership
        self.controller = (ElasticController(membership,
                                             model_axis=model_axis)
                           if membership else None)
        self.failover = (FailoverManager(cfg.failover, self.controller)
                         if (cfg.failover and self.controller) else None)
        self.history: List[Dict[str, float]] = []

    def init_state(self, rng) -> tuple:
        params = self.model.init(rng)
        opt = adamw.init_state(params, self.cfg.train.opt)
        return params, opt

    def fit(self, state: tuple, data: Iterator[Dict[str, np.ndarray]],
            start_step: int = 0) -> tuple:
        params, opt = state
        step = start_step
        for batch in data:
            if step >= self.cfg.steps:
                break
            t0 = time.perf_counter()
            jbatch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            params, opt, metrics = self.step_fn(params, opt, jbatch)
            dt = time.perf_counter() - t0
            step += 1

            if self.controller is not None:
                self.controller.heartbeat(0, dt)
            if self.failover is not None:
                self.failover.maybe_save(step, {"params": params, "opt": opt})
                if self.failover.needs_restore():
                    step, restored = self.failover.restore_latest(
                        {"params": params, "opt": opt})
                    params, opt = restored["params"], restored["opt"]

            if step % self.cfg.log_every == 0 or step == 1:
                rec = {"step": step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "lr": float(metrics["lr"]),
                       "step_time_s": dt}
                self.history.append(rec)
        return params, opt
