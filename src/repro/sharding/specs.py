"""Logical-axis sharding rules (GSPMD PartitionSpec rule engine).

Model code annotates tensors with *logical* axes ("batch", "heads", ...);
the launcher installs a rule set mapping logical axes to mesh axes for the
active mesh (single-pod ("data","model") or multi-pod ("pod","data",
"model")).  Outside any mesh (CPU smoke tests) every constraint is a
no-op, so the same model code runs everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# Logical tensor axes used by the model code.
#   batch    — global batch             -> data (and pod)
#   seq      — sequence (for SP/long-context KV shards)
#   heads    — attention heads / MoE experts / ff hidden  -> tensor axis
#   embed    — d_model rows (FSDP-style weight shard)     -> data
#   vocab    — vocabulary               -> tensor axis
#   layers   — stacked-layer leading dim (never sharded)
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "moe_ff": None,
    "moe_embed": "data",   # expert-weight d_model rows (FSDP default)
    "embed": "data",        # weight d_model rows: FSDP-style over data
    "act_embed": "model",   # activation d_model: TP-sharded residual stream
    "vocab": "model",
    "layers": None,
    "state": None,
}


class _State(threading.local):
    def __init__(self) -> None:
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, MeshAxes] = dict(DEFAULT_RULES)


_STATE = _State()


def set_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]] = None) -> None:
    _STATE.mesh = mesh
    base = dict(DEFAULT_RULES)
    if rules:
        base.update(rules)
    if mesh is not None:
        # drop mesh axes the current mesh does not have (e.g. "pod")
        names = set(mesh.axis_names)

        def filt(v: MeshAxes) -> MeshAxes:
            if v is None:
                return None
            if isinstance(v, str):
                return v if v in names else None
            kept = tuple(a for a in v if a in names)
            return kept if kept else None

        base = {k: filt(v) for k, v in base.items()}
    _STATE.rules = base


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]] = None):
    prev_mesh, prev_rules = _STATE.mesh, dict(_STATE.rules)
    set_mesh(mesh, rules)
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev_mesh, prev_rules


def update_rules(**kw: MeshAxes) -> None:
    _STATE.rules.update(kw)


def logical_spec(*axes: Optional[str]) -> P:
    """PartitionSpec for a tensor whose dims carry the given logical axes.

    Unknown logical axes raise: a typo in a spec tuple used to resolve
    to "replicated" and silently de-shard the tensor on every mesh."""
    rules = _STATE.rules
    resolved = []
    used: set = set()

    def resolve(a: Optional[str]) -> MeshAxes:
        if a is None:
            return None
        if a not in rules:
            raise KeyError(
                f"unknown logical axis {a!r}; known axes: {sorted(rules)}")
        v = rules[a]
        if v is None:
            return None
        vs = (v,) if isinstance(v, str) else tuple(v)
        kept = tuple(x for x in vs if x not in used)
        used.update(kept)
        if not kept:
            return None
        return kept[0] if len(kept) == 1 else kept

    for a in axes:
        resolved.append(resolve(a))
    return P(*resolved)


def shard(x, *axes: Optional[str]):
    """with_sharding_constraint on logical axes; identity without a mesh
    and inside a tensor-parallel shard_map body (where every array is
    already a per-device shard — a GSPMD constraint would be ill-typed)."""
    mesh = _STATE.mesh
    if mesh is None or tp_axis() is not None:
        return x
    spec = logical_spec(*axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*axes: Optional[str]) -> Optional[NamedSharding]:
    mesh = _STATE.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_spec(*axes))


# ---------------------------------------------------------------------------
# Tensor-parallel execution context (models/tp.py)
#
# Model code is written against GLOBAL shapes with `shard()` constraints;
# under tensor parallelism the same code runs INSIDE a shard_map body on
# per-device shards (local heads / ff / vocab), where partial matmul
# results must be combined with an explicit psum.  The TP context names
# the mapped mesh axis at trace time; `psum_tp` is the reduction hook the
# layer code calls after every row-parallel matmul (attention wo, mlp w2,
# moe combine, vocab-sharded embed).  Outside the context both are
# no-ops, so single-device execution is untouched.
# ---------------------------------------------------------------------------

class _TPState(threading.local):
    def __init__(self) -> None:
        self.axis: Optional[str] = None


_TP = _TPState()


def tp_axis() -> Optional[str]:
    """Mapped TP mesh-axis name while tracing inside a TP shard_map body
    (set by ``tp_context``), else None."""
    return _TP.axis


@contextlib.contextmanager
def tp_context(axis: str):
    prev = _TP.axis
    _TP.axis = axis
    try:
        yield
    finally:
        _TP.axis = prev


def psum_tp(x):
    """All-reduce ``x`` over the TP axis inside a TP context; identity
    outside one.  This is the row-parallel combine: each device holds a
    partial sum over its shard of the contracted dimension."""
    a = tp_axis()
    return jax.lax.psum(x, a) if a is not None else x


def tp_index() -> int:
    """This device's position along the TP axis (traced value inside a
    TP context; 0 outside one — the single-shard case)."""
    a = tp_axis()
    return jax.lax.axis_index(a) if a is not None else 0
