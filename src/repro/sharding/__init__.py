from .specs import (logical_spec, mesh_context, named_sharding, set_mesh,
                    shard, update_rules)

__all__ = ["logical_spec", "mesh_context", "named_sharding", "set_mesh",
           "shard", "update_rules"]
