"""EDRA dissemination tree as TPU collectives (DESIGN.md §2, level 2).

EDRA's rules map 1:1 onto a recursive-doubling schedule over an ICI ring:

  Rule 7  (send M(l) to succ(p, 2^l))      -> lax.ppermute shift by 2^l
  Rule 3  (aggregate everything acked)     -> concatenate accumulated blocks
  Rule 8  (discharge past the reporter)    -> stop at axis size (log2 n rounds)
  Theorem 1 (exactly-once, log time)       -> each block moves exactly once
                                              per round, rho = log2(n) rounds

``edra_allgather`` is therefore a *faithful* translation of the paper's
event-dissemination pattern into jax.lax collectives — each round ships
the peer's entire "acknowledged" set one power-of-two hop clockwise —
and doubles as an alternative data-parallel gradient-sync path
(reduce-scatter + edra tree) selectable in the trainer.

``edra_broadcast`` is the single-event special case (Figure 1 of the
paper): the reporter's block reaches all n peers in log2(n) rounds.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# jax moved shard_map out of experimental and renamed check_rep ->
# check_vma; support both so the EDRA collectives run on any jax >= 0.4.3x.
if hasattr(jax, "shard_map"):
    _shard_map, _CHECK_KW = jax.shard_map, "check_vma"
else:  # pragma: no cover - exercised on older jax only
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map_compat(f, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable jax.shard_map with replication checking off by
    default (the EDRA schedules intentionally produce per-device values)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})


def _axis_size(axis_name: str) -> int:
    """Mapped-axis size as a Python int on any jax version: psum of the
    literal 1 is constant-folded to the axis size (no communication)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def _rounds(n: int) -> int:
    r = int(math.log2(n))
    if 2 ** r != n:
        raise ValueError(f"EDRA collective needs a power-of-two axis, got {n}")
    return r


def edra_allgather(x: jax.Array, axis_name: str) -> jax.Array:
    """All-gather along ``axis_name`` via the EDRA tree.

    Inside shard_map: x is the local block; returns (n, *x.shape) stacked
    in ring order (block j = peer j's shard).
    """
    n = _axis_size(axis_name)
    rho = _rounds(n)
    idx = jax.lax.axis_index(axis_name)
    buf = x[None]                                   # blocks [i]
    for l in range(rho):
        m = 1 << l
        # Rule 7: every peer ships its acknowledged set to succ(p, 2^l);
        # equivalently each receives from pred(p, 2^l).
        perm = [(i, (i + m) % n) for i in range(n)]
        recv = jax.lax.ppermute(buf, axis_name, perm)
        # Rule 3 aggregation: prepend the predecessor's older blocks
        buf = jnp.concatenate([recv, buf], axis=0)
    # buf[j] = block of peer (i - n + 1 + j) mod n; rotate to canonical order
    return jnp.roll(buf, shift=idx + 1, axis=0)


def edra_broadcast(x: jax.Array, axis_name: str, source: int = 0) -> jax.Array:
    """Figure-1 dissemination: the reporter's block reaches all peers in
    log2(n) rounds; peers outside the frontier forward zeros that are
    overwritten on receipt (static schedule, exactly-once per Theorem 1).
    """
    n = _axis_size(axis_name)
    rho = _rounds(n)
    idx = jax.lax.axis_index(axis_name)
    off = (idx - source) % n                        # offset from reporter
    have = off == 0
    val = jnp.where(have, x, jnp.zeros_like(x))
    for l in range(rho):
        m = 1 << l
        perm = [((source + i) % n, (source + i + m) % n) for i in range(m)]
        recv = jax.lax.ppermute(val, axis_name, perm)
        gets = (off >= m) & (off < 2 * m)
        val = jnp.where(gets, recv, val)
    return val


def edra_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """DP all-reduce: native reduce-scatter (the reduction half has no
    analogue in the paper) + EDRA-tree all-gather for dissemination."""
    n = _axis_size(axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = jax.lax.psum_scatter(flat.reshape(n, -1), axis_name,
                                 scatter_dimension=0, tiled=False)
    full = edra_allgather(shard, axis_name).reshape(-1)
    if pad:
        full = full[:-pad]
    return full.reshape(x.shape)


def make_edra_allreduce(mesh: Mesh, axis_name: str = "data"):
    """shard_map-wrapped pytree all-reduce over one mesh axis, usable as a
    drop-in gradient synchronizer."""
    other = tuple(a for a in mesh.axis_names if a != axis_name)

    def tree_allreduce(tree):
        def one(g):
            fn = shard_map_compat(
                partial(edra_allreduce, axis_name=axis_name),
                mesh,
                in_specs=P(*(None for _ in g.shape)),
                out_specs=P(*(None for _ in g.shape)),
            )
            return fn(g)
        return jax.tree.map(one, tree)

    del other
    return tree_allreduce
