"""ServeCluster — churn-aware continuous-batching orchestration over the
D1HT ring.

The serving layer used to *route* with the ring and stop there: when a
replica failed, its sessions were silently orphaned.  ServeCluster closes
the loop, turning RingState into an end-to-end serve plane:

  * **Ownership**: a session's key is its ring hash; its home replica is
    the key's successor, resolved through the shared device-resident
    table (one hop, no directory — the paper's whole point).
  * **Membership subscription**: on every leave/quarantine/join batch the
    cluster asks ``RingState.owner_diff`` which key RANGES moved and
    re-resolves only the sessions inside them — O(affected), not
    O(sessions) per event.
  * **Migration**: an affected session moves to its ``replica_set``
    successor (Leslie's r-way successor-list replica group) and is
    re-prefilled from its transcript — the control plane keeps every
    session's prompt + generated tokens as the recoverable hot state
    (DistHash's replicated-object model), so a crash loses no session
    even though the device slab is gone.
  * **Quarantine gateways** (paper §V): a quarantined node owns no
    sessions (the mask excludes it from the active view) but proxies
    submissions to the real owner, paying one extra nearby hop.
  * **Generation restarts**: ``runtime.failover.ReplicaSupervisor`` pins
    a required generation per departed node; a node re-entering the ring
    gets a FRESH replica (its old slab is stale) instead of resuming.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set

import numpy as np

from repro.analysis.metering import metered
from repro.core.ringstate import _BUCKET_MIN_N
from repro.dht.data import BlockStore, PrefixCache, pack_array, unpack_array
from repro.models import Model
from repro.runtime import Membership, ReplicaSupervisor
from repro.runtime.placement import PlacementPolicy

from .server import Replica, Request, SessionRouter, session_key


@dataclass
class SessionRecord:
    """Control-plane view of one session — everything needed to rebuild
    it anywhere (the recoverable hot state)."""

    session_id: str
    key: int                       # ring key id
    prompt: np.ndarray
    max_new_tokens: int
    owner: int = -1
    # where the request physically came from (a node id or a Topology
    # region name; None = no locality info) — the placement policy's
    # ranking origin for this session's admission AND every later
    # migration, so a re-home optimizes for the same client
    origin: Optional[object] = None
    generated: List[int] = field(default_factory=list)
    migrations: int = 0
    done: bool = False
    # KV chunks exported to the replicated block store so far (chunk j
    # covers cache positions [j*chunk, (j+1)*chunk) of the transcript)
    exported_chunks: int = 0

    @property
    def transcript(self) -> np.ndarray:
        """prompt + every generated token: re-prefilling this on a new
        replica reproduces the decode state exactly (the last generated
        token is the pending input, so the prefill's next-token output is
        bit-for-bit what the old replica's next round would have
        emitted)."""
        if not self.generated:
            return np.asarray(self.prompt, np.int32)
        return np.concatenate([np.asarray(self.prompt, np.int32),
                               np.asarray(self.generated, np.int32)])


@dataclass
class RequestTrace:
    """Per-request wall-clock breakdown through the serve path (all in
    microseconds) — the measured request-latency plane's serve-side leg
    (DESIGN.md §9):

      * ``route_us``  — owner resolution (replica_set successor walks at
        submit and on every migration);
      * ``queue_us``  — capacity probing plus any time the session spent
        stranded waiting for a replica_set slot to free;
      * ``decode_us`` — prefill(s), including migration re-prefills, plus
        this session's share of every decode round it took a token from;
      * ``handoff_us`` — cache-TRANSFER time on migrations: fetching the
        session's KV blocks from the replica set plus importing them
        into the new replica's cache.  Kept apart from ``decode_us`` so
        a handoff (transfer) and a re-prefill (recompute) migration are
        distinguishable in the report instead of both landing in the
        route/decode buckets.
    """

    submitted_ns: int = 0
    completed_ns: int = 0
    queue_us: float = 0.0
    route_us: float = 0.0
    decode_us: float = 0.0
    handoff_us: float = 0.0
    _stranded_ns: int = 0          # transient: set while awaiting re-home

    @property
    def done(self) -> bool:
        return self.completed_ns > 0

    @property
    def total_us(self) -> float:
        """Submit -> completion wall time (in-flight sessions read 'so
        far')."""
        end = self.completed_ns or time.perf_counter_ns()
        return (end - self.submitted_ns) / 1e3


class ServeCluster:
    """Cluster-wide serve plane: replicas keyed by ring node, sessions
    migrated on churn, quarantined nodes proxying as gateways."""

    def __init__(self, membership: Membership, model: Model, params, *,
                 slots: int = 8, max_len: int = 64, replication: int = 2,
                 decode_kernel: Optional[bool] = None,
                 prefill_chunk: Optional[int] = 16,
                 prefill_duty: int = 6,
                 fused: Optional[bool] = None,
                 kv_blocks: Optional[bool] = None,
                 prefix_cache: Optional[bool] = None,
                 block_store: Optional[BlockStore] = None,
                 tp: int = 1, mesh=None,
                 policy: Optional[PlacementPolicy] = None):
        self.membership = membership
        self.state = membership.ring_state
        # every placement decision in the serve plane — admission spill,
        # migration targets, stranded re-homes — ranks through ONE
        # policy (DESIGN.md §13); default = the membership's policy (so
        # gateways and the serve plane always agree), which itself
        # defaults to RingSuccessor = the legacy successor-walk order
        self.policy = policy if policy is not None else membership.policy
        self.model = model if decode_kernel is None else \
            dataclasses.replace(model, decode_use_kernel=decode_kernel)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.replication = replication
        # chunked prefill segment length (None/0 = whole-prompt prefill);
        # migration re-prefills additionally OVERLAP decode rounds
        self.prefill_chunk = prefill_chunk
        # stall-free scheduling: advance in-flight prefill chunks only
        # every Nth decode round, bounding the sustained decode-
        # throughput hit to ~chunk_cost/(N*round_cost) while the drain
        # stays far below a synchronous whole-prompt re-prefill
        self.prefill_duty = max(int(prefill_duty), 1)
        self._step_seq = 0
        # fused route→gather→decode rounds: None = auto (engage once the
        # ring is big enough for the bucket directory to pay for itself —
        # the same _BUCKET_MIN_N threshold the lookup dispatch uses),
        # True = force (tests / small rings), False = never
        self.fused = fused
        self.router = SessionRouter(membership)
        self.supervisor = ReplicaSupervisor(membership)
        # tensor-parallel replica groups: with tp > 1 a ring node maps to
        # a device sub-mesh (models.tp.TPReplicaGroup), not a device.
        # The pooled ``mesh`` (a Mesh, a device sequence, or None for
        # every host device) is carved into len(devices)/tp groups; a
        # node acquires a group when its replica is created and releases
        # it when the replica dies.  Groups outnumbered by ring nodes are
        # shared deterministically (host-device test topologies).
        self.tp = int(tp)
        self._group_meshes: List[Any] = []
        self._group_objs: Dict[int, Any] = {}     # gi -> TPReplicaGroup
        self._group_params: Dict[int, Any] = {}   # gi -> sharded params
        self._node_group: Dict[int, int] = {}
        self._free_groups: List[int] = []
        self._dead_groups: Set[int] = set()
        if self.tp > 1:
            from repro.launch.mesh import replica_groups
            from repro.models.tp import validate_tp
            validate_tp(self.model.cfg, self.tp)
            self._group_meshes = replica_groups(mesh, self.tp)
            self._free_groups = list(
                range(len(self._group_meshes) - 1, -1, -1))
        # prefix-cache-aware admission: node -> content-addresses of the
        # prefix chunks that node has computed or imported (warm = no
        # fetch needed in a real placement); ``submit`` prefers a warm
        # replica_set candidate when several have capacity
        self._warm_prefixes: Dict[int, Set[str]] = {}
        self.prefix_affinity_hits = 0
        self.replicas: Dict[int, Replica] = {}
        self.sessions: Dict[str, SessionRecord] = {}
        self.traces: Dict[str, RequestTrace] = {}
        self.proxied: Dict[int, int] = {}      # gateway node -> proxy count
        self.migrated_sessions = 0
        # locality accounting: placements whose target sits in a
        # different Topology region than the request's origin (only
        # metered when the policy carries a topology and the request an
        # origin — the geo demo/bench read these)
        self.cross_region_admits = 0
        self.cross_region_migrations = 0
        self.stranded = 0                  # handoff attempts deferred on
        # overlapped migration re-prefills in flight: sid -> target node
        self._pending_homes: Dict[str, Dict] = {}
        self._retry: Set[str] = set()      # sids needing an off-event re-home
        # DHT-backed KV data plane (DESIGN.md §11): None = auto (on when
        # the family exports KV blocks and prefill is chunked).  The
        # store replicates every session's full KV chunks across the
        # ring, so migration becomes a cache HANDOFF (fetch + tail
        # re-prefill) instead of a transcript recompute; the prefix
        # cache shares prompt-prefix chunks across sessions.
        want_kv = kv_blocks if kv_blocks is not None else \
            bool(self.prefill_chunk) and model.supports_kv_blocks
        self.blocks: Optional[BlockStore] = None
        self.prefix: Optional[PrefixCache] = None
        if want_kv:
            if not (self.prefill_chunk and model.supports_kv_blocks):
                raise ValueError("kv_blocks needs a chunk-prefill family "
                                 "and a prefill_chunk size")
            self.blocks = block_store if block_store is not None else \
                BlockStore(self.state, replication=replication,
                           policy=self.policy)
            if prefix_cache is None or prefix_cache:
                self.prefix = PrefixCache(self.blocks,
                                          chunk=self.prefill_chunk,
                                          salt=model.cfg.name)
        self.handoffs = 0              # migrations served from KV blocks
        self.handoff_misses = 0        # block fetches that found nothing
        self.handoff_chunks = 0        # chunks imported instead of recomputed
        self.exported_blocks = 0       # chunks shipped into the store
        self.fused_rounds = 0
        self.fused_routed_keys = 0
        # fused-route owners that differ from the control plane's record:
        # sessions living on a replica_set spill member or mid-migration
        self.route_divergence = 0
        self._route_cal_us_per_key: Optional[float] = None
        self.state.track_owner_diffs()     # arm arc logging before events
        self._seen_version = self.state.active_version
        membership.subscribe(self._on_event)

    # -- replica lifecycle ---------------------------------------------------
    def _live_replica(self, node: int) -> Optional[Replica]:
        """The node's replica iff its device state is still valid.  A
        slab built before the node left and re-entered is stale —
        discarded here, so every caller (capacity probe, residency
        check, admit) agrees on restart-means-fresh (failover generation
        bump drives the replica restart)."""
        rep = self.replicas.get(node)
        if rep is not None and self.supervisor.needs_restart(node,
                                                            rep.generation):
            del self.replicas[node]
            self._forget_node(node)
            return None
        return rep

    def _replica_for(self, node: int) -> Replica:
        rep = self._live_replica(node)
        if rep is None:
            group, params = None, self.params
            if self.tp > 1:
                gi = self._acquire_group(node)
                group = self._group_obj(gi)
                params = self._params_for(gi)
            rep = Replica(self.model, slots=self.slots, max_len=self.max_len,
                          generation=self.supervisor.stamp(),
                          prefill_chunk=self.prefill_chunk,
                          prefix_cache=self.prefix, group=group)
            rep.attach_params(params)
            self.replicas[node] = rep
            if group is not None:
                self.supervisor.register_group(node, group.device_ids())
        return rep

    def _has_capacity(self, node: int) -> bool:
        rep = self._live_replica(node)
        if rep is not None:
            return rep.num_free > 0
        if self.slots <= 0:
            return False
        # a fresh replica additionally needs a live device group
        return self.tp == 1 or \
            len(self._dead_groups) < len(self._group_meshes)

    # -- device-group pool (tp > 1) -----------------------------------------
    def _group_obj(self, gi: int):
        g = self._group_objs.get(gi)
        if g is None:
            from repro.models.tp import TPReplicaGroup
            g = TPReplicaGroup(self.model, self._group_meshes[gi])
            self._group_objs[gi] = g
        return g

    def _params_for(self, gi: int):
        p = self._group_params.get(gi)
        if p is None:
            p = self._group_obj(gi).shard_params(self.params)
            self._group_params[gi] = p
        return p

    def _acquire_group(self, node: int) -> int:
        gi = self._node_group.get(node)
        if gi is not None and gi not in self._dead_groups:
            return gi
        while self._free_groups and \
                self._free_groups[-1] in self._dead_groups:
            self._free_groups.pop()
        if self._free_groups:
            gi = self._free_groups.pop()
        else:
            live = [i for i in range(len(self._group_meshes))
                    if i not in self._dead_groups]
            if not live:
                raise RuntimeError("no live device group for a new replica")
            gi = live[node % len(live)]    # oversubscribed: share a group
        self._node_group[node] = gi
        return gi

    def _release_group(self, node: int) -> None:
        gi = self._node_group.pop(node, None)
        if gi is None or gi in self._dead_groups:
            return
        if gi not in self._node_group.values() \
                and gi not in self._free_groups:
            self._free_groups.append(gi)

    def _forget_node(self, node: int) -> None:
        """A node's replica is gone: return its device group to the pool
        (unless the group died) and drop its warm-prefix residency."""
        self.supervisor.release_group(node)
        self._release_group(node)
        self._warm_prefixes.pop(node, None)

    def lose_device(self, device_id: int) -> Optional[int]:
        """Partial-group loss: any device of a replica group failing
        loses the whole replica (its weight shards and KV slices are
        useless without their siblings).  The owning group is marked
        dead FIRST — the membership-event cascade releases groups back
        to the pool synchronously, and a dead group must never host a
        fresh replica — then the owning ring node ``fail()``s, driving
        the normal generation-bump -> migration path onto healthy
        groups.  Returns the failed node id (None if the device backs no
        group)."""
        node = self.supervisor.group_owner(device_id)
        if node is None:
            return None
        gi = self._node_group.get(node)
        if gi is not None:
            self._dead_groups.add(gi)
        failed = self.supervisor.device_lost(device_id)
        if gi is not None:
            # oversubscribed topologies: every other node sharing the
            # dead group lost its devices too
            members = set(self.membership.members())
            for other, g in list(self._node_group.items()):
                if g == gi and other != failed and other in members:
                    self.supervisor.release_group(other)
                    self.membership.fail(other)
        return failed

    def _session_resident(self, rec: "SessionRecord") -> bool:
        """Does the session's slot actually exist on its recorded owner?
        False for stranded sessions (owner died with the slab) — even if
        the same node id later re-enters the ring with a fresh replica."""
        rep = self._live_replica(rec.owner)
        return rep is not None and rec.session_id in rep.sessions

    # -- request intake --------------------------------------------------------
    def submit(self, req: Request, *, via: Optional[int] = None,
               origin=None) -> int:
        """Admit a session and return its first generated token.

        ``via`` is the node the request physically arrived at.  A
        quarantined ``via`` node acts as a §V gateway: it forwards to the
        key's owner without ever owning the session (it is masked out of
        the active view, so the lookup can never pick it).  ``origin``
        (a node id or Topology region name; defaults to ``via``) is the
        locality the placement policy optimizes for — it sticks to the
        session, so migrations keep serving the same client."""
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            # guarantees any mid-stream transcript (prompt + generated,
            # at most prompt + max_new - 1 tokens) re-prefills into a
            # successor's cache on migration
            raise ValueError("prompt + max_new_tokens exceeds max_len")
        if via is not None and self.state.is_quarantined(via):
            self.proxied[via] = self.proxied.get(via, 0) + 1
        if origin is None:
            origin = via
        t_sub = time.perf_counter_ns()
        key = session_key(req.session_id)
        # host-side policy-ranked replica group (no device dispatch for a
        # single key); admission spills down the ranked group exactly
        # like migration does, so a hot arc fills its group before
        # rejecting — ring-successor order under the default policy
        group = self.policy.replica_group(self.state, key,
                                          self.replication, origin=origin)
        t_route = time.perf_counter_ns()
        cands = [n for n in group if self._has_capacity(n)]
        owner = cands[0] if cands else None
        if owner is None:
            raise RuntimeError(
                f"no capacity in the {len(group)}-way replica set for "
                f"session {req.session_id}")
        if len(cands) > 1:
            # prefix-cache-aware admission: among capacity-holding
            # replica_set candidates, prefer one that already computed or
            # imported this prompt's first prefix chunk (warm = the
            # prefix KV needs no fetch in a real placement)
            warm = self._warm_candidate(req.prompt, cands)
            if warm is not None:
                owner = warm
                self.prefix_affinity_hits += 1
        rec = SessionRecord(req.session_id, key, np.asarray(req.prompt,
                                                            np.int32),
                            req.max_new_tokens, owner=owner, origin=origin)
        self._note_region(rec, migration=False)
        t_queue = time.perf_counter_ns()
        tok = self._replica_for(owner).admit(req)
        self._note_warm(owner, rec.prompt)
        t_admit = time.perf_counter_ns()
        self.traces[req.session_id] = RequestTrace(
            submitted_ns=t_sub,
            route_us=(t_route - t_sub) / 1e3,
            queue_us=(t_queue - t_route) / 1e3,
            decode_us=(t_admit - t_queue) / 1e3)
        self.sessions[req.session_id] = rec
        self._export_session(rec)      # replicate the prompt's KV chunks
        self._push_token(rec, tok)
        return tok

    # -- placement-policy plumbing --------------------------------------------
    def _group_for(self, rec: "SessionRecord") -> List[int]:
        """Policy-ranked replica group for a session's NEXT placement:
        ranked from the session's recorded origin, with the current
        owner as the affinity candidate (policies may discount it so
        churn does not bounce a well-placed session; RingSuccessor
        ignores both and reproduces the legacy successor walk)."""
        return self.policy.replica_group(
            self.state, rec.key, self.replication,
            origin=rec.origin, prefer=rec.owner if rec.owner >= 0 else None)

    def _note_region(self, rec: "SessionRecord", *, migration: bool) -> None:
        topo = self.policy.topology
        if topo is None or rec.origin is None:
            return
        if topo.region_of(rec.owner) != (
                rec.origin if isinstance(rec.origin, str)
                else topo.region_of(rec.origin)):
            if migration:
                self.cross_region_migrations += 1
            else:
                self.cross_region_admits += 1

    # -- prefix-affinity bookkeeping ------------------------------------------
    def _warm_candidate(self, prompt, cands: List[int]) -> Optional[int]:
        if self.prefix is None:
            return None
        name = self.prefix.chunk_name(np.asarray(prompt, np.int32),
                                      self.prefix.chunk)
        if name is None:
            return None
        return next((n for n in cands
                     if name in self._warm_prefixes.get(n, ())), None)

    def _note_warm(self, node: int, prompt) -> None:
        """Record that ``node`` now holds every full prefix chunk of this
        prompt (it just computed or imported them)."""
        if self.prefix is None:
            return
        prompt = np.asarray(prompt, np.int32)
        c = self.prefix.chunk
        names = set()
        for end in range(c, self.prefix.max_cover(len(prompt)) + 1, c):
            nm = self.prefix.chunk_name(prompt, end)
            if nm is not None:
                names.add(nm)
        if names:
            self._warm_prefixes.setdefault(node, set()).update(names)

    # -- KV data plane (DESIGN.md §11) ----------------------------------------
    @staticmethod
    def _block_name(session_id: str, j: int, shard: int = 0) -> str:
        """Store name of chunk ``j``: shard 0 keeps the legacy name (a
        tp=1 store is byte-identical to before), shard s > 0 of a TP
        group's per-device export lands under a ``#s`` suffix."""
        base = f"kv/{session_id}/{j}"
        return base if shard == 0 else f"{base}#{shard}"

    def _export_session(self, rec: SessionRecord) -> None:
        """Ship every newly completed KV chunk of the session's live
        cache into the replicated store (put = r-way successor write).
        These blocks are what make a later migration a cache handoff:
        they survive the owner's death on its replica set."""
        if self.blocks is None or rec.done:
            return
        rep = self._live_replica(rec.owner)
        if rep is None:
            return
        slot = rep.sessions.get(rec.session_id)
        if slot is None:
            return
        c = self.prefill_chunk
        full = int(rep.lengths[slot]) // c
        for j in range(rec.exported_chunks, full):
            # per-shard export: each device of a TP group ships only its
            # kv_heads slice (one slab for single-device replicas).
            # Placed AT the session's ring key, not the block-name hash:
            # the session and its blocks share ONE replica set, so the
            # migration target the policy picks already holds the
            # handoff blocks locally — BlockStore.sync() and migration
            # can no longer re-home them to different replicas
            for s_i, slab in enumerate(
                    rep.export_block_shards(rec.session_id, j)):
                self.blocks.put(self._block_name(rec.session_id, j, s_i),
                                pack_array(slab), at=rec.key)
            self.exported_blocks += 1
        rec.exported_chunks = max(rec.exported_chunks, full)

    def _fetch_blocks(self, rec: SessionRecord, s: int) -> List[np.ndarray]:
        """The longest contiguous run of the session's stored KV chunks,
        capped so the final prompt segment is always recomputed (its
        all-position logits carry the admit token)."""
        c = self.prefill_chunk
        cap = max(((s - 1) // c) * c, 0)
        hkv = self.model.cfg.num_kv_heads
        blocks: List[np.ndarray] = []
        while (len(blocks) + 1) * c <= cap:
            data = self.blocks.get(self._block_name(rec.session_id,
                                                    len(blocks)))
            if data is None:
                break
            slab0 = unpack_array(data)
            # shard 0's local head count names the donor's shard fan-out
            # (self-describing: a tp=4 donor's chunks reassemble on a
            # tp=2 — or tp=1 — consumer and vice versa); ANY missing
            # sibling shard makes the whole chunk a miss, so a torn
            # export degrades to recompute, never to wrong KV
            if slab0.shape[3] == 0 or hkv % slab0.shape[3]:
                break
            n_shards = hkv // slab0.shape[3]
            shards = [slab0]
            for s_i in range(1, n_shards):
                d2 = self.blocks.get(self._block_name(rec.session_id,
                                                      len(blocks), s_i))
                if d2 is None:
                    shards = None
                    break
                shards.append(unpack_array(d2))
            if shards is None:
                break
            blocks.append(shards[0] if len(shards) == 1
                          else np.concatenate(shards, axis=3))
        return blocks

    def _drop_session_blocks(self, rec: SessionRecord) -> None:
        if self.blocks is None:
            return
        for j in range(rec.exported_chunks):
            for s_i in range(max(self.tp, 1)):
                self.blocks.remove(self._block_name(rec.session_id, j, s_i))
        rec.exported_chunks = 0

    def _push_token(self, rec: SessionRecord, tok: int) -> None:
        rec.generated.append(tok)
        if len(rec.generated) >= rec.max_new_tokens:
            rec.done = True
            trace = self.traces.get(rec.session_id)
            if trace is not None and not trace.done:
                trace.completed_ns = time.perf_counter_ns()
            rep = self.replicas.get(rec.owner)
            if rep is not None:
                rep.evict(rec.session_id)
            self._drop_session_blocks(rec)   # a finished session's KV is
            # dead weight on r nodes — reclaim it (prefix chunks persist:
            # they are content-addressed, not session-owned)

    # -- decode loop -----------------------------------------------------------
    def _route_table(self):
        """Device bucket directory for fused rounds, or None to run the
        classic (unfused) rounds.  Auto mode engages fusion at the same
        ring size the lookup dispatch switches to the bucket index, so
        small test clusters keep their exact legacy upload accounting."""
        if self.fused is False:
            return None
        if self.fused is None and len(self.state) < _BUCKET_MIN_N:
            return None
        return self.state.device_bucket_table()

    @metered
    def _calibrate_route(self, rep: Replica, route) -> None:
        """One-time per-key cost of the on-device route, measured by
        timing the bucketized lookup standalone on this replica's key
        slab (warm trace, second call timed).  The fused round is ONE
        dispatch, so this is how the queue/route/decode trace splits
        survive fusion: the round's wall time is split into a route
        share (this calibration x keys) and a decode share.

        ``@metered``: the two block_until_ready syncs are the
        measurement — repro-lint RL003 allowlists this site, and the
        meter counter lets tests assert it stays out of the round loop
        (one call per (replica, ring-version), never per round)."""
        import jax
        import jax.numpy as jnp

        from repro.kernels.ring_lookup.ops import ring_lookup_bucketed
        khi = jnp.asarray(rep.key_hi)
        klo = jnp.asarray(rep.key_lo)
        jax.block_until_ready(ring_lookup_bucketed(khi, klo, *route))
        t0 = time.perf_counter_ns()
        jax.block_until_ready(ring_lookup_bucketed(khi, klo, *route))
        self._route_cal_us_per_key = \
            (time.perf_counter_ns() - t0) / 1e3 / max(rep.key_hi.size, 1)

    def step(self) -> Dict[str, int]:
        """One continuous-batching round across every replica: advance
        in-flight overlapped prefills by one chunk, then run one decode
        round (fused with the on-device route when enabled)."""
        if self._seen_version != self.state.active_version:
            self._migrate_affected()       # retry deferred re-homes
        self._service_pending()
        route = self._route_table()
        self._step_seq += 1
        duty_turn = self._step_seq % self.prefill_duty == 0
        out: Dict[str, int] = {}
        for node in list(self.replicas):
            rep = self.replicas[node]
            # advance chunks on the duty-cycle beat — or every round
            # when the replica has no decode traffic to protect
            if rep.num_pending and (duty_turn or not rep.sessions):
                t0 = time.perf_counter_ns()
                completions = rep.advance_prefills()
                adv_us = (time.perf_counter_ns() - t0) / 1e3
                self._finish_pending(node, rep, completions, adv_us)
            if route is not None and self._route_cal_us_per_key is None \
                    and rep.sessions:
                self._calibrate_route(rep, route)
            t0 = time.perf_counter_ns()
            toks = rep.decode_round(route=route)
            round_us = (time.perf_counter_ns() - t0) / 1e3
            route_us = 0.0
            if route is not None and toks:
                self.fused_rounds += 1
                self.fused_routed_keys += len(rep.routed_owners)
                self._note_owner_divergence(rep)
                route_us = min((self._route_cal_us_per_key or 0.0)
                               * len(toks), round_us)
            share_route = route_us / max(len(toks), 1)
            share_decode = (round_us - route_us) / max(len(toks), 1)
            for sid, tok in toks.items():
                trace = self.traces.get(sid)
                if trace is not None:
                    trace.decode_us += share_decode
                    trace.route_us += share_route
                self._push_token(self.sessions[sid], tok)
                out[sid] = tok
        if self.blocks is not None and duty_turn:
            # decode rounds advance lengths across chunk boundaries;
            # ship the newly completed chunks on the same duty beat the
            # prefill scheduler uses, bounding the export sync cost to
            # ~1/duty of rounds
            for rec in self.live_sessions:
                self._export_session(rec)
        return out

    def _note_owner_divergence(self, rep: Replica) -> None:
        for sid, owner in rep.routed_owners.items():
            rec = self.sessions.get(sid)
            if rec is not None and owner != rec.owner:
                self.route_divergence += 1

    def _finish_pending(self, node: int, rep: Replica,
                        completions: Dict[str, int], adv_us: float) -> None:
        """Commit overlapped re-prefills that just completed (the admit
        token is the session's next token) and re-strand any that
        failed mid-chunk (their slot is already released)."""
        share_us = adv_us / max(len(completions), 1)
        for sid, tok in completions.items():
            self._pending_homes.pop(sid, None)
            rec = self.sessions.get(sid)
            if rec is None:
                rep.evict(sid)
                continue
            trace = self.traces.get(sid)
            if trace is not None:
                trace.decode_us += share_us
            self._push_token(rec, tok)
        for sid in rep.failed_prefills:
            self._pending_homes.pop(sid, None)
            self._retry.add(sid)
        rep.failed_prefills.clear()

    def _service_pending(self) -> None:
        """Re-home sessions whose overlapped-prefill target died with the
        chunks in flight, plus strands with no membership event left to
        piggyback a retry on."""
        for sid in list(self._pending_homes):
            node = self._pending_homes[sid]["node"]
            rep = self._live_replica(node)
            if rep is None or (sid not in rep._pending
                               and sid not in rep.sessions):
                del self._pending_homes[sid]
                self._retry.add(sid)
        for sid in list(self._retry):
            self._retry.discard(sid)
            rec = self.sessions.get(sid)
            if rec is None or rec.done or self._session_resident(rec) \
                    or sid in self._pending_homes:
                continue
            self._rehome(rec)

    def _rehome(self, rec: SessionRecord) -> None:
        group = self._group_for(rec)
        try:
            self._handoff(rec, group)
        except RuntimeError:               # replica_set full right now
            self.stranded += 1
            self._retry.add(rec.session_id)
            trace = self.traces.get(rec.session_id)
            if trace is not None and not trace._stranded_ns:
                trace._stranded_ns = time.perf_counter_ns()

    def run(self, max_rounds: int = 1024) -> int:
        """Decode until every live session completes; returns rounds."""
        rounds = 0
        while any(not r.done for r in self.sessions.values()):
            if rounds >= max_rounds:
                raise RuntimeError("sessions did not complete")
            self.step()
            rounds += 1
        return rounds

    @property
    def live_sessions(self) -> List[SessionRecord]:
        return [r for r in self.sessions.values() if not r.done]

    @property
    def pending_migrations(self) -> int:
        """Overlapped re-prefills still in flight (chunks not yet done)."""
        return len(self._pending_homes)

    # -- churn handling --------------------------------------------------------
    def _on_event(self, ev) -> None:
        if ev.kind != "join":
            # leave: the node's slab is gone with it; quarantine: the
            # supervisor pinned its generation, so the slab could never
            # be resumed anyway — reclaim it instead of hoarding KV
            if self.replicas.pop(ev.subject_id, None) is not None:
                self._forget_node(ev.subject_id)
            if self.blocks is not None and ev.kind == "leave":
                # a detected failure takes the node's block copies with
                # it (quarantine keeps them: the peer is alive, §V)
                self.blocks.drop_node(ev.subject_id)
        if self.blocks is not None:
            # re-replicate exactly the affected blocks BEFORE re-homing
            # sessions: the handoff fetch below must find r live copies
            self.blocks.sync()
        self._migrate_affected()

    def _migrate_affected(self) -> int:
        """Move exactly the sessions whose key range changed owners.

        ``_seen_version`` only advances when the whole batch re-homed: a
        session that finds its entire replica_set full stays flagged (the
        skip check makes reprocessing idempotent) and is retried by the
        next ``step``/event once capacity frees, instead of silently
        pointing at a dead owner forever."""
        target_version = self.state.active_version
        diff = self.state.owner_diff(self._seen_version, target_version)
        live = self.live_sessions
        if not live:
            self._seen_version = target_version
            return 0
        keys = np.fromiter((r.key for r in live), np.uint64, len(live))
        hit = diff.affected(keys)
        moved = 0
        complete = True
        for rec in (r for r, h in zip(live, hit) if h):
            if rec.session_id in self._pending_homes:
                continue    # an overlapped re-home is already in flight;
                # _service_pending re-strands it if that target dies
            t0 = time.perf_counter_ns()
            group = self._group_for(rec)
            trace = self.traces.get(rec.session_id)
            if trace is not None:
                trace.route_us += (time.perf_counter_ns() - t0) / 1e3
            if group[0] == rec.owner and self._session_resident(rec):
                continue    # still primary AND its slot is really there
                # (a bare owner-id match is not enough: a stranded
                # session's dead owner may have re-entered the ring with
                # an empty slab)
            try:
                self._handoff(rec, group)
                moved += 1
            except RuntimeError:            # replica_set full right now
                self.stranded += 1
                complete = False
                if trace is not None and not trace._stranded_ns:
                    trace._stranded_ns = time.perf_counter_ns()
        if complete:
            self._seen_version = target_version
        return moved

    def _handoff(self, rec: SessionRecord, group: List[int]) -> None:
        """Re-prefill the session's transcript on the first member of its
        replica_set group with a free slot (capacity spill down the r-way
        successor list); the admit's return value IS the next token.  The
        new slot is filled BEFORE the old one is freed, so a failed admit
        never strands a session half-migrated."""
        resident = self._session_resident(rec)
        new_owner = None
        for n in group:
            if n == rec.owner and resident:
                return      # a group member already holds its live slot;
                # moving it to a lower-priority member gains nothing
            if self._has_capacity(n):
                new_owner = n
                break
        if new_owner is None:
            raise RuntimeError(
                f"no capacity in the {len(group)}-way replica set for "
                f"session {rec.session_id}")
        t0 = time.perf_counter_ns()
        trace = self.traces.get(rec.session_id)
        if trace is not None and trace._stranded_ns:
            trace.queue_us += (t0 - trace._stranded_ns) / 1e3
            trace._stranded_ns = 0
        rep = self._replica_for(new_owner)
        req = Request(rec.session_id, rec.transcript, rec.max_new_tokens)
        if self.blocks is not None and rep._chunkable(len(req.prompt)) \
                and self._handoff_from_blocks(rec, rep, req, resident,
                                              new_owner, trace):
            return
        if not resident and rep._chunkable(len(req.prompt)):
            # the old slab is gone, so nobody is decoding this session:
            # re-prefill it one fixed-shape chunk per round, OVERLAPPED
            # with the replicas' decode rounds instead of stalling them
            if rep.begin_admit(req) is None:
                self._pending_homes[rec.session_id] = {"node": new_owner,
                                                       "t0": t0}
                # ownership transfers NOW (the old owner is gone and the
                # route must point at the re-prefill target); the next
                # token arrives when the pending completes
                rec.owner = new_owner
                rec.migrations += 1
                self.migrated_sessions += 1
                self._note_region(rec, migration=True)
                return
            raise AssertionError("chunkable begin_admit returned a token")
        tok = rep.admit(req)
        if trace is not None:
            trace.decode_us += (time.perf_counter_ns() - t0) / 1e3
        if resident:                        # clean handoff: free the slot
            self.replicas[rec.owner].evict(rec.session_id)
        rec.owner = new_owner
        rec.migrations += 1
        self.migrated_sessions += 1
        self._note_region(rec, migration=True)
        self._note_warm(new_owner, rec.prompt)
        self._push_token(rec, tok)

    def _handoff_from_blocks(self, rec: SessionRecord, rep: Replica,
                             req: Request, resident: bool, new_owner: int,
                             trace: Optional[RequestTrace]) -> bool:
        """Zero-recompute cache handoff: fetch the session's KV chunks
        from their replica sets and admit from them — only the final
        prompt segment is re-prefilled.  Returns False on a total block
        miss (or an import failure), sending the caller down the
        re-prefill paths; the migration then costs recompute but never
        correctness."""
        if resident:
            # the old slab is still live (quarantine / spill): flush its
            # newest chunks into the store first so the transfer covers
            # the whole transcript, not just the last duty-beat export
            self._export_session(rec)
        t0 = time.perf_counter_ns()
        blocks = self._fetch_blocks(rec, len(req.prompt))
        if not blocks:
            self.handoff_misses += 1
            return False
        fetch_us = (time.perf_counter_ns() - t0) / 1e3
        t1 = time.perf_counter_ns()
        try:
            tok = rep.admit_from_blocks(req, blocks)
        except Exception:
            # a torn/mismatched block import must degrade to recompute,
            # never kill the migration batch
            self.handoff_misses += 1
            return False
        admit_us = (time.perf_counter_ns() - t1) / 1e3
        if trace is not None:
            trace.handoff_us += fetch_us + rep.import_us
            trace.decode_us += max(admit_us - rep.import_us, 0.0)
        self.handoffs += 1
        self.handoff_chunks += len(blocks)
        if resident:
            self.replicas[rec.owner].evict(rec.session_id)
        # chunks up to the fetched run are still stored and content-
        # valid; anything past it (a lost block broke the run) will be
        # re-exported from the new slab on the next duty beat
        rec.exported_chunks = len(blocks)
        rec.owner = new_owner
        rec.migrations += 1
        self.migrated_sessions += 1
        self._note_region(rec, migration=True)
        self._note_warm(new_owner, rec.prompt)
        self._push_token(rec, tok)
        return True

    # -- observability -----------------------------------------------------------
    def latency_report(self) -> Dict[str, float]:
        """Serve-path request-latency distribution with the queue/route/
        decode breakdown (completed sessions only), µs.  The measured
        twin of the request plane's network-side accounting: BENCH
        latency rows report lookup latency, this reports what the serve
        path adds on top of the route."""
        done = [t for t in self.traces.values() if t.done]
        if not done:
            return {"completed": 0}
        tot = np.array([t.total_us for t in done])
        return {
            "completed": len(done),
            "total_us_mean": round(float(tot.mean()), 1),
            "total_us_p50": round(float(np.percentile(tot, 50)), 1),
            "total_us_p99": round(float(np.percentile(tot, 99)), 1),
            "queue_us_mean": round(
                float(np.mean([t.queue_us for t in done])), 1),
            "route_us_mean": round(
                float(np.mean([t.route_us for t in done])), 1),
            "decode_us_mean": round(
                float(np.mean([t.decode_us for t in done])), 1),
            "handoff_us_mean": round(
                float(np.mean([t.handoff_us for t in done])), 1),
            "router_route_us_per_key": round(
                self.router.route_us_per_key, 2),
        }

    def stats(self) -> Dict[str, int]:
        """Serve-plane counters plus the routing plane's device-traffic
        accounting: the router resolves through ``RingState.lookup``
        (two-level bucket index at scale, flat scan below it — §7), so
        ``route_upload_bytes`` IS the maintenance traffic this cluster's
        membership churn has cost the device so far."""
        out = {
            "sessions": len(self.sessions),
            "live": len(self.live_sessions),
            "replicas": len(self.replicas),
            "migrated": self.migrated_sessions,
            "stranded": self.stranded,
            "proxied": sum(self.proxied.values()),
            "route_uploads": self.state.upload_count,
            "route_upload_bytes": self.state.upload_bytes,
            "route_delta_uploads": self.state.delta_uploads,
        }
        if self.policy.topology is not None:
            out.update({
                "cross_region_admits": self.cross_region_admits,
                "cross_region_migrations": self.cross_region_migrations,
            })
        if self.tp > 1:
            out.update({
                "tp": self.tp,
                "groups": len(self._group_meshes),
                "dead_groups": len(self._dead_groups),
            })
        if self.blocks is not None:
            out.update({
                "handoffs": self.handoffs,
                "handoff_misses": self.handoff_misses,
                "handoff_chunks": self.handoff_chunks,
                "exported_blocks": self.exported_blocks,
                "block_upload_bytes": self.blocks.upload_bytes,
                "block_repair_bytes": self.blocks.repair_bytes,
            })
        if self.prefix is not None:
            out.update({
                "prefix_hits": self.prefix.hits,
                "prefix_misses": self.prefix.misses,
                "prefix_tokens_saved": self.prefix.tokens_saved,
                "prefix_affinity_hits": self.prefix_affinity_hits,
            })
        return out
