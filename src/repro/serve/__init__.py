from .server import Replica, Request, SessionRouter

__all__ = ["Replica", "Request", "SessionRouter"]
