from .cluster import RequestTrace, ServeCluster, SessionRecord
from .server import Replica, Request, SessionRouter, session_key

__all__ = ["Replica", "Request", "RequestTrace", "ServeCluster",
           "SessionRecord", "SessionRouter", "session_key"]
