from .cluster import ServeCluster, SessionRecord
from .server import Replica, Request, SessionRouter, session_key

__all__ = ["Replica", "Request", "ServeCluster", "SessionRecord",
           "SessionRouter", "session_key"]
