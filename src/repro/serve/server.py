"""Batched serving with D1HT session routing.

Requests carry a session id; the D1HT ring (full routing table, single
local lookup) decides which serving replica owns the session's KV cache.
The Pallas ``ring_lookup`` kernel resolves whole request batches
on-device.  Each replica runs continuous batched decode over its slots:
slot state lives in flat per-slot arrays and every active slot decodes at
its OWN cache position in one jitted call (per-slot lengths flow through
``decode_attention``'s masking), so mixed-length sessions never attend
past their real length and a long session never gates short ones.

Quarantined replicas (spot nodes inside T_q) take no sessions but may
proxy requests — the paper's gateway mechanism (§V); see
``repro.serve.cluster.ServeCluster`` for the churn-aware orchestration
(migration on leave/quarantine, generation-driven restarts).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ring import hash_id
from repro.core.ringstate import RingState
from repro.models import Model
from repro.runtime import Membership


@dataclass
class Request:
    session_id: str
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16


class SessionRouter:
    """Batched session -> replica resolution over the ring.

    Routes from the Membership's shared ``RingState``: the sorted table
    lives on-device as capacity-padded uint32 (hi, lo) word pairs and is
    re-uploaded only when a membership event bumps the state version —
    never per request batch — and lookups compare full 64-bit IDs (the
    old path truncated to the top 32 bits, which collides at scale).
    """

    def __init__(self, membership: Membership):
        self.membership = membership
        # no event subscription needed: the device table refreshes
        # lazily off the shared state's version
        self.state: RingState = membership.ring_state
        # per-batch route-latency accounting (request-latency plane §9)
        self.route_ns = 0
        self.route_batches = 0
        self.route_keys = 0

    @property
    def uploads(self) -> int:
        """Device-table uploads so far (1 per membership version actually
        routed against — asserted by the serve acceptance test)."""
        return self.state.upload_count

    @property
    def route_us_per_key(self) -> float:
        """Measured mean on-device resolution cost per routed key."""
        return self.route_ns / 1e3 / max(self.route_keys, 1)

    def route(self, session_ids: List[str]) -> List[int]:
        keys = np.fromiter(
            (session_key(s) for s in session_ids),
            np.uint64, len(session_ids))
        t0 = time.perf_counter_ns()
        owners = self.state.lookup(keys)
        self.route_ns += time.perf_counter_ns() - t0
        self.route_batches += 1
        self.route_keys += len(session_ids)
        return [int(p) for p in owners]


def session_key(session_id: str) -> int:
    """Ring key of a session (shared by router, placement and cluster)."""
    return hash_id(f"session/{session_id}")


def _decode_bucket(active: int, slots: int) -> int:
    """Pad an active-slot count to the next power of two (capped at the
    slot count): decode batches only ever take log2(slots)+1 distinct
    shapes, so churn in the number of live sessions can never trigger a
    fresh trace per count."""
    b = 1
    while b < active:
        b *= 2
    return min(b, slots)


@lru_cache(maxsize=32)
def _jitted(model: Model) -> Tuple:
    """One jitted (prefill, decode_slots) pair per Model value, shared by
    every replica of that model — a migrated-to replica reuses the
    donor's compiled executables instead of re-tracing (Model is a
    frozen dataclass, so value-equal models hit the same cache line).

    ``decode_slots`` is the bucketized decode round: it gathers the
    (padded) active-slot rows out of the full slab, steps ONLY those
    rows through the model, and scatters the fresh KV back — so round
    cost scales with the active bucket, not the slab width, and the
    out-of-range padding index is dropped on the way back (padded rows
    never corrupt the slab).  ``decode_full`` is the full-house variant
    (bucket == slab width): the gather would be the identity, so it
    steps the slab in place and skips the scatter copy.

    The ``*_fused`` variants additionally run the device-resident
    bucketized ring lookup on the batch's session keys INSIDE the same
    program (the inner jitted wrapper inlines): one decode round =
    route + gather + decode in a single dispatch, returning the
    (hi, lo) owner words next to the tokens.  ``prefill_chunk`` is the
    fixed-shape continuation prefill segment (chunked prefill — every
    chunk of every admit shares one trace), or None for families
    without a chunk path.

    Every decode variant returns the (B,) int32 GREEDY TOKENS, not the
    (B, V) logits: the argmax rides inside the compiled program, so the
    per-round host transfer is B int32 words instead of a full f32
    logits slab (repro-lint RL003 — the readback was the decode loop's
    hidden host sync).  Tensor-parallel groups keep returning logits
    from ``TPReplicaGroup.fns`` (the head stays vocab-sharded there, so
    the argmax needs the global array — see ``decode_round``)."""
    prefill = jax.jit(model.prefill)

    def _pick(logits):
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _index(lengths):
        # per-slot cache positions for transformer families; lockstep
        # max-length for the rest (inactive/padding rows are length 0,
        # so they never raise the lockstep position)
        return lengths if model.supports_per_slot_decode \
            else jnp.max(lengths)

    @jax.jit
    def decode_full(params, cache, tokens, lengths):
        logits, new_cache = model.decode_step(params, cache, tokens,
                                              _index(lengths))
        return _pick(logits), new_cache

    @jax.jit
    def decode_slots(params, cache, tokens, lengths, idx):
        sub = jax.tree.map(
            lambda c: jnp.take(c, idx, axis=1, mode="fill", fill_value=0),
            cache)
        tok = jnp.take(tokens, idx, axis=0, mode="fill", fill_value=0)
        ln = jnp.take(lengths, idx, axis=0, mode="fill", fill_value=0)
        logits, new_sub = model.decode_step(params, sub, tok, _index(ln))
        out_cache = jax.tree.map(
            lambda c, s: c.at[:, idx].set(s, mode="drop"), cache, new_sub)
        return _pick(logits), out_cache

    from repro.kernels.ring_lookup.ops import ring_lookup_bucketed

    @jax.jit
    def decode_full_fused(params, cache, tokens, lengths,
                          khi, klo, bhi, blo, occ):
        ohi, olo = ring_lookup_bucketed(khi, klo, bhi, blo, occ)
        logits, new_cache = model.decode_step(params, cache, tokens,
                                              _index(lengths))
        return _pick(logits), new_cache, ohi, olo

    @jax.jit
    def decode_slots_fused(params, cache, tokens, lengths, idx,
                           khi, klo, bhi, blo, occ):
        qhi = jnp.take(khi, idx, axis=0, mode="fill", fill_value=0)
        qlo = jnp.take(klo, idx, axis=0, mode="fill", fill_value=0)
        ohi, olo = ring_lookup_bucketed(qhi, qlo, bhi, blo, occ)
        sub = jax.tree.map(
            lambda c: jnp.take(c, idx, axis=1, mode="fill", fill_value=0),
            cache)
        tok = jnp.take(tokens, idx, axis=0, mode="fill", fill_value=0)
        ln = jnp.take(lengths, idx, axis=0, mode="fill", fill_value=0)
        logits, new_sub = model.decode_step(params, sub, tok, _index(ln))
        out_cache = jax.tree.map(
            lambda c, s: c.at[:, idx].set(s, mode="drop"), cache, new_sub)
        return _pick(logits), out_cache, ohi, olo

    prefill_chunk = jax.jit(model.prefill_chunk) \
        if model.supports_chunked_prefill else None

    return (prefill, decode_full, decode_slots,
            decode_full_fused, decode_slots_fused, prefill_chunk)


class Replica:
    """One serving replica: a vectorized slab of continuous-batching
    decode slots.

    Slot bookkeeping is flat per-slot arrays (``lengths``, ``tokens``,
    ``active``) plus an O(1) free-list — no dict scans (the old admit
    path re-scanned ``sessions.values()`` per admission: O(slots²)).
    ``decode_round`` compacts the active slots into a power-of-two
    bucketized batch and steps only those rows in a single jitted call,
    each at its own cache position (the gathered lengths are the
    per-row cache index, so each slot writes its fresh KV at its own
    length and masks attention there).  The old engine stepped the full
    slab every round — a single straggler session cost as much as a
    full house — and each distinct decode shape risked a fresh trace.
    """

    def __init__(self, model: Model, *, slots: int, max_len: int,
                 generation: int = 0, prefill_chunk: Optional[int] = None,
                 prefix_cache=None, group=None):
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.generation = generation     # membership generation at creation
        # tensor-parallel replica group (models.tp.TPReplicaGroup) or
        # None for a single-device replica.  With a group, the compiled
        # programs come from the group (shard_map over its sub-mesh), the
        # cache is kv_heads-sharded across its devices, and the fused
        # route→decode variants are skipped (the ring lookup stays
        # host-side for groups).
        self.group = group
        # content-addressed cross-session prompt-prefix cache
        # (repro.dht.data.PrefixCache or None): chunked prefills consult
        # it before computing a chunk and insert what they computed
        self.prefix_cache = prefix_cache \
            if model.supports_kv_blocks else None
        # wall time the last admit_from_blocks spent importing blocks
        # (the cluster splits handoff-transfer from re-prefill with it)
        self.import_us = 0.0
        self.cache = self._init_cache(slots, max_len)
        self.lengths = np.zeros((slots,), np.int32)
        self.tokens = np.zeros((slots, 1), np.int32)
        self.active = np.zeros((slots,), bool)
        # per-slot session ring-key words for the fused route→decode round
        self.key_hi = np.zeros((slots,), np.uint32)
        self.key_lo = np.zeros((slots,), np.uint32)
        self.sessions: Dict[str, int] = {}
        self._free = list(range(slots - 1, -1, -1))   # pop() -> slot 0 first
        # chunked prefill: fixed segment length (None = whole-prompt
        # prefill; ignored for families without a chunk path)
        self.prefill_chunk = prefill_chunk \
            if model.supports_chunked_prefill else None
        # in-flight overlapped prefills: sid -> progress state (slot is
        # reserved but the session is NOT in ``sessions`` until complete,
        # so decode_round never sees a half-filled slot)
        self._pending: Dict[str, dict] = {}
        # owners resolved by the last *fused* decode round: sid -> uint64
        self.routed_owners: Dict[str, int] = {}
        # sids whose overlapped prefill failed (slot already released)
        self.failed_prefills: List[str] = []
        if group is not None:
            (self._prefill, self._decode_full, self._decode_slots,
             self._prefill_chunk) = group.fns()
            self._decode_full_fused = self._decode_slots_fused = None
        else:
            (self._prefill, self._decode_full, self._decode_slots,
             self._decode_full_fused, self._decode_slots_fused,
             self._prefill_chunk) = _jitted(model)

    # -- group-aware cache plumbing (identity for single-device replicas) --
    def _init_cache(self, batch: int, max_len: int):
        if self.group is not None:
            return self.group.init_cache(batch, max_len)
        return self.model.init_cache(batch, max_len)

    def _cache_with_blocks(self, blocks):
        if self.group is not None:
            return self.group.cache_with_blocks(self.max_len, blocks)
        return self.model.cache_with_blocks(self.max_len, blocks)

    def _export_kv_block(self, cache, row: int, off: int, chunk: int):
        if self.group is not None:
            return self.group.export_kv_block(cache, row, off, chunk)
        return self.model.export_kv_block(cache, row, off, chunk)

    @property
    def num_active(self) -> int:
        return len(self.sessions)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def attach_params(self, params) -> None:
        self.params = params

    def admit(self, req: Request) -> int:
        """Prefill a prompt into a free slot (single-sequence batch into a
        fresh slot-shaped cache, then written back slot-granular) and
        return the first generated token.

        Any prefill failure (bad tokens, OOM, a kernel error) rolls the
        slot allocation back: the session entry and the free-list slot
        used to be committed BEFORE prefill ran, so a failed admit left a
        phantom session with ``active=False`` and the next
        ``decode_round`` raised KeyError for every caller."""
        s = len(req.prompt)
        if s >= self.max_len:   # validate BEFORE allocating: a rejected
            # admit must not leak the slot or leave a phantom session
            raise ValueError(f"prompt of {s} tokens >= max_len {self.max_len}")
        fresh = False
        if req.session_id in self.sessions:
            slot = self.sessions[req.session_id]
        elif self._free:
            slot = self._free.pop()
            self.sessions[req.session_id] = slot
            fresh = True
        else:
            raise RuntimeError("replica full")
        try:
            one = self._init_cache(1, self.max_len)
            if self._chunkable(s):
                # fixed-shape chunk loop: every admit of every length
                # reuses ONE compiled segment program (whole-prompt
                # prefill retraces per distinct prompt length — the bulk
                # of the measured per-session migration cost)
                tok, one = self._run_chunks(req.prompt, one)
            else:
                batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
                logits, one = self._prefill(self.params, batch, one)
                tok = int(jnp.argmax(logits[0]))
            # the commit stays inside the try: with async dispatch a
            # device-side prefill failure (OOM, kernel error) surfaces
            # only HERE, when the result is first materialized
            self._write_slot(one, slot)
            self._commit_slot(req.session_id, slot, s, tok)
        except BaseException:
            if fresh:
                del self.sessions[req.session_id]
                self._free.append(slot)
                self.active[slot] = False
                self.lengths[slot] = 0
                self.tokens[slot, 0] = 0
            raise
        return tok

    # -- chunked / overlapped prefill ---------------------------------------
    def _chunkable(self, s: int) -> bool:
        """Chunk the prefill iff a chunk size is configured, the model
        has a continuation path, and the padded prompt fits the cache."""
        c = self.prefill_chunk
        return bool(c) and self._prefill_chunk is not None \
            and (s + c - 1) // c * c <= self.max_len

    def _run_chunks(self, prompt: np.ndarray, one, *,
                    start: int = 0) -> Tuple[int, object]:
        """Drive the fixed-shape segment program over a prompt from cache
        position ``start`` (0 = whole prompt; > 0 continues over a cache
        whose first ``start`` positions were imported from KV blocks);
        returns (first generated token, filled 1-row cache).

        With a prefix cache attached and ``start == 0``, the longest
        cached token-prefix is imported instead of computed — a hit on a
        shared system prompt skips those chunks' prefill FLOPs entirely
        — and every freshly computed full chunk is offered back."""
        c = self.prefill_chunk
        s = len(prompt)
        if start == 0 and self.prefix_cache is not None:
            covered, blocks = self.prefix_cache.match(prompt)
            if covered:
                # replace the caller's zero cache with one assembled
                # host-side around the imported run (a dispatched set per
                # block would cost as much as recomputing the chunk)
                one = self._cache_with_blocks(blocks)
                start = covered
        padded = (s + c - 1) // c * c
        buf = np.zeros(padded, np.int32)
        buf[:s] = prompt
        logits = None
        for off in range(start, padded, c):
            seg = jnp.asarray(buf[off:off + c], jnp.int32)[None, :]
            logits, one = self._prefill_chunk(self.params, seg, one, off)
            if self.prefix_cache is not None and off + c <= s:
                self.prefix_cache.insert(
                    prompt, off, self._export_kv_block(one, 0, off, c))
        # the prompt's last real token sits at column (s-1) - (padded-c)
        # of the final (right-padded) segment's all-position logits
        tok = int(jnp.argmax(logits[0, (s - 1) - (padded - c)]))
        return tok, one

    def admit_from_blocks(self, req: Request, blocks) -> int:
        """Admit from imported KV blocks: cache positions
        [0, len(blocks)*chunk) come off the wire (a replica-set fetch),
        only the remaining tail of the prompt is re-prefilled.  The
        blocks are bit-identical to what this replica would have
        computed, so the returned token — and every decode after it —
        matches a from-scratch admit exactly.  Degrades to ``admit``
        when no blocks are given; the same rollback discipline applies
        (a failed import or tail prefill leaks no slot)."""
        if not blocks:
            return self.admit(req)
        s = len(req.prompt)
        c = self.prefill_chunk
        if not self._chunkable(s):
            raise ValueError("prompt not chunkable on this replica")
        covered = len(blocks) * c
        if covered > max(((s - 1) // c) * c, 0):
            raise ValueError("blocks cover the final segment: the tail "
                             "must be recomputed to produce logits")
        if s >= self.max_len:
            raise ValueError(f"prompt of {s} tokens >= max_len {self.max_len}")
        fresh = False
        if req.session_id in self.sessions:
            slot = self.sessions[req.session_id]
        elif self._free:
            slot = self._free.pop()
            self.sessions[req.session_id] = slot
            fresh = True
        else:
            raise RuntimeError("replica full")
        try:
            t0 = time.perf_counter_ns()
            one = self._cache_with_blocks(blocks)
            jax.block_until_ready(jax.tree.leaves(one)[0])
            self.import_us = (time.perf_counter_ns() - t0) / 1e3
            tok, one = self._run_chunks(req.prompt, one, start=covered)
            self._write_slot(one, slot)
            self._commit_slot(req.session_id, slot, s, tok)
        except BaseException:
            if fresh:
                del self.sessions[req.session_id]
                self._free.append(slot)
                self.active[slot] = False
                self.lengths[slot] = 0
                self.tokens[slot, 0] = 0
            raise
        return tok

    def export_block(self, session_id: str, j: int) -> np.ndarray:
        """Chunk ``j`` of the session's live cache as a host slab
        (positions [j*chunk, (j+1)*chunk) — the caller guarantees the
        session's length has crossed that boundary)."""
        slot = self.sessions[session_id]
        c = self.prefill_chunk
        return self._export_kv_block(self.cache, slot, j * c, c)

    def export_block_shards(self, session_id: str, j: int) -> List[np.ndarray]:
        """Chunk ``j`` as per-shard slabs — shard s is the kv_heads slice
        device s of the replica group holds (a 1-element list for
        single-device replicas), each independently storable so a group
        export moves every device's slice without first gathering the
        full slab onto one device."""
        slot = self.sessions[session_id]
        c = self.prefill_chunk
        if self.group is not None:
            return self.group.export_kv_shards(self.cache, slot, j * c, c)
        return [self.model.export_kv_block(self.cache, slot, j * c, c)]

    def _commit_slot(self, session_id: str, slot: int, s: int,
                     tok: int) -> None:
        key = np.uint64(session_key(session_id))
        self.key_hi[slot] = np.uint32(key >> np.uint64(32))
        self.key_lo[slot] = np.uint32(key & np.uint64(0xFFFFFFFF))
        self.lengths[slot] = s
        self.tokens[slot, 0] = tok
        self.active[slot] = True

    def begin_admit(self, req: Request) -> Optional[int]:
        """Start an admit that overlaps with decode rounds.

        When the prompt is chunkable the slot is reserved, the prefill
        state parked in ``_pending``, and None is returned — subsequent
        ``decode_round`` calls advance it one fixed-shape chunk at a
        time (``advance_prefills``) until the first token materializes.
        Otherwise this degrades to the synchronous ``admit`` and returns
        its first token directly.  The session enters ``sessions`` only
        on completion, so a half-filled slot is never decoded and a
        chunk failure cannot leave a phantom session."""
        s = len(req.prompt)
        if not self._chunkable(s):
            return self.admit(req)
        if req.session_id in self.sessions or req.session_id in self._pending:
            raise RuntimeError(f"session {req.session_id} already resident")
        if s >= self.max_len:
            raise ValueError(f"prompt of {s} tokens >= max_len {self.max_len}")
        if not self._free:
            raise RuntimeError("replica full")
        slot = self._free.pop()
        c = self.prefill_chunk
        padded = (s + c - 1) // c * c
        buf = np.zeros(padded, np.int32)
        buf[:s] = np.asarray(req.prompt, np.int32)
        st = {
            "slot": slot, "cache": None,
            "prompt": buf, "s": s, "off": 0, "logits": None,
        }
        if self.prefix_cache is not None:
            # overlapped admits hit the cross-session prefix cache too:
            # imported chunks are chunks the duty-cycle never has to
            # advance (inserts stay on the synchronous path only)
            covered, blocks = self.prefix_cache.match(
                np.asarray(req.prompt, np.int32))
            if covered:
                st["cache"] = self._cache_with_blocks(blocks)
                st["off"] = covered
        if st["cache"] is None:
            st["cache"] = self._init_cache(1, self.max_len)
        self._pending[req.session_id] = st
        return None

    @property
    def num_pending(self) -> int:
        return len(self._pending)

    def advance_prefills(self, chunks: int = 1) -> Dict[str, int]:
        """Advance every in-flight overlapped prefill by up to ``chunks``
        fixed-shape segments; returns {sid: first token} for the ones
        that completed.  A failed chunk releases the reserved slot,
        drops the pending state, and records the sid in
        ``failed_prefills`` (instead of raising, so one bad session
        can't discard siblings' completions mid-loop) — the cluster
        re-strands failed sessions for a later re-home."""
        done: Dict[str, int] = {}
        for sid in list(self._pending):
            st = self._pending[sid]
            try:
                c = self.prefill_chunk
                for _ in range(chunks):
                    off = st["off"]
                    seg = jnp.asarray(st["prompt"][off:off + c],
                                      jnp.int32)[None, :]
                    st["logits"], st["cache"] = self._prefill_chunk(
                        self.params, seg, st["cache"], off)
                    st["off"] = off + c
                    if st["off"] >= len(st["prompt"]):
                        break
                if st["off"] < len(st["prompt"]):
                    continue
                padded, s, slot = len(st["prompt"]), st["s"], st["slot"]
                tok = int(jnp.argmax(
                    st["logits"][0, (s - 1) - (padded - c)]))
                self._write_slot(st["cache"], slot)
                self.sessions[sid] = slot
                self._commit_slot(sid, slot, s, tok)
                del self._pending[sid]
                done[sid] = tok
            except Exception:
                slot = st["slot"]
                del self._pending[sid]
                self._free.append(slot)
                self.active[slot] = False
                self.lengths[slot] = 0
                self.tokens[slot, 0] = 0
                self.failed_prefills.append(sid)
        return done

    def _write_slot(self, one_cache, slot: int) -> None:
        def wr(dst, src):
            return dst.at[:, slot:slot + 1].set(src) if dst.ndim >= 2 else dst
        self.cache = jax.tree.map(wr, self.cache, one_cache)

    def decode_round(self, route=None) -> Dict[str, int]:
        """One decode step for all active sessions — each at its own
        cache position.  The active slots are compacted into a batch
        padded to a power-of-two bucket (see ``_decode_bucket``): decode
        work scales with the live session count, and the jit only ever
        sees log2(slots)+1 batch shapes, so admitting or evicting a
        session never costs a recompile.  Padding rows carry an
        out-of-range index: gathers fill them with zeros and the KV
        scatter drops them.

        ``route`` is the device bucket directory (bkt_hi, bkt_lo, occ)
        from ``RingState.device_bucket_table``: when given, the round
        runs the FUSED program — the bucketized owner lookup on the
        batch's session keys rides inside the same dispatch as the
        gather + decode, and the resolved owners land in
        ``routed_owners`` (sid -> uint64 peer id) for the cluster's
        ownership accounting.  One device program per round either way.
        """
        self.routed_owners = {}
        if self.group is not None:
            route = None     # fused ring lookup stays host-side for groups
        if not self.sessions:
            return {}
        act_idx = np.nonzero(self.active)[0].astype(np.int32)
        bucket = _decode_bucket(act_idx.size, self.slots)
        ohi = olo = None
        if bucket == self.slots:
            # full house: the gather would be the identity permutation —
            # step the slab directly and skip the scatter-back copy
            # (inactive rows decode garbage at position 0, as the slab
            # engine always did; admit rewrites the whole slot anyway)
            if route is not None:
                out, self.cache, ohi, olo = self._decode_full_fused(
                    self.params, self.cache, jnp.asarray(self.tokens),
                    jnp.asarray(self.lengths), jnp.asarray(self.key_hi),
                    jnp.asarray(self.key_lo), *route)
            else:
                out, self.cache = self._decode_full(
                    self.params, self.cache, jnp.asarray(self.tokens),
                    jnp.asarray(self.lengths))
            rows = act_idx
        else:
            idx = np.full(bucket, self.slots, np.int32)  # slots = OOB pad
            idx[:act_idx.size] = act_idx
            if route is not None:
                out, self.cache, ohi, olo = self._decode_slots_fused(
                    self.params, self.cache, jnp.asarray(self.tokens),
                    jnp.asarray(self.lengths), jnp.asarray(idx),
                    jnp.asarray(self.key_hi), jnp.asarray(self.key_lo),
                    *route)
            else:
                out, self.cache = self._decode_slots(
                    self.params, self.cache, jnp.asarray(self.tokens),
                    jnp.asarray(self.lengths), jnp.asarray(idx))
            rows = np.arange(act_idx.size)
        if self.group is not None:
            # TP groups return vocab-SHARDED logits (models/tp.py keeps
            # the head shard-local): the greedy pick needs the global
            # array, so it runs here instead of inside the group program
            out = jnp.argmax(out, axis=-1).astype(jnp.int32)
        row_of = {int(s): int(r) for s, r in zip(act_idx, rows)}
        # the round's ONE mandatory device->host transfer: B int32
        # tokens (plus the fused path's owner words) in a single
        # device_get — logits never cross the host boundary
        if ohi is not None:
            # repro-lint: allow(RL003) the one mandatory per-round transfer
            nxt, hi, lo = jax.device_get((out, ohi, olo))
            owners = (hi.astype(np.uint64) << np.uint64(32)) \
                | lo.astype(np.uint64)
            self.routed_owners = {sid: int(owners[row_of[slot]])
                                  for sid, slot in self.sessions.items()}
        else:
            # repro-lint: allow(RL003) the one mandatory per-round transfer
            nxt = jax.device_get(out)
        nxt = nxt.astype(np.int32, copy=False)
        self.tokens[act_idx, 0] = nxt[rows]
        self.lengths[act_idx] += 1
        return {sid: int(nxt[row_of[slot]])
                for sid, slot in self.sessions.items()}

    def evict(self, session_id: str) -> None:
        """Free the session's slot and zero its row — stale lengths used
        to survive eviction and (under the old global-max decode index)
        inflated every remaining session's decode position."""
        slot = self.sessions.pop(session_id, None)
        if slot is None:
            pend = self._pending.pop(session_id, None)
            if pend is not None:           # abandon an in-flight prefill
                self._free.append(pend["slot"])
            return
        self.active[slot] = False
        self.lengths[slot] = 0
        self.tokens[slot, 0] = 0
        self.key_hi[slot] = 0
        self.key_lo[slot] = 0
        self._free.append(slot)
