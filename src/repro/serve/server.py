"""Batched serving with D1HT session routing.

Requests carry a session id; the D1HT ring (full routing table, single
local lookup) decides which serving replica owns the session's KV cache.
The Pallas ``ring_lookup`` kernel resolves whole request batches
on-device.  Each replica runs continuous batched decode over its slots.

Quarantined replicas (spot nodes inside T_q) take no sessions but may
proxy requests — the paper's gateway mechanism (§V).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ring import hash_id
from repro.core.ringstate import RingState
from repro.models import Model
from repro.runtime import Membership, Placement


@dataclass
class Request:
    session_id: str
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16


class SessionRouter:
    """Batched session -> replica resolution over the ring.

    Routes from the Membership's shared ``RingState``: the sorted table
    lives on-device as capacity-padded uint32 (hi, lo) word pairs and is
    re-uploaded only when a membership event bumps the state version —
    never per request batch — and lookups compare full 64-bit IDs (the
    old path truncated to the top 32 bits, which collides at scale).
    """

    def __init__(self, membership: Membership):
        self.membership = membership
        self.state: RingState = membership.ring_state
        self.events_observed = 0
        membership.subscribe(self._on_event)

    def _on_event(self, ev) -> None:
        # The device table refreshes lazily via the state version; the
        # subscription just tracks churn for observability.
        self.events_observed += 1

    @property
    def uploads(self) -> int:
        """Device-table uploads so far (1 per membership version actually
        routed against — asserted by the serve acceptance test)."""
        return self.state.upload_count

    def route(self, session_ids: List[str]) -> List[int]:
        keys = np.fromiter(
            (hash_id(f"session/{s}") for s in session_ids),
            np.uint64, len(session_ids))
        return [int(p) for p in self.state.lookup(keys)]


class Replica:
    """One serving replica: slab of decode slots + jitted prefill/decode."""

    def __init__(self, model: Model, *, slots: int, max_len: int):
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len)
        self.lengths = np.zeros((slots,), np.int32)
        self.sessions: Dict[str, int] = {}
        self.tokens = np.zeros((slots, 1), np.int32)
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def _slot_for(self, session_id: str) -> int:
        if session_id in self.sessions:
            return self.sessions[session_id]
        free = [i for i in range(self.slots)
                if i not in self.sessions.values()]
        if not free:
            raise RuntimeError("replica full")
        self.sessions[session_id] = free[0]
        return free[0]

    def attach_params(self, params) -> None:
        self.params = params

    def admit(self, req: Request) -> int:
        """Prefill a prompt into the session's slot (single-sequence batch
        into a fresh slot-shaped cache, then written back slot-granular)."""
        slot = self._slot_for(req.session_id)
        s = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
        one = self.model.init_cache(1, self.max_len)
        logits, one = self._prefill(self.params, batch, one)
        self._write_slot(one, slot)
        self.lengths[slot] = s
        tok = int(jnp.argmax(logits[0]))
        self.tokens[slot, 0] = tok
        return tok

    def _write_slot(self, one_cache, slot: int) -> None:
        def wr(dst, src):
            return dst.at[:, slot:slot + 1].set(src) if dst.ndim >= 2 else dst
        self.cache = jax.tree.map(wr, self.cache, one_cache)

    def decode_round(self) -> Dict[str, int]:
        """One synchronized decode step for all active sessions."""
        if not self.sessions:
            return {}
        idx = int(self.lengths.max())
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(idx, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        out = {}
        for sid, slot in self.sessions.items():
            self.tokens[slot, 0] = nxt[slot]
            self.lengths[slot] += 1
            out[sid] = int(nxt[slot])
        return out

    def evict(self, session_id: str) -> None:
        self.sessions.pop(session_id, None)
