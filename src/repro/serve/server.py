"""Batched serving with D1HT session routing.

Requests carry a session id; the D1HT ring (full routing table, single
local lookup) decides which serving replica owns the session's KV cache.
The Pallas ``ring_lookup`` kernel resolves whole request batches
on-device.  Each replica runs continuous batched decode over its slots:
slot state lives in flat per-slot arrays and every active slot decodes at
its OWN cache position in one jitted call (per-slot lengths flow through
``decode_attention``'s masking), so mixed-length sessions never attend
past their real length and a long session never gates short ones.

Quarantined replicas (spot nodes inside T_q) take no sessions but may
proxy requests — the paper's gateway mechanism (§V); see
``repro.serve.cluster.ServeCluster`` for the churn-aware orchestration
(migration on leave/quarantine, generation-driven restarts).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ring import hash_id
from repro.core.ringstate import RingState
from repro.models import Model
from repro.runtime import Membership


@dataclass
class Request:
    session_id: str
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16


class SessionRouter:
    """Batched session -> replica resolution over the ring.

    Routes from the Membership's shared ``RingState``: the sorted table
    lives on-device as capacity-padded uint32 (hi, lo) word pairs and is
    re-uploaded only when a membership event bumps the state version —
    never per request batch — and lookups compare full 64-bit IDs (the
    old path truncated to the top 32 bits, which collides at scale).
    """

    def __init__(self, membership: Membership):
        self.membership = membership
        # no event subscription needed: the device table refreshes
        # lazily off the shared state's version
        self.state: RingState = membership.ring_state

    @property
    def uploads(self) -> int:
        """Device-table uploads so far (1 per membership version actually
        routed against — asserted by the serve acceptance test)."""
        return self.state.upload_count

    def route(self, session_ids: List[str]) -> List[int]:
        keys = np.fromiter(
            (session_key(s) for s in session_ids),
            np.uint64, len(session_ids))
        return [int(p) for p in self.state.lookup(keys)]


def session_key(session_id: str) -> int:
    """Ring key of a session (shared by router, placement and cluster)."""
    return hash_id(f"session/{session_id}")


@lru_cache(maxsize=32)
def _jitted(model: Model) -> Tuple:
    """One jitted (prefill, decode) pair per Model value, shared by every
    replica of that model — a migrated-to replica reuses the donor's
    compiled executables instead of re-tracing (Model is a frozen
    dataclass, so value-equal models hit the same cache line)."""
    return jax.jit(model.prefill), jax.jit(model.decode_step)


class Replica:
    """One serving replica: a vectorized slab of continuous-batching
    decode slots.

    Slot bookkeeping is flat per-slot arrays (``lengths``, ``tokens``,
    ``active``) plus an O(1) free-list — no dict scans (the old admit
    path re-scanned ``sessions.values()`` per admission: O(slots²)).
    ``decode_round`` steps EVERY active slot at its own cache position in
    a single jitted call: the (slots,) lengths array is the per-row cache
    index, so each slot writes its fresh KV at its own length and masks
    attention there (the old engine stepped everyone at ``lengths.max()``
    and shorter sessions attended garbage).
    """

    def __init__(self, model: Model, *, slots: int, max_len: int,
                 generation: int = 0):
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.generation = generation     # membership generation at creation
        self.cache = model.init_cache(slots, max_len)
        self.lengths = np.zeros((slots,), np.int32)
        self.tokens = np.zeros((slots, 1), np.int32)
        self.active = np.zeros((slots,), bool)
        self.sessions: Dict[str, int] = {}
        self._free = list(range(slots - 1, -1, -1))   # pop() -> slot 0 first
        self._prefill, self._decode = _jitted(model)

    @property
    def num_active(self) -> int:
        return len(self.sessions)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def attach_params(self, params) -> None:
        self.params = params

    def admit(self, req: Request) -> int:
        """Prefill a prompt into a free slot (single-sequence batch into a
        fresh slot-shaped cache, then written back slot-granular) and
        return the first generated token."""
        s = len(req.prompt)
        if s >= self.max_len:   # validate BEFORE allocating: a rejected
            # admit must not leak the slot or leave a phantom session
            raise ValueError(f"prompt of {s} tokens >= max_len {self.max_len}")
        if req.session_id in self.sessions:
            slot = self.sessions[req.session_id]
        elif self._free:
            slot = self._free.pop()
            self.sessions[req.session_id] = slot
        else:
            raise RuntimeError("replica full")
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
        one = self.model.init_cache(1, self.max_len)
        logits, one = self._prefill(self.params, batch, one)
        self._write_slot(one, slot)
        self.lengths[slot] = s
        tok = int(jnp.argmax(logits[0]))
        self.tokens[slot, 0] = tok
        self.active[slot] = True
        return tok

    def _write_slot(self, one_cache, slot: int) -> None:
        def wr(dst, src):
            return dst.at[:, slot:slot + 1].set(src) if dst.ndim >= 2 else dst
        self.cache = jax.tree.map(wr, self.cache, one_cache)

    def decode_round(self) -> Dict[str, int]:
        """One decode step for all active sessions — each at its own
        cache position (the (slots,) lengths array IS the index).
        Families without per-slot index support (SSM/hybrid/enc-dec)
        fall back to lockstep at the max active length."""
        if not self.sessions:
            return {}
        if self.model.supports_per_slot_decode:
            index = jnp.asarray(self.lengths)
        else:
            index = jnp.asarray(int(self.lengths[self.active].max()),
                                jnp.int32)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.tokens), index)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        act = self.active
        self.tokens[act, 0] = nxt[act]
        self.lengths[act] += 1
        return {sid: int(nxt[slot]) for sid, slot in self.sessions.items()}

    def evict(self, session_id: str) -> None:
        """Free the session's slot and zero its row — stale lengths used
        to survive eviction and (under the old global-max decode index)
        inflated every remaining session's decode position."""
        slot = self.sessions.pop(session_id, None)
        if slot is None:
            return
        self.active[slot] = False
        self.lengths[slot] = 0
        self.tokens[slot, 0] = 0
        self._free.append(slot)
