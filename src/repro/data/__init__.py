from .pipeline import DataConfig, Prefetcher, SyntheticLM, make_pipeline
__all__ = ["DataConfig", "Prefetcher", "SyntheticLM", "make_pipeline"]
