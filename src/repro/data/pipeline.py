"""Deterministic synthetic token pipeline (host-sharded, prefetchable).

Real deployments plug a file-backed reader into the same iterator
contract; for the reproduction the stream is a seeded Zipf-mixture
language so that training loss has structure to learn (unigram skew +
bigram dependency), which the train examples exploit.
"""
from __future__ import annotations

import threading
import queue as queue_mod
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    bigram_strength: float = 0.7
    host_index: int = 0
    host_count: int = 1


class SyntheticLM:
    """Zipf unigram with a deterministic bigram transition overlay."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.host_count:
            raise ValueError("global_batch must divide across hosts")
        self.local_batch = cfg.global_batch // cfg.host_count
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self.unigram = p / p.sum()
        # each token deterministically prefers a successor
        self.next_tok = rng.permutation(cfg.vocab)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_index, 0xD1147))
        b, s = self.local_batch, cfg.seq_len
        base = rng.choice(cfg.vocab, size=(b, s + 1), p=self.unigram)
        follow = rng.random((b, s + 1)) < cfg.bigram_strength
        toks = base.copy()
        for t in range(1, s + 1):
            toks[:, t] = np.where(follow[:, t],
                                  self.next_tok[toks[:, t - 1]], base[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-N) over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: queue_mod.Queue = queue_mod.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def make_pipeline(model_cfg: ModelConfig, shape: ShapeConfig, *,
                  seed: int = 0, host_index: int = 0, host_count: int = 1,
                  prefetch: int = 2):
    dc = DataConfig(vocab=model_cfg.vocab, seq_len=shape.seq_len,
                    global_batch=shape.global_batch, seed=seed,
                    host_index=host_index, host_count=host_count)
    return Prefetcher(iter(SyntheticLM(dc)), depth=prefetch)
