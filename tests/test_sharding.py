"""Logical sharding rules + EDRA collectives (subprocess for multi-device)."""
import subprocess
import sys

import pytest

from repro.sharding import specs as sh


def test_logical_spec_dedups_axes():
    sh.set_mesh(None)
    sh._STATE.rules = dict(sh.DEFAULT_RULES)
    spec = sh.logical_spec("batch", "seq", "heads")
    # no mesh axis may appear twice in one spec
    flat = []
    for e in spec:
        if e is None:
            continue
        flat.extend([e] if isinstance(e, str) else list(e))
    assert len(flat) == len(set(flat))


def test_rules_filtered_to_mesh_axes():
    sh.set_mesh(None, {"batch": ("pod", "data")})
    assert sh._STATE.rules["batch"] == ("pod", "data")
    sh.set_mesh(None)


def test_shard_noop_without_mesh():
    import jax.numpy as jnp
    sh.set_mesh(None)
    x = jnp.ones((4, 4))
    assert sh.shard(x, "batch", None) is x


def test_unknown_logical_axis_raises():
    """A typo in a spec tuple must fail loudly, not silently resolve to
    'replicated' and de-shard the tensor on every mesh."""
    sh.set_mesh(None)
    sh._STATE.rules = dict(sh.DEFAULT_RULES)
    with pytest.raises(KeyError, match="unknown logical axis"):
        sh.logical_spec("batch", "headz")
    with pytest.raises(KeyError, match="unknown logical axis"):
        sh.logical_spec("vocabs")
    assert sh.logical_spec("batch", None, "heads") is not None


def test_shard_noop_inside_tp_context():
    """Inside a TP shard_map body every array is already a per-device
    shard; a GSPMD constraint there would be ill-typed."""
    import jax
    import jax.numpy as jnp
    mesh = jax.make_mesh((1,), ("model",))
    x = jnp.ones((4, 4))
    try:
        sh.set_mesh(mesh)
        with sh.tp_context("model"):
            assert sh.shard(x, "heads", None) is x
    finally:
        sh.set_mesh(None)


RULES_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from jax.sharding import NamedSharding
from repro.launch.mesh import make_production_mesh
from repro.sharding import specs as sh

for multi_pod in (False, True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    with sh.mesh_context(mesh):
        for axis in sh.DEFAULT_RULES:
            spec = sh.logical_spec(axis)
            # every resolved mesh axis must exist on THIS mesh (and the
            # sharding must construct — NamedSharding validates names)
            for e in spec:
                for a in ([e] if isinstance(e, str) else list(e or ())):
                    assert a in mesh.axis_names, (multi_pod, axis, a)
            NamedSharding(mesh, spec)
        # absent mesh axes are filtered, present ones kept
        batch = sh.logical_spec("batch")
        assert batch[0] == (("pod", "data") if multi_pod else "data"), batch
print("RULES_OK")
"""


@pytest.mark.slow
def test_default_rules_resolve_on_production_meshes():
    """Every DEFAULT_RULES logical axis resolves to a valid PartitionSpec
    under both production meshes (single-pod 16x16 and multi-pod
    2x16x16), with absent axes ('pod' on single-pod) filtered out."""
    out = subprocess.run(
        [sys.executable, "-c", RULES_SCRIPT],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "RULES_OK" in out.stdout, out.stderr[-2000:]


COLLECTIVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.sharding.collectives import (edra_allgather, edra_broadcast,
                                        edra_allreduce, shard_map_compat)
mesh = jax.make_mesh((8,), ("d",))
x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
ag = shard_map_compat(partial(edra_allgather, axis_name="d"), mesh,
                      in_specs=P("d", None), out_specs=P("d", None, None))
got = np.asarray(ag(x)).reshape(8, 8, 4)
for i in range(8):
    assert (got[i].squeeze() == np.asarray(x)).all()
for src in (0, 3, 7):
    bc = shard_map_compat(partial(edra_broadcast, axis_name="d", source=src),
                          mesh, in_specs=P("d", None),
                          out_specs=P("d", None))
    got = np.asarray(bc(x))
    assert (got == np.tile(np.asarray(x)[src], (8, 1))).all()
ar = shard_map_compat(partial(edra_allreduce, axis_name="d"), mesh,
                      in_specs=P(None, None), out_specs=P(None, None))
y = jnp.arange(5 * 3, dtype=jnp.float32).reshape(5, 3)
assert np.allclose(np.asarray(ar(y)), np.asarray(y) * 8)
print("COLLECTIVES_OK")
"""


@pytest.mark.slow
def test_edra_collectives_8dev():
    out = subprocess.run(
        [sys.executable, "-c", COLLECTIVE_SCRIPT],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "COLLECTIVES_OK" in out.stdout, out.stderr[-2000:]


EDRA_GRADSYNC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.sharding.collectives import edra_allreduce, shard_map_compat

# data-parallel gradient sync via the paper's dissemination tree:
# per-shard grads -> reduce-scatter + EDRA-tree all-gather == psum
mesh = jax.make_mesh((8,), ("data",))
w = jnp.asarray(np.random.default_rng(0).standard_normal((16, 8)),
                jnp.float32)
x = jnp.asarray(np.random.default_rng(1).standard_normal((32, 16)),
                jnp.float32)
y = jnp.asarray(np.random.default_rng(2).standard_normal((32, 8)),
                jnp.float32)

def local_grad(w_, x_, y_):
    # per-shard loss grad (batch shard), then EDRA-tree sync
    g = jax.grad(lambda wt: jnp.mean((x_ @ wt - y_) ** 2))(w_)
    return edra_allreduce(g, "data") / 8.0

sm = shard_map_compat(local_grad, mesh,
                      in_specs=(P(None, None), P("data", None),
                                P("data", None)),
                      out_specs=P(None, None))
step = jax.jit(sm)
g_edra = np.asarray(step(w, x, y))
g_ref = np.asarray(jax.grad(lambda wt: jnp.mean((x @ wt - y) ** 2))(w))
assert np.allclose(g_edra, g_ref, atol=1e-5), np.abs(g_edra - g_ref).max()
# schedule check: the EDRA path lowers to ppermute rounds, not all-gather
hlo = jax.jit(sm).lower(w, x, y).compile().as_text()
assert "collective-permute" in hlo
print("EDRA_GRADSYNC_OK")
"""


@pytest.mark.slow
def test_edra_gradient_sync_equals_psum():
    """DP gradient sync through the paper's dissemination tree (DESIGN.md
    §2 level 2) matches the exact data-parallel gradient, and lowers to
    the ppermute recursive-doubling schedule."""
    out = subprocess.run(
        [sys.executable, "-c", EDRA_GRADSYNC_SCRIPT],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "EDRA_GRADSYNC_OK" in out.stdout, out.stderr[-2000:]
