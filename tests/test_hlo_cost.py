"""Loop-aware HLO cost analyzer vs closed-form FLOP counts."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_scan_flops_loop_scaled():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    r = analyze(_compile(f, x, ws))
    assert r["matmul_flops"] == 10 * 2 * 64**3


def test_nested_scan_flops():
    def g(x, ws):
        def outer(c, _):
            def body(cc, w):
                return cc @ w, None
            out, _ = jax.lax.scan(body, c, ws)
            return out, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    r = analyze(_compile(g, x, ws))
    assert r["matmul_flops"] == 50 * 2 * 64**3


def test_plain_matmul_flops():
    def h(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    r = analyze(_compile(h, a, b))
    assert r["matmul_flops"] == 2 * 128 * 256 * 64
    # boundary bytes at least operands+result
    assert r["hbm_bytes"] >= (128 * 256 + 256 * 64 + 128 * 64) * 4


def test_bytes_scale_with_trip_count():
    def f(x):
        def body(c, _):
            return jnp.tanh(c) * 2.0, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r7 = analyze(_compile(f, x))

    def f1(x):
        return jnp.tanh(x) * 2.0
    r1 = analyze(_compile(f1, x))
    assert r7["hbm_bytes"] >= 5 * max(r1["hbm_bytes"], 1)
