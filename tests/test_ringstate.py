"""RingState: delta-apply vs full-rebuild equivalence, version
monotonicity, quarantine masking, replica-set wrap-around, and the
64-bit device lookup path (kernel + facade oracle)."""
import numpy as np
import pytest

from repro.core.edra import Event
from repro.core.ring import RING_SIZE, RoutingTable, build_ring
from repro.core.ringstate import RingState

RNG = np.random.default_rng(7)


def _rand_ids(k):
    return [int(x) for x in RNG.integers(0, 2**64, size=k, dtype=np.uint64)]


def test_delta_apply_matches_full_rebuild():
    """Randomized join/leave/quarantine sequences: incrementally applied
    deltas must land on the same table as a from-scratch rebuild."""
    state = RingState()
    alive = set()
    quarantined = set()
    pool = _rand_ids(400)
    for step in range(60):
        batch = []
        for _ in range(int(RNG.integers(1, 12))):
            pid = pool[int(RNG.integers(len(pool)))]
            if pid in alive and RNG.random() < 0.45:
                batch.append(Event(subject_id=pid, kind="leave", seq=step))
                alive.discard(pid)
                quarantined.discard(pid)
            else:
                batch.append(Event(subject_id=pid, kind="join", seq=step))
                alive.add(pid)
                quarantined.discard(pid)
        state.apply_events(batch)
        # occasional quarantine flips on live peers
        if alive and RNG.random() < 0.5:
            pid = list(alive)[int(RNG.integers(len(alive)))]
            flag = bool(RNG.random() < 0.5)
            state.set_quarantined(pid, flag)
            (quarantined.add if flag else quarantined.discard)(pid)
        rebuild = sorted(alive - quarantined)
        assert state.active_ids_list() == rebuild
        assert [int(x) for x in state.all_ids()] == sorted(alive)


def test_version_strictly_monotonic_and_noop_safe():
    state = RingState()
    versions = [state.version]
    for pid in _rand_ids(50):
        state.add(pid)
        versions.append(state.version)
    assert all(b > a for a, b in zip(versions, versions[1:]))
    # no-ops must NOT bump the version (caches stay valid)
    v = state.version
    known = state.active_ids_list()[0]
    assert not state.add(known)
    assert not state.remove(123456789)  # absent
    assert state.apply_events([]) == 0
    assert state.version == v


def test_batch_join_leave_nets_out():
    state = RingState([10, 20, 30])
    v = state.version
    # same subject joins then leaves within one EDRA flush: later wins
    state.apply_events([Event(subject_id=40, kind="join", seq=1),
                        Event(subject_id=40, kind="leave", seq=2),
                        Event(subject_id=20, kind="leave", seq=3)])
    assert state.active_ids_list() == [10, 30]
    assert state.version > v


def test_capacity_doubles_preserving_content():
    state = RingState(capacity=64)
    ids = sorted(set(_rand_ids(500)))
    state.apply_events([Event(subject_id=p, kind="join") for p in ids])
    assert state.capacity >= 500 and state.capacity % 64 == 0
    assert state.active_ids_list() == ids


def test_replica_set_wraps_at_ring_origin():
    ids = [100, 200, 300, 400]
    state = RingState(ids)
    # key past the largest ID wraps to the ring origin
    assert state.replica_set(350, 3) == [400, 100, 200]
    assert state.replica_set(500, 2) == [100, 200]
    # r larger than the ring truncates to n distinct peers
    assert state.replica_set(0, 10) == [100, 200, 300, 400]
    # quarantined peers never appear in a replica set
    state.set_quarantined(100, True)
    assert state.replica_set(500, 2) == [200, 300]


def test_apply_events_counts_changed_slots_exactly():
    """A leave for an ABSENT id whose bisect position lands on another
    departing id must not be double-counted."""
    state = RingState([7, 100])
    assert state.apply_events([Event(subject_id=5, kind="leave"),
                               Event(subject_id=7, kind="leave")]) == 1
    assert state.active_ids_list() == [100]
    assert state.apply_events([Event(subject_id=999, kind="leave")]) == 0


def test_quarantine_only_changes_keep_device_table_cached():
    """Tracking a new quarantined peer leaves the active view — and
    therefore the uploaded device table — untouched."""
    state = RingState([100, 200, 300])
    state.device_table()
    u = state.upload_count
    av = state.active_version
    state.add(250, quarantined=True)          # active view unchanged
    assert state.active_version == av
    state.device_table()
    assert state.upload_count == u
    state.remove(250)                          # quarantined-only removal
    state.device_table()
    assert state.upload_count == u
    state.add(250)                             # real admission invalidates
    state.device_table()
    assert state.upload_count == u + 1


def test_quarantine_excluded_from_ownership():
    state = RingState([100, 200, 300])
    assert state.successor_of(150) == 200
    state.set_quarantined(200, True)
    assert state.successor_of(150) == 300
    assert len(state) == 2 and state.total == 3
    assert 200 not in state and state.is_quarantined(200)
    state.set_quarantined(200, False)
    assert state.successor_of(150) == 200


def test_device_lookup_matches_python_oracle():
    t = build_ring(257, seed=11)
    state = t.state
    keys = RNG.integers(0, 2**64, size=513, dtype=np.uint64)
    owners = state.lookup(keys)
    want = [t.successor_of(int(k)) for k in keys]
    assert [int(o) for o in owners] == want


def test_device_table_shapes_static_across_churn():
    """Membership churn must not change the capacity-padded device-table
    shapes (so the jitted kernel is never re-specialized)."""
    state = RingState(_rand_ids(300))
    thi0, tlo0, n0 = state.device_table()
    u0 = state.upload_count
    state.apply_events([Event(subject_id=p, kind="join")
                        for p in _rand_ids(5)])
    thi1, tlo1, n1 = state.device_table()
    assert thi1.shape == thi0.shape and tlo1.shape == tlo0.shape
    assert state.upload_count == u0 + 1
    # unchanged state -> cached table, no re-upload
    state.device_table()
    assert state.upload_count == u0 + 1


def test_facade_routingtable_shares_state():
    t = RoutingTable([5, 15, 25])
    assert t.state.active_ids_list() == [5, 15, 25]
    t.add(35)
    assert 35 in t.state
    view = RoutingTable(state=t.state)
    view.remove(15)
    assert t.ids == [5, 25, 35]
    assert t.successor_of(30) == 35
    assert t.successor_of(RING_SIZE - 1) == 5  # wrap


# ---------------------------------------------------------------------------
# owner_diff: incremental ownership-change tracking
# ---------------------------------------------------------------------------

def _owners(state, keys):
    return [state.successor_of(int(k)) for k in keys]


def test_owner_diff_flags_exactly_the_changed_keys():
    """For any single join/leave/quarantine batch, owner_diff's arcs must
    flag a key iff its owner actually changed (oracle: re-resolve all)."""
    state = RingState(_rand_ids(64))
    state.track_owner_diffs()
    keys = np.array(_rand_ids(512), np.uint64)
    for step in range(30):
        v0 = state.active_version
        before = _owners(state, keys)
        live = state.active_ids()
        kind = int(RNG.integers(3))
        if kind == 0:
            state.apply_events(
                [Event(subject_id=p, kind="join", seq=step)
                 for p in _rand_ids(int(RNG.integers(1, 6)))])
        elif kind == 1:
            gone = [int(live[int(RNG.integers(live.size))])
                    for _ in range(int(RNG.integers(1, 4)))]
            state.apply_events(
                [Event(subject_id=p, kind="leave", seq=step) for p in gone])
        else:
            state.set_quarantined(int(live[int(RNG.integers(live.size))]),
                                  True)
        after = _owners(state, keys)
        changed = np.array([a != b for a, b in zip(before, after)])
        diff = state.owner_diff(v0)
        flagged = diff.affected(keys)
        np.testing.assert_array_equal(flagged, changed)


def test_owner_diff_accumulates_across_batches():
    """A diff spanning several batches is a superset of the net change
    (arcs may over-approximate when churn nets out A->B->A)."""
    state = RingState(_rand_ids(32))
    state.track_owner_diffs()
    keys = np.array(_rand_ids(256), np.uint64)
    v0 = state.active_version
    before = _owners(state, keys)
    for step in range(5):
        state.apply_events(
            [Event(subject_id=p, kind="join", seq=step)
             for p in _rand_ids(3)])
    victim = int(state.active_ids()[4])
    state.remove(victim)
    after = _owners(state, keys)
    changed = np.array([a != b for a, b in zip(before, after)])
    flagged = state.owner_diff(v0).affected(keys)
    assert (flagged | ~changed).all()      # flagged is a superset


def test_owner_diff_noop_batches_flag_nothing():
    state = RingState(_rand_ids(16))
    v0 = state.active_version
    keys = np.array(_rand_ids(64), np.uint64)
    diff = state.owner_diff(v0)
    assert not diff.full and diff.arcs.size == 0
    assert not diff.affected(keys).any()
    # quarantine-only tracking of a NEW peer leaves ownership intact
    state.add(_rand_ids(1)[0], quarantined=True)
    assert not state.owner_diff(v0).affected(keys).any()


def test_owner_diff_falls_back_to_full_when_history_evicted():
    from repro.core.ringstate import _DIFF_HISTORY
    state = RingState(_rand_ids(8))
    state.track_owner_diffs()
    v0 = state.active_version
    for i, pid in enumerate(_rand_ids(_DIFF_HISTORY + 10)):
        state.add(pid)
    diff = state.owner_diff(v0)
    assert diff.full
    assert diff.affected(np.array(_rand_ids(5), np.uint64)).all()


def test_owner_diff_untracked_mutations_answered_conservatively():
    """Arc recording is opt-in (the EDRA hot path pays nothing without a
    consumer): churn before the first owner_diff call yields a full diff,
    and tracking is armed from that call onward."""
    state = RingState(_rand_ids(16))
    v0 = state.active_version
    state.add(_rand_ids(1)[0])             # mutation before any consumer
    assert state.owner_diff(v0).full       # conservative, never stale
    v1 = state.active_version
    state.add(_rand_ids(1)[0])             # now recorded
    assert not state.owner_diff(v1).full


def test_owner_diff_tiny_views_are_conservative():
    state = RingState()
    state.track_owner_diffs()
    v0 = state.active_version
    a, b = _rand_ids(2)
    state.add(a)                           # 0 -> 1 peers: unbounded
    assert state.owner_diff(v0).full
    v1 = state.active_version
    state.add(b)                           # 1 -> 2 peers: still unbounded
    assert state.owner_diff(v1).full


def test_owner_diff_rejects_reversed_versions():
    state = RingState(_rand_ids(4))
    with pytest.raises(ValueError):
        state.owner_diff(state.active_version + 1, state.active_version)
