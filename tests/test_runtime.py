"""Membership / quarantine / elastic / placement behaviour."""
import numpy as np
import pytest

from repro.runtime import (ElasticController, FailoverConfig,
                           FailoverManager, Membership, Placement)


def _mk(n=32, t=None):
    t = [0.0] if t is None else t
    m = Membership(t_q=60.0, now=lambda: t[0])
    for i in range(n):
        m.request_join(f"10.0.0.{i}", 7000 + i)
    return m, t


def test_join_fail_updates_view():
    m, t = _mk(8)
    assert m.size() == 8
    victim = m.members()[2]
    m.fail(victim)
    assert m.size() == 7 and victim not in m.members()


def test_quarantine_admission_flow():
    m, t = _mk(4)
    nid = m.request_join("10.9.9.9", 9999, preemptible=True)
    assert m.size() == 4                  # not admitted yet (paper §V)
    t[0] = 30.0
    assert m.poll_quarantine() == []
    t[0] = 61.0
    assert m.poll_quarantine() == [nid]
    assert m.size() == 5
    # volatile peer: leaves inside T_q -> no events at all
    before = m._events_seen
    nid2 = m.request_join("10.9.9.8", 9998, preemptible=True)
    m.fail(nid2)
    assert m._events_seen == before


def test_elastic_replan_power_of_two():
    m, t = _mk(37)
    c = ElasticController(m, model_axis=4)
    plan = c.replan()
    assert plan.model_axis == 4
    assert plan.data_axis * 4 <= 37
    assert plan.data_axis in (1, 2, 4, 8)
    gen = c.generation
    m.fail(m.members()[0])                # event triggers replan
    assert c.generation > gen


def test_straggler_eviction_rule5_generalized():
    m, t = _mk(8)
    c = ElasticController(m, model_axis=1)
    members = m.members()
    for i, nid in enumerate(members):
        c.heartbeat(nid, 1.0)
    c.heartbeat(members[0], 5.0)          # 5x median
    out = c.evict_stragglers(factor=2.0)
    assert out == [members[0]]
    assert members[0] not in m.members()


def test_placement_balance_and_stability():
    m, t = _mk(64)
    p = Placement(m.table)
    stats = p.balance_stats(4096)
    assert stats["cv"] < 1.5              # consistent hashing variance
    before = {f"k{i}": p.owner(f"k{i}") for i in range(200)}
    victim = m.members()[10]
    m.fail(victim)
    p2 = Placement(m.table)
    moved = sum(1 for k, o in before.items()
                if p2.owner(k) != o)
    # only the failed node's arc remaps (~1/64 of keys)
    assert moved <= max(10, int(0.10 * len(before)))
    for k, o in before.items():
        if o != victim and p2.owner(k) != o:
            pytest.fail("key moved although its owner survived")


def test_expert_assignment_covers_all_shards():
    m, t = _mk(64)
    p = Placement(m.table)
    assign = p.expert_assignment(128, 16)
    assert assign.shape == (128,)
    assert set(assign.tolist()) <= set(range(16))
    perm = p.expert_permutation(128, 16)
    assert sorted(perm.tolist()) == list(range(128))


def test_failover_save_restore_cycle(tmp_path):
    m, t = _mk(8)
    c = ElasticController(m, model_axis=1)
    f = FailoverManager(FailoverConfig(str(tmp_path), save_every_steps=2,
                                       keep_last=2), c)
    state = {"w": np.arange(10.0)}
    assert f.maybe_save(1, state) is None
    assert f.maybe_save(2, state) is not None
    assert not f.needs_restore()
    m.fail(m.members()[0])
    assert f.needs_restore()
    step, restored = f.restore_latest(state)
    assert step == 2
    np.testing.assert_array_equal(restored["w"], state["w"])


def test_retune_window_anchored_to_construction():
    """Regression: the event-rate window divided by the raw clock value,
    so any monotonic clock with boot-relative epoch deflated r (and blew
    up Theta) by orders of magnitude.  The window must be time since the
    Membership view was constructed."""
    uptime = 1_000_000.0                   # host has been up for ~12 days
    t = [uptime]
    m = Membership(now=lambda: t[0])
    for i in range(8):
        m.request_join(f"10.0.2.{i}", 7000 + i)
    t[0] = uptime + 100.0
    m.fail(m.members()[0])
    # 9 events over 100 s of view lifetime
    assert m.params.r == pytest.approx(9 / 100.0, rel=1e-6)


def test_retune_late_burst_as_aggressive_as_early():
    """Regression: the rate window was anchored to view construction
    forever, so the estimate decayed toward 0 on a long-lived view and a
    churn burst after a quiet day barely moved Theta.  With the sliding
    window, a late burst must retune exactly as aggressively as an early
    one (§IV-D: Theta must track the CURRENT rate)."""
    t_a = [0.0]
    m_early = Membership(now=lambda: t_a[0])
    for i in range(8):
        m_early.request_join(f"10.1.0.{i}", 7000 + i)
    r_early = m_early.params.r

    t_b = [0.0]
    m_late = Membership(now=lambda: t_b[0])
    for i in range(8):
        m_late.request_join(f"10.1.0.{i}", 7000 + i)
    t_b[0] = 86_400.0                    # a quiet day goes by
    for i in range(8):
        m_late.request_join(f"10.1.1.{i}", 7100 + i)
    r_late = m_late.params.r
    # lifetime-anchored estimate would be 16/86400 ~ 2e-4 events/s
    assert r_late > 100.0 * (16 / 86_400.0)
    assert r_late == pytest.approx(r_early, rel=0.25)


def test_retune_rate_decays_after_burst():
    """Events older than the sliding horizon drop out of the estimate."""
    t = [0.0]
    m = Membership(now=lambda: t[0])
    for i in range(8):
        m.request_join(f"10.1.0.{i}", 7000 + i)
    r_burst = m.params.r
    t[0] = Membership.RATE_HORIZON + 10.0
    m.request_join("10.1.2.1", 7201)     # one straggler event
    assert m.params.r < r_burst / 4      # burst aged out of the window


def test_preemptible_restart_while_quarantined():
    """A preemptible node that restarts BEFORE its T_q elapsed hits the
    request_join path with its id already present (and masked) in the
    shared state: the tracked slot must be reused — never duplicated —
    and the quarantine clock must restart from the new incarnation."""
    m, t = _mk(4)
    nid = m.request_join("10.9.9.7", 9997, preemptible=True)
    total0 = m.ring_state.total
    events0 = m._events_seen

    t[0] = 30.0                           # restart before T_q = 60 elapsed
    nid2 = m.request_join("10.9.9.7", 9997, preemptible=True)
    assert nid2 == nid
    assert m.ring_state.total == total0   # no duplicate tracked entry
    assert m.ring_state.is_quarantined(nid)
    assert m.size() == 4                  # still masked out of ownership
    assert m._events_seen == events0      # nothing disseminated

    t[0] = 85.0   # original clock would have admitted at 60; restarted at 30
    assert m.poll_quarantine() == []      # quarantine clock was reset
    t[0] = 91.0
    assert m.poll_quarantine() == [nid]   # admitted once, 61 s post-restart
    assert m.size() == 5
    assert m._events_seen == events0 + 1  # exactly one join event
    assert not m.ring_state.is_quarantined(nid)
    assert m.ring_state.total == total0


def test_quarantine_member_masks_without_leave_event():
    m, t = _mk(8)
    nid = m.members()[3]
    events_before = m._events_seen
    seen = []
    m.subscribe(lambda ev: seen.append(ev.kind))
    assert m.quarantine_member(nid)
    assert m._events_seen == events_before   # no EDRA dissemination
    assert seen == ["quarantine"]            # but local listeners notified
    assert nid not in m.members()            # masked out of ownership
    assert m.ring_state.is_quarantined(nid)
    assert not m.quarantine_member(nid)      # idempotent
