"""Vectorized EDRA simulators: C1 + Theorem-1 bound at n=512 (fixed-n
plane) and the §VII churn plane vs the DES oracle / analytical model
(DESIGN.md §8 cross-validation ladder)."""
import pytest

from repro.core.churn import ChurnConfig
from repro.core.jax_sim import SimConfig, simulate, simulate_churn


@pytest.mark.slow
def test_sim_one_hop_and_ack_bound():
    r = simulate(SimConfig(n=512, s_avg=174 * 60, duration=1200.0, seed=3))
    assert r.one_hop_fraction >= 0.99           # claim C1
    assert r.mean_ack_time <= r.theorem1_bound  # Theorem 1 (+detection)
    # analysis is a deliberate overestimate (factor-2 in Eq IV.6 + ceil rho)
    assert 0.55 <= r.mean_out_bps / r.analytical_bps <= 1.1


@pytest.mark.slow
def test_sim_higher_churn_still_one_hop():
    r = simulate(SimConfig(n=512, s_avg=60 * 60, duration=900.0, seed=4))
    assert r.one_hop_fraction >= 0.99


# ---------------------------------------------------------------------------
# churn plane (simulate_churn)
# ---------------------------------------------------------------------------

def test_churn_plane_smoke_and_model_band():
    """Fast config: the vectorized plane produces a sane ChurnResult and
    lands in the analytical model's band (the model deliberately
    overestimates, cf. test_sim_one_hop_and_ack_bound)."""
    r = simulate_churn(ChurnConfig(n=512, s_avg=174 * 60, duration=300,
                                   warmup=60, seed=3))
    assert r.events > 0
    assert r.one_hop_fraction >= 0.98
    assert r.mean_ack_s > 0 and r.p99_ack_s >= r.mean_ack_s
    assert 0.4 <= r.mean_out_bps / r.analytical_bps <= 1.3
    assert r.sum_out_bps == pytest.approx(r.mean_out_bps * 512)


def test_churn_plane_d1ht_beats_calot():
    """The paper's headline ordering (Figs 3-4): D1HT's aggregated EDRA
    maintenance costs less than 1h-Calot's one-event-per-message plan,
    on the SAME event stream (same config/seed)."""
    base = dict(n=2048, s_avg=169 * 60, duration=300, warmup=60, seed=9)
    d1 = simulate_churn(ChurnConfig(protocol="d1ht", **base))
    ca = simulate_churn(ChurnConfig(protocol="calot", **base))
    assert d1.events == ca.events          # identical churn realization
    assert d1.mean_out_bps < ca.mean_out_bps
    assert ca.one_hop_fraction >= 0.98 and d1.one_hop_fraction >= 0.98


def test_churn_plane_quarantine_reduces_traffic():
    """§V on the vectorized plane: volatile peers never enter the ring,
    so maintenance traffic drops and admissions/skips are counted."""
    base = dict(n=2048, s_avg=174 * 60, duration=300, warmup=60, seed=7,
                volatile_fraction=0.31)
    plain = simulate_churn(ChurnConfig(**base))
    quar = simulate_churn(ChurnConfig(quarantine_tq=600.0, **base))
    assert quar.mean_out_bps < plain.mean_out_bps
    assert quar.quarantine_skipped > 0
    assert quar.events < plain.events
    assert quar.one_hop_fraction >= 0.98


@pytest.mark.slow
def test_churn_twin_des_vs_vectorized_d1ht():
    """DES <-> vectorized twin at overlapping n (the §VII methodology on
    both planes from ONE ChurnConfig): per-peer maintenance bandwidth
    and one-hop fraction must agree within tolerance."""
    from repro.dht import run_churn

    cfg = ChurnConfig(n=1000, s_avg=174 * 60, duration=600, warmup=120,
                      seed=11)
    des = run_churn(cfg)
    vec = simulate_churn(cfg)
    ratio = vec.mean_out_bps / des.mean_out_bps
    assert 0.7 <= ratio <= 1.4, (vec.summary(), des.summary())
    assert abs(vec.one_hop_fraction - des.one_hop_fraction) <= 0.006
    assert vec.one_hop_fraction >= 0.99 and des.one_hop_fraction >= 0.99


@pytest.mark.slow
def test_churn_twin_des_vs_vectorized_calot():
    from repro.dht import run_churn

    cfg = ChurnConfig(n=512, s_avg=174 * 60, duration=600, warmup=120,
                      seed=13, protocol="calot")
    des = run_churn(cfg)
    vec = simulate_churn(cfg)
    ratio = vec.mean_out_bps / des.mean_out_bps
    assert 0.6 <= ratio <= 1.5, (vec.summary(), des.summary())
    assert abs(vec.one_hop_fraction - des.one_hop_fraction) <= 0.008


@pytest.mark.slow
def test_churn_plane_tracks_model_at_scale():
    """The paper-scale cross-validation the DES cannot reach: at
    n = 10^4 the measured per-peer bandwidth stays within 2x of
    Eqs IV.5-IV.7 / Eq VII.1 for both protocols."""
    for proto in ("d1ht", "calot"):
        r = simulate_churn(ChurnConfig(n=10_000, s_avg=174 * 60,
                                       duration=600, warmup=120,
                                       protocol=proto, seed=2))
        ratio = r.mean_out_bps / r.analytical_bps
        assert 0.5 <= ratio <= 2.0, (proto, r.summary())
        assert r.one_hop_fraction >= 0.99
