"""Vectorized EDRA simulator: C1 + Theorem-1 bound at n=512."""
import pytest

from repro.core.jax_sim import SimConfig, simulate


@pytest.mark.slow
def test_sim_one_hop_and_ack_bound():
    r = simulate(SimConfig(n=512, s_avg=174 * 60, duration=1200.0, seed=3))
    assert r.one_hop_fraction >= 0.99           # claim C1
    assert r.mean_ack_time <= r.theorem1_bound  # Theorem 1 (+detection)
    # analysis is a deliberate overestimate (factor-2 in Eq IV.6 + ceil rho)
    assert 0.55 <= r.mean_out_bps / r.analytical_bps <= 1.1


@pytest.mark.slow
def test_sim_higher_churn_still_one_hop():
    r = simulate(SimConfig(n=512, s_avg=60 * 60, duration=900.0, seed=4))
    assert r.one_hop_fraction >= 0.99
