"""Unified placement-policy layer (DESIGN.md §13, ISSUE 9).

Acceptance properties:

  * **Bit-identity of the default.**  ``RingSuccessor`` must reproduce
    the pre-refactor ad-hoc successor loops exactly — replica groups,
    §V gateway picks, serve-plane owners/tokens/proxy counts — under
    hypothesis-driven churn streams (fixed-seed twins always run; the
    hypothesis layer skips when the package is absent, as elsewhere in
    this tree).
  * **Set-preservation.**  Any policy's ``rank`` is a permutation of the
    replica set, so ``BlockStore.sync``'s vectorized ``replica_sets``
    repair stays policy-independent.
  * **Proximity + affinity.**  ``LatencyAware`` prefers same-region
    replica-set members, keeps a held placement within the affinity
    hysteresis, and degenerates to exact ring order on a single-region
    topology.
  * **Co-location (ISSUE 9 satellite).**  A session's exported KV blocks
    live on the SESSION's replica set, so the node a migration targets
    already holds the handoff blocks.
  * **GeoDelay** is the stochastic twin of the topology estimator and
    reproduces LanDelay exactly in the single-region case.
"""
import math
import random

import numpy as np
import pytest

from repro.core.edra import Event
from repro.core.ringstate import RingState
from repro.dht.data import BlockStore
from repro.dht.des import GeoDelay, LanDelay, SimNet, WanDelay
from repro.runtime import Membership
from repro.runtime.placement import (LatencyAware, PlacementPolicy,
                                     RingSuccessor, Topology)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


def _rand_ids(rng: np.random.Generator, k: int) -> np.ndarray:
    x = rng.integers(0, 2**64, size=2 * k + 16, dtype=np.uint64)
    x = np.unique(x)[:k]
    assert x.size == k
    return x


def _churned_state(seed: int, n: int = 64, batches: int = 4) -> RingState:
    """A ring that has LIVED: built, then churned through event batches."""
    rng = np.random.default_rng(seed)
    state = RingState(_rand_ids(rng, n))
    for _ in range(batches):
        live = state.active_ids()
        leave = live[rng.integers(0, live.size, size=4)]
        evs = [Event(subject_id=int(p), kind="leave") for p in np.unique(leave)]
        evs += [Event(subject_id=int(p), kind="join")
                for p in _rand_ids(rng, 4)]
        state.apply_events(evs)
    return state


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------

def test_topology_hash_assignment_deterministic_and_covering():
    topo = Topology.multi_dc(4)
    ids = _rand_ids(np.random.default_rng(0), 4096)
    a = topo.region_index(ids)
    b = topo.region_index(ids)
    np.testing.assert_array_equal(a, b)
    # every region gets a healthy share of a hash-assigned population
    counts = np.bincount(a, minlength=4)
    assert counts.min() > 4096 // 8, counts


def test_topology_pinning_overrides_hash():
    topo = Topology.multi_dc(3)
    ids = _rand_ids(np.random.default_rng(1), 32)
    topo.place(int(ids[5]), "eu-west")
    topo.place(int(ids[9]), "us-east")
    assert topo.region_of(int(ids[5])) == "eu-west"
    assert topo.region_of(int(ids[9])) == "us-east"
    # vectorized path agrees with the scalar one, pins included
    idx = topo.region_index(ids)
    for i, nid in enumerate(ids):
        assert topo.names[idx[i]] == topo.region_of(int(nid))


def test_topology_rtt_symmetric_and_consistent():
    topo = Topology.multi_dc(4)
    assert topo.rtt_ms("us-east", "eu-west") == topo.rtt_ms("eu-west",
                                                            "us-east")
    assert topo.rtt_ms("us-east", "us-east") == pytest.approx(
        topo.intra_rtt_ms)
    ids = _rand_ids(np.random.default_rng(2), 16)
    many = topo.rtt_ms_many("us-east", ids)
    for i, nid in enumerate(ids):
        assert many[i] == pytest.approx(topo.rtt_ms("us-east", int(nid)))


# ---------------------------------------------------------------------------
# ReplicaView
# ---------------------------------------------------------------------------

def test_replica_view_matches_replica_set_with_increasing_arcs():
    state = _churned_state(3)
    rng = np.random.default_rng(4)
    for key in rng.integers(0, 2**64, size=32, dtype=np.uint64):
        view = state.replica_view(int(key), 3)
        assert list(view.ids) == [int(p) for p in state.replica_set(
            int(key), 3)]
        assert view.n_active == state.active_ids().size
        # successors are walked clockwise: arc distances strictly grow
        assert all(a < b for a, b in zip(view.arc_dist, view.arc_dist[1:]))


# ---------------------------------------------------------------------------
# RingSuccessor bit-identity vs the pre-refactor inline oracles
# ---------------------------------------------------------------------------

def _assert_ring_successor_oracle(state: RingState, keys) -> None:
    pol = RingSuccessor()
    for key in keys:
        # pre-refactor admission/migration/data-plane pick: the raw
        # successor list, regardless of origin/prefer hints
        want = [int(p) for p in state.replica_set(int(key), 2)]
        assert pol.replica_group(state, int(key), 2) == want
        assert pol.replica_group(state, int(key), 2, origin=want[0],
                                 prefer=want[-1]) == want
    # pre-refactor §V gateway pick: active_ids()[:2]
    assert pol.gateways(state, 2) == [int(p) for p in state.active_ids()[:2]]


def test_ring_successor_oracle_fixed_seed_churn_stream():
    rng = np.random.default_rng(5)
    for seed in range(6):
        state = _churned_state(seed)
        _assert_ring_successor_oracle(
            state, rng.integers(0, 2**64, size=16, dtype=np.uint64))


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), n=st.integers(2, 40),
           stream=st.lists(st.tuples(st.booleans(),
                                     st.integers(0, 2**64 - 1)),
                           max_size=24))
    def test_ring_successor_oracle_hypothesis_churn_stream(seed, n, stream):
        rng = np.random.default_rng(seed)
        state = RingState(_rand_ids(rng, n))
        for is_leave, x in stream:
            if is_leave:
                live = state.active_ids()
                state.apply_events([Event(
                    subject_id=int(live[x % live.size]), kind="leave")])
                if not state.active_ids().size:     # never drain the ring
                    state.apply_events([Event(subject_id=int(x) | 1,
                                              kind="join")])
            else:
                state.apply_events([Event(subject_id=int(x), kind="join")])
        _assert_ring_successor_oracle(
            state, rng.integers(0, 2**64, size=8, dtype=np.uint64))


def test_membership_gateway_pick_bit_identical_to_legacy():
    """Two Membership twins fed the same join stream — default policy vs
    an inline reimplementation of the legacy active_ids()[:2] pick —
    record identical §V gateway sets for every quarantined joiner."""

    class LegacyOracle(PlacementPolicy):
        name = "legacy_oracle"

        def rank(self, view, *, origin=None, prefer=None):
            return list(view.ids)

        def gateways(self, state, k, *, origin=None):
            return [int(x) for x in state.active_ids()[:k]]

    t = [0.0]
    twins = [Membership(t_q=60.0, now=lambda: t[0]),
             Membership(t_q=60.0, now=lambda: t[0],
                        policy=LegacyOracle())]
    for m in twins:
        for i in range(12):
            m.request_join(f"10.7.0.{i}", 7000 + i)
        for i in range(6):
            m.request_join(f"10.7.1.{i}", 7100 + i, preemptible=True)
    a, b = (m.quarantine.pending for m in twins)
    assert a.keys() == b.keys() and len(a) == 6
    for nid in a:
        assert a[nid].gateways == b[nid].gateways
        assert len(a[nid].gateways) == 2


# ---------------------------------------------------------------------------
# LatencyAware
# ---------------------------------------------------------------------------

def test_latency_aware_is_set_preserving():
    topo = Topology.multi_dc(4)
    pol = LatencyAware(topo)
    state = _churned_state(7)
    rng = np.random.default_rng(8)
    origins = state.active_ids()
    for key in rng.integers(0, 2**64, size=64, dtype=np.uint64):
        base = state.replica_set(int(key), 3)
        origin = int(origins[rng.integers(0, origins.size)])
        got = pol.replica_group(state, int(key), 3, origin=origin,
                                prefer=int(base[-1]))
        assert sorted(got) == sorted(int(p) for p in base)


def test_latency_aware_prefers_same_region_and_ignores_missing_origin():
    topo = Topology.multi_dc(2)
    pol = LatencyAware(topo)
    state = _churned_state(9)
    rng = np.random.default_rng(10)
    promoted = 0
    for key in rng.integers(0, 2**64, size=128, dtype=np.uint64):
        view = state.replica_view(int(key), 2)
        assert pol.rank(view) == list(view.ids)      # no origin: ring order
        for region in topo.names:
            got = pol.rank(view, origin=region)
            regions = [topo.region_of(p) for p in got]
            if region in regions:
                assert regions[0] == region          # nearest first
                promoted += got[0] != view.ids[0]
    assert promoted > 0      # the ranking actually reordered something


def test_latency_aware_affinity_hysteresis():
    """The discount pins the holder against any strictly-farther rival;
    EQUAL-bucket rivals still win by ring order (deliberately — that tie
    rule is what degenerates LatencyAware to RingSuccessor on LAN)."""
    topo = Topology.multi_dc(4)
    state = _churned_state(11)
    rng = np.random.default_rng(12)
    sticky = LatencyAware(topo, affinity_ms=1e6)
    checked = 0
    for key in rng.integers(0, 2**64, size=64, dtype=np.uint64):
        view = state.replica_view(int(key), 3)
        cand_regions = {topo.region_of(int(p)) for p in view.ids}
        origin = next((nm for nm in topo.names if nm not in cand_regions),
                      None)
        if origin is None:       # every region holds a candidate: ties
            continue             # possible, hysteresis not guaranteed
        checked += 1
        for held in view.ids:
            # every rival is >= one inter-region hop from the origin, so
            # the discounted holder's bucket is strictly best
            assert sticky.rank(view, origin=origin, prefer=int(held))[0] \
                == held
        # a prefer hint OUTSIDE the candidate set must be ignored
        assert sorted(sticky.rank(view, origin=origin, prefer=12345)) \
            == sorted(view.ids)
    assert checked > 8


def test_latency_aware_degenerates_to_ring_order_on_single_region():
    topo = Topology.single_region()
    pol = LatencyAware(topo)
    state = _churned_state(13)
    rng = np.random.default_rng(14)
    origins = state.active_ids()
    for key in rng.integers(0, 2**64, size=64, dtype=np.uint64):
        view = state.replica_view(int(key), 3)
        origin = int(origins[rng.integers(0, origins.size)])
        assert pol.rank(view, origin=origin) == list(view.ids)
    assert pol.gateways(state, 2, origin=int(origins[0])) \
        == [int(p) for p in state.active_ids()[:2]]


def test_latency_aware_gateways_pick_low_rtt_actives():
    topo = Topology.multi_dc(2)
    pol = LatencyAware(topo)
    state = _churned_state(15)
    for region in topo.names:
        gws = pol.gateways(state, 2, origin=region)
        assert len(gws) == 2
        best = topo.rtt_ms_many(region, state.active_ids()).min()
        for g in gws:
            assert topo.rtt_ms(region, g) == pytest.approx(best)


# ---------------------------------------------------------------------------
# BlockStore through a policy: set-preservation keeps repair invariant
# ---------------------------------------------------------------------------

def test_block_store_placement_set_policy_independent():
    """The copies' LOCATION SET never depends on the policy (only the
    preferred read order does) — so sync repair traffic is identical."""
    rng = np.random.default_rng(16)
    ids = _rand_ids(rng, 48)
    topo = Topology.multi_dc(3)
    stores = []
    for pol in (None, RingSuccessor(), LatencyAware(topo)):
        state = RingState(ids.copy())
        s = BlockStore(state, replication=3, policy=pol)
        for i in range(24):
            s.put(f"blk/{i}", bytes([i]) * 64)
            s.put(f"kv/{i}", bytes([i]) * 64, at=i * 7 + 1)
        stores.append(s)
    base = stores[0]
    for s in stores[1:]:
        for key, holders in base._placement.items():
            assert sorted(s._placement[key]) == sorted(holders)
    # and the co-located block really sits on its placement key's set
    want = [int(p) for p in stores[0].state.replica_set(8, 3)]   # 1*7+1
    assert sorted(base._placement[BlockStore.key_of("kv/1")]) == sorted(want)


# ---------------------------------------------------------------------------
# GeoDelay
# ---------------------------------------------------------------------------

def test_geo_delay_single_region_reproduces_lan_delay():
    gd = GeoDelay(Topology.single_region())
    lan = LanDelay()
    assert gd.mean == pytest.approx(lan.mean)
    r1, r2 = random.Random(42), random.Random(42)
    for _ in range(64):
        assert gd.sample_pair(r1, 1, 2) == pytest.approx(lan.sample(r2))


def test_geo_delay_per_pair_medians_track_topology():
    topo = Topology.multi_dc(4)
    gd = GeoDelay(topo, sigma=0.25)
    rng = random.Random(0)
    for a, b in (("us-east", "eu-west"), ("us-east", "ap-south")):
        xs = sorted(gd.sample_pair(rng, a, b) for _ in range(4001))
        med = xs[2000]
        assert med == pytest.approx(topo.one_way_ms(a, b) * 1e-3, rel=0.1)
    # intra-region stays microseconds even on the WAN topology
    nid = 7
    other = next(i for i in range(8, 64)
                 if topo.region_of(i) == topo.region_of(nid))
    assert gd.sample_pair(rng, nid, other) < 1e-3


def test_geo_delay_mean_supports_churn_duck_typing():
    from repro.core.churn import delay_mean_seconds
    topo = Topology.multi_dc(4)
    gd = GeoDelay(topo, sigma=0.25)
    assert delay_mean_seconds(gd) == pytest.approx(gd.mean)
    # cross-check against the analytic pieces it is built from
    bump = math.exp(0.5 * 0.25**2)
    names = topo.names
    want = sum((gd._intra_mean() if a == b
                else topo.one_way_ms(a, b) * 1e-3 * bump)
               for a in names for b in names) / len(names) ** 2
    assert gd.mean == pytest.approx(want)
    assert delay_mean_seconds(WanDelay()) == pytest.approx(
        math.exp(math.log(0.060) + 0.6**2 / 2))


def test_simnet_routes_through_sample_pair():
    """SimNet.send samples the (src, dst) pair: datagrams between far
    regions arrive tens of ms later than intra-region ones.  All sends
    happen at t=0, so delivery times ARE the sampled one-way delays."""
    from repro.dht.des import SimPeer

    class Sink(SimPeer):
        def __init__(self, pid, net):
            super().__init__(pid, net)
            self.alive = True
            self.at = []

        def start(self):                              # pragma: no cover
            pass

        def stop(self, *, crash):                     # pragma: no cover
            self.alive = False

        def on_datagram(self, src, kind, payload):
            self.at.append(self.net.now)

    topo = Topology.multi_dc(2)
    # pin the test peers so the pairings are unambiguous
    topo.place(1, "us-east"); topo.place(2, "us-east")
    topo.place(3, "us-west")
    delays = {}
    for dst in (2, 3):
        net = SimNet(GeoDelay(topo), seed=0)
        net.peers.update({pid: Sink(pid, net) for pid in (1, dst)})
        for _ in range(200):
            net.send(1, dst, 1000, "ping", acked=False, maintenance=False)
        net.run_until(10.0)
        assert len(net.peers[dst].at) == 200
        delays[dst] = float(np.median(net.peers[dst].at))
    assert delays[2] < 1e-3                      # intra: LAN regime
    assert delays[3] == pytest.approx(           # inter: topo median
        topo.one_way_ms("us-east", "us-west") * 1e-3, rel=0.15)


# ---------------------------------------------------------------------------
# serve plane: co-location regression + twin-run bit-identity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_model():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import Model
    cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _membership(n, t, policy=None):
    m = Membership(t_q=60.0, now=lambda: t[0], policy=policy)
    for i in range(n):
        m.request_join(f"10.8.0.{i}", 7300 + i)
    return m


@pytest.mark.slow
def test_session_blocks_resident_on_migration_target(smoke_model):
    """ISSUE 9 consistency fix: exported KV blocks are placed AT the
    session's ring key, so every chunk's holder set IS the session's
    replica set — and when the owner dies, the surviving member the
    policy promotes already holds the handoff blocks locally (asserted
    directly against the pre-kill holder sets, plus zero fetch misses).
    Pre-fix, blocks hashed to kv/<sid>/<j>'s OWN unrelated replica set
    and migration handoffs fetched from third-party nodes."""
    from repro.serve import Request, ServeCluster
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(5, t)
    cluster = ServeCluster(m, model, params, slots=16, max_len=64,
                           prefill_chunk=8)
    assert cluster.blocks is not None
    rng = np.random.default_rng(0)
    for i in range(8):
        cluster.submit(Request(
            f"p{i}", rng.integers(0, cfg.vocab, 10 + (i % 4) * 3,
                                  dtype=np.int32), max_new_tokens=6))
    store = cluster.blocks
    assert cluster.exported_blocks > 0
    holders_before = {}
    for rec in cluster.sessions.values():
        group = {int(p) for p in cluster.state.replica_set(
            rec.key, cluster.replication)}
        assert rec.owner in group
        assert rec.exported_chunks > 0
        for j in range(rec.exported_chunks):
            key = store.key_of(cluster._block_name(rec.session_id, j))
            held = set(store._placement[key])
            assert held == group, (
                f"{rec.session_id}/{j} stored on {held}, "
                f"session replica set is {group}")
            holders_before[(rec.session_id, j)] = held

    by_owner = {}
    for rec in cluster.sessions.values():
        by_owner.setdefault(rec.owner, []).append(rec)
    victim = max(by_owner, key=lambda o: len(by_owner[o]))
    moved = list(by_owner[victim])
    m.fail(victim)
    assert cluster.handoffs >= 1
    assert cluster.handoff_misses == 0
    for rec in moved:
        assert rec.owner != victim
        for j in range(rec.exported_chunks):
            assert rec.owner in holders_before[(rec.session_id, j)], (
                f"{rec.session_id} migrated to a node that did not "
                "already hold its KV chunks")
    cluster.run()
    assert all(rec.done for rec in cluster.sessions.values())


@pytest.mark.slow
def test_cluster_policy_plumbing_bit_identical_to_inline_oracle(smoke_model):
    """Twin runs of one workload — churn, a quarantined §V gateway, a
    node kill — under (a) the default policy and (b) an inline ring-
    order oracle defined here: generated tokens, final owners, and
    proxy counts must all be identical.  The policy layer added ZERO
    behavior to the pre-refactor successor walks."""
    from repro.serve import Request, ServeCluster
    cfg, model, params = smoke_model

    class InlineOracle(PlacementPolicy):
        name = "inline_oracle"

        def rank(self, view, *, origin=None, prefer=None):
            return list(view.ids)

        def gateways(self, state, k, *, origin=None):
            return [int(x) for x in state.active_ids()[:k]]

    def drive(policy):
        t = [0.0]
        m = _membership(6, t, policy=policy)
        cluster = ServeCluster(m, model, params, slots=16, max_len=64,
                               prefill_chunk=8)
        rng = np.random.default_rng(5)
        for i in range(9):
            cluster.submit(Request(
                f"s{i}", rng.integers(0, cfg.vocab, 6 + (i % 3) * 5,
                                      dtype=np.int32), max_new_tokens=6))
        q = m.request_join("10.8.9.9", 7999, preemptible=True)
        cluster.submit(Request(
            "via-gw", rng.integers(0, cfg.vocab, 7, dtype=np.int32),
            max_new_tokens=6), via=q)
        cluster.step()
        m.fail(sorted(m.members())[0])
        cluster.run()
        return ({sid: rec.owner for sid, rec in cluster.sessions.items()},
                {sid: list(rec.generated)
                 for sid, rec in cluster.sessions.items()},
                dict(cluster.proxied),
                {nid: e.gateways for nid, e in m.quarantine.pending.items()})

    assert drive(None) == drive(InlineOracle())
