"""Tensor-parallel replica groups: one ring node = a device sub-mesh.

In-process tests cover the host-side plumbing (config validation, mesh
carving, prefix-affinity admission); the multi-device execution plane —
tp=1/2/4 token parity, 1/TP per-device KV bytes, per-shard handoff
through a partial-group device loss — runs in subprocesses under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag must be
set before jax initializes its backend).
"""
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.mesh import make_host_mesh, replica_groups
from repro.models import Model
from repro.models.tp import TPReplicaGroup, validate_tp
from repro.runtime import Membership
from repro.serve import Request, ServeCluster
from repro.serve.server import session_key


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# config validation + mesh carving (host-side, any device count)
# ---------------------------------------------------------------------------

def test_validate_tp_rejects_indivisible_dims():
    cfg = get_smoke_config("qwen2.5-3b")     # heads=4, kv_heads=2
    validate_tp(cfg, 1)
    validate_tp(cfg, 2)
    with pytest.raises(ValueError, match="num_heads"):
        validate_tp(cfg, 3)
    with pytest.raises(ValueError, match="num_kv_heads"):
        validate_tp(cfg, 4)                  # heads divide, kv_heads don't
    validate_tp(cfg.with_overrides(num_kv_heads=4), 4)
    with pytest.raises(ValueError, match="tp=0"):
        validate_tp(cfg, 0)


def test_validate_tp_rejects_non_transformer_families():
    cfg = get_smoke_config("falcon-mamba-7b")
    with pytest.raises(ValueError, match="famil"):
        validate_tp(cfg, 2)


def test_make_host_mesh_validates_model_axis():
    n = len(jax.devices())
    mesh = make_host_mesh()                  # model_axis=1 always divides
    assert mesh.shape == {"data": n, "model": 1}
    with pytest.raises(ValueError, match="divide"):
        make_host_mesh(model_axis=n + 1)
    with pytest.raises(ValueError, match="model_axis=0"):
        make_host_mesh(model_axis=0)


def test_replica_groups_carving():
    n = len(jax.devices())
    groups = replica_groups(None, 1)
    assert len(groups) == n
    for g in groups:
        assert g.axis_names == ("model",) and g.devices.size == 1
    # carving a Mesh walks its devices in row-major order
    assert len(replica_groups(make_host_mesh(), 1)) == n
    with pytest.raises(ValueError, match="divide"):
        replica_groups(None, n + 1)
    with pytest.raises(ValueError, match="tp=0"):
        replica_groups(None, 0)


def test_group_mesh_must_be_1d_model_axis(smoke_model):
    _, model, _ = smoke_model
    bad = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="1-D"):
        TPReplicaGroup(model, bad)


# ---------------------------------------------------------------------------
# prefix-cache-aware admission (host-side bookkeeping, tp=1)
# ---------------------------------------------------------------------------

def test_submit_prefers_warm_prefix_candidate(smoke_model):
    """Among replica_set candidates with capacity, submit must pick the
    node that already holds the prompt's prefix chunks — and count it."""
    cfg, model, params = smoke_model
    m = Membership(t_q=60.0, now=lambda: 0.0)
    for i in range(2):
        m.request_join(f"10.9.0.{i}", 7000 + i)
    cluster = ServeCluster(m, model, params, slots=4, max_len=64,
                           replication=2)
    assert cluster.prefix is not None
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, 20, dtype=np.int32)  # 1 full chunk
    cluster.submit(Request("warm0", prompt, max_new_tokens=2))
    owner_a = cluster.sessions["warm0"].owner
    # a session whose PRIMARY is the other node, so only affinity can
    # route it back to the warm one (both nodes have free slots)
    sid = next(s for s in (f"warm-b{i}" for i in range(64))
               if int(cluster.state.replica_set(session_key(s), 2)[0])
               != owner_a)
    cluster.submit(Request(sid, prompt.copy(), max_new_tokens=2))
    assert cluster.sessions[sid].owner == owner_a
    assert cluster.prefix_affinity_hits == 1
    assert cluster.stats()["prefix_affinity_hits"] == 1
    # a cold prompt must NOT be steered off its primary
    cold = rng.integers(0, cfg.vocab, 20, dtype=np.int32)
    sid2 = next(s for s in (f"cold-{i}" for i in range(64))
                if int(cluster.state.replica_set(session_key(s), 2)[0])
                != owner_a)
    cluster.submit(Request(sid2, cold, max_new_tokens=2))
    assert cluster.sessions[sid2].owner != owner_a
    assert cluster.prefix_affinity_hits == 1


# ---------------------------------------------------------------------------
# multi-device execution plane (subprocess: 8 host devices)
# ---------------------------------------------------------------------------

def _run_script(script: str, timeout: int = 900) -> None:
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=timeout,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "ALL_OK" in out.stdout, \
        out.stdout[-2000:] + "\n" + out.stderr[-4000:]


TP_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.launch.mesh import make_host_mesh, replica_groups
from repro.models import Model
from repro.models.tp import TPReplicaGroup

assert len(jax.devices()) == 8
mesh = make_host_mesh(4)
assert mesh.shape == {"data": 2, "model": 4}

cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32",
                                                    num_kv_heads=4)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompt = rng.integers(0, cfg.vocab, 12, dtype=np.int32)
B, MAXLEN, STEPS = 2, 48, 8

def run_tp(tp):
    g = TPReplicaGroup(model, replica_groups(None, tp)[0])
    sp = g.shard_params(params)
    cache = g.init_cache(B, MAXLEN)
    bytes_per_dev = g.per_device_cache_bytes(cache)
    prefill, decode_full, decode_slots, prefill_chunk = g.fns()
    toks_b = jnp.tile(jnp.asarray(prompt)[None], (B, 1))
    logits, cache = prefill(sp, {"tokens": toks_b}, cache)
    toks = [int(jnp.argmax(logits[0]))]
    n = jnp.full((B,), len(prompt), jnp.int32)
    for _ in range(STEPS - 1):
        t = jnp.full((B, 1), toks[-1], jnp.int32)
        logits, cache = decode_full(sp, cache, t, n)
        toks.append(int(jnp.argmax(logits[0])))
        n = n + 1
    # bucketized slot decode must agree with the full-slab path
    idx = jnp.asarray([0, B], jnp.int32)      # row 0 + one OOB pad slot
    t = jnp.full((B, 1), toks[-1], jnp.int32)
    ls, _ = decode_slots(sp, cache, t, n, idx)
    lf, _ = decode_full(sp, cache, t, n)
    assert int(jnp.argmax(ls[0])) == int(jnp.argmax(lf[0]))
    # chunked prefill parity with whole-prompt prefill
    c2 = g.init_cache(B, MAXLEN)
    l2, c2 = prefill_chunk(sp, toks_b, c2, jnp.asarray(0, jnp.int32))
    assert int(jnp.argmax(l2[0, len(prompt) - 1])) == toks[0]
    # per-shard export reassembles to the full slab
    full = g.export_kv_block(cache, 0, 0, 8)
    shards = g.export_kv_shards(cache, 0, 0, 8)
    assert len(shards) == tp
    assert np.array_equal(np.concatenate(shards, axis=3), full)
    return toks, bytes_per_dev

base, ref_bytes = run_tp(1)
for tp in (2, 4):
    toks, b = run_tp(tp)
    assert toks == base, f"tp={tp} tokens {toks} != tp=1 {base}"
    assert b == ref_bytes // tp, (tp, b, ref_bytes)
print("ALL_OK", base)
"""


@pytest.mark.slow
def test_tp_decode_parity_and_cache_sharding_8dev():
    """tp=1/2/4 produce bit-identical greedy tokens on the same prompt;
    per-device KV bytes scale as 1/TP; chunked prefill, slot decode and
    per-shard export agree with the single-device paths."""
    _run_script(TP_PARITY_SCRIPT)


TP_CLUSTER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_smoke_config
from repro.models import Model
from repro.runtime import Membership
from repro.serve import Request, ServeCluster

cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

def run(tp, *, lose_device=False, fail_owner=False, nodes=4, prompt_len=10):
    m = Membership(t_q=60.0, now=lambda: 0.0)
    for i in range(nodes):
        m.request_join(f"10.3.0.{i}", 7000 + i)
    cl = ServeCluster(m, model, params, slots=8, max_len=64, tp=tp)
    rng = np.random.default_rng(0)
    reqs = [Request(f"s{i}",
                    rng.integers(0, cfg.vocab, prompt_len, dtype=np.int32),
                    max_new_tokens=8) for i in range(6)]
    for r in reqs:
        cl.submit(r)
    for _ in range(2):
        cl.step()
    if lose_device:
        # partial-group loss: kill ONE device of an in-use group -> the
        # whole replica dies and its sessions migrate to a healthy group
        node, devs = next(iter(cl.supervisor._groups.items()))
        assert cl.lose_device(devs[-1]) == node
        assert cl.stats().get("dead_groups", 0) == 1
    if fail_owner:
        m.fail(cl.sessions["s0"].owner)
    cl.run()
    toks = {sid: list(rec.generated) for sid, rec in cl.sessions.items()}
    return toks, cl.stats()

# token parity under churn-free serving, device loss, and 5 nodes on 4
# groups (deterministic group sharing)
base, _ = run(1)
for kw in ({}, {"lose_device": True}, {"nodes": 5}):
    toks, st = run(2, **kw)
    assert toks == base, (kw, toks, base)
    if kw.get("lose_device"):
        assert st["migrated"] >= 1, st

# per-shard KV handoff: long prompts export 2 full chunks per session,
# so a tp=2 owner's death re-homes sessions by fetching BOTH kv-head
# shards of each chunk and reassembling them on the target group
base_l, st1 = run(1, fail_owner=True, prompt_len=40)
tp2_l, st2 = run(2, fail_owner=True, prompt_len=40)
assert tp2_l == base_l
assert st2["handoffs"] >= 1 and st2["handoff_misses"] == 0, st2
assert st1["handoffs"] >= 1, st1
print("ALL_OK")
"""


@pytest.mark.slow
def test_tp_cluster_migration_token_identical_8dev():
    """A 2-group ServeCluster keeps every session's token stream
    bit-identical to tp=1 through normal serving, a partial-group device
    loss, oversubscribed groups, and a per-shard KV-block handoff."""
    _run_script(TP_CLUSTER_SCRIPT)
