"""Training-loop behaviour + checkpoint/restart fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt as ckpt_lib
from repro.configs import get_smoke_config
from repro.data import SyntheticLM, DataConfig
from repro.models import Model
from repro.optim import adamw
from repro.train.step import TrainConfig, make_train_step


def _setup(arch="qwen2.5-3b", microbatches=1):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    tcfg = TrainConfig(opt=adamw.OptConfig(peak_lr=3e-3, warmup_steps=5,
                                           total_steps=50),
                       microbatches=microbatches)
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params, tcfg.opt)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=8, seed=1))
    return model, step, params, opt, data


@pytest.mark.slow
def test_loss_decreases_on_synthetic_bigrams():
    model, step, params, opt, data = _setup()
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_grad_accumulation_equivalence():
    """mb=1 and mb=4 take (nearly) the same step."""
    model, step1, params, opt, data = _setup(microbatches=1)
    _, step4, _, _, _ = _setup(microbatches=4)
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    p1, o1, m1 = step1(jax.tree.map(jnp.copy, params),
                       jax.tree.map(jnp.copy, opt), b)
    p4, o4, m4 = step4(jax.tree.map(jnp.copy, params),
                       jax.tree.map(jnp.copy, opt), b)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-2
    diffs = jax.tree.map(
        lambda a, b2: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                            - b2.astype(jnp.float32)))),
        p1, p4)
    assert max(jax.tree.leaves(diffs)) < 5e-2


def test_ckpt_roundtrip_and_integrity(tmp_path):
    model, step, params, opt, data = _setup()
    state = {"params": params, "opt": opt}
    path = ckpt_lib.save(str(tmp_path), 7, state)
    assert ckpt_lib.latest_step(str(tmp_path)) == 7
    restored = ckpt_lib.restore(str(tmp_path), 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # corrupt a shard -> restore must fail loudly
    shard = os.path.join(path, "shard_0.npz")
    with open(shard, "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x01\x02")
    with pytest.raises(IOError):
        ckpt_lib.restore(str(tmp_path), 7, state)


def test_int8_grad_compression_trains():
    cfg = get_smoke_config("qwen2.5-3b")
    model = Model(cfg)
    tcfg = TrainConfig(opt=adamw.OptConfig(peak_lr=3e-3, warmup_steps=2,
                                           total_steps=20),
                       grad_compression="int8")
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params, tcfg.opt)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=8, seed=1))
    losses = []
    for i in range(12):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all() and losses[-1] < losses[0]
