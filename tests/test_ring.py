"""Consistent-hashing ring invariants (paper §III)."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.ring import (RING_SIZE, RoutingTable, build_ring, hash_id,
                             in_interval, ring_distance)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=RING_SIZE - 1),
                min_size=2, max_size=200, unique=True),
       st.integers(min_value=0, max_value=RING_SIZE - 1))
def test_successor_owns_key(ids, key):
    t = RoutingTable(ids)
    owner = t.successor_of(key)
    # no peer lies strictly between the key and its owner (clockwise)
    for p in t.ids:
        if p != owner:
            assert not in_interval(p, key - 1, owner, inclusive_hi=False) \
                or p == key
    # bisect semantics: owner is the first id >= key, else wraps to min
    ge = [p for p in t.ids if p >= key]
    assert owner == (min(ge) if ge else min(t.ids))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=RING_SIZE - 1),
                min_size=3, max_size=100, unique=True))
def test_succ_pred_inverse(ids):
    t = RoutingTable(ids)
    for p in t.ids[:10]:
        assert t.pred(t.succ(p, 1), 1) == p
        assert t.succ(p, len(t)) == p          # full loop


def test_stretch_covers_ring():
    t = build_ring(17, seed=3)
    p = t.ids[0]
    s = t.stretch(p, len(t) - 1)
    assert sorted(s) == sorted(t.ids)


def test_ring_distance_wraps():
    assert ring_distance(RING_SIZE - 1, 0) == 1
    assert ring_distance(0, RING_SIZE - 1) == RING_SIZE - 1


def test_hash_deterministic():
    assert hash_id("abc") == hash_id("abc")
    assert hash_id("abc") != hash_id("abd")


def test_add_remove_membership():
    t = build_ring(32, seed=0)
    pid = t.ids[5]
    assert pid in t
    assert t.remove(pid)
    assert pid not in t
    assert not t.remove(pid)
    assert t.add(pid)
    assert pid in t
