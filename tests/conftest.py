def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    # REPRO_SANITIZE=1: run the WHOLE suite with the runtime invariant
    # sanitizer installed (RingState monotonicity + lookup oracle,
    # BlockStore replication/tombstones, Replica slot conservation) —
    # the CI `sanitize` job sets it; see src/repro/analysis/sanitize.py
    # and DESIGN.md §14.
    from repro.analysis import sanitize
    if sanitize.enabled():
        sanitize.install()


def pytest_unconfigure(config):
    from repro.analysis import sanitize
    sanitize.uninstall()
