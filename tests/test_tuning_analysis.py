"""Validation of the paper's closed forms against its published numbers."""
import math

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import analysis as A
from repro.core.tuning import (EdraParams, event_rate, max_buffered_events,
                               rho, theta)

# C2: D1HT per-peer maintenance bandwidth at n=1e6 (paper §VIII)
PAPER_C2 = {60: 20.7e3, 169: 7.3e3, 174: 7.1e3, 780: 1.6e3}


@pytest.mark.parametrize("mins,expected", sorted(PAPER_C2.items()))
def test_c2_paper_bandwidth_numbers(mins, expected):
    got = A.d1ht_bandwidth(10**6, mins * 60)
    assert abs(got - expected) / expected < 0.05, (mins, got, expected)


def test_c3_orderings_at_scale():
    """D1HT lowest; ~10x below 1h-Calot and OneHop slice leaders at 1e6;
    ~OneHop ordinary nodes (paper §VIII)."""
    n, s = 10**6, 169 * 60
    d1 = A.d1ht_bandwidth(n, s)
    ca = A.calot_bandwidth(n, s)
    oh = A.onehop_bandwidth(n, s)
    assert d1 < ca and d1 < oh.slice_leader_bps
    assert ca / d1 > 10 and oh.slice_leader_bps / d1 > 10
    assert 0.5 < oh.ordinary_bps / d1 < 2.0
    assert oh.slice_leader_bps > 140e3 * 0.95   # "above 140 kbps"
    assert ca > 140e3 * 0.9


def test_calot_at_least_twice_d1ht_from_small_n():
    """Paper: 1h-Calot overheads at least 2x D1HT (for n >= ~1e4)."""
    for n in (10**4, 10**5, 10**6, 10**7):
        assert A.calot_bandwidth(n, 169 * 60) > \
            2 * A.d1ht_bandwidth(n, 169 * 60)


def test_c4_quarantine_reductions():
    """~24% (KAD) / ~31% (Gnutella) asymptotically, growing with n."""
    kad = A.quarantine_reduction(10**7, 169 * 60, 0.24)
    gnu = A.quarantine_reduction(10**7, 174 * 60, 0.31)
    assert abs(kad - 0.24) < 0.03
    assert abs(gnu - 0.31) < 0.03
    small = A.quarantine_reduction(10**4, 169 * 60, 0.24)
    assert small < kad    # reduction grows with system size (Fig. 8)


def test_fasttrack_supernode_example():
    """§III: 40K SNs, 2.5h sessions -> ~1 kbps per SN."""
    b = A.d1ht_bandwidth(40_000, 2.5 * 3600)
    assert 0.7e3 < b < 1.3e3


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=16, max_value=10**7),
       st.floats(min_value=600, max_value=10**5))
def test_theta_positive_and_monotone_in_savg(n, s_avg):
    th = theta(n, s_avg)
    assert th > 0
    assert theta(n, s_avg * 2) > th            # calmer system -> more buffering


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=16, max_value=10**7))
def test_eq_iv4_consistency(n):
    """E ~= r * Theta at the operating point (the paper derives E from
    r = E/Theta)."""
    s_avg = 169 * 60
    e = max_buffered_events(n)
    r = event_rate(n, s_avg)
    th = theta(n, s_avg)
    assert math.isclose(e, r * th, rel_tol=1e-9)


def test_n_msgs_between_1_and_rho():
    for n in (100, 10**4, 10**6):
        r = event_rate(n, 169 * 60)
        th = theta(n, 169 * 60)
        nm = A.n_msgs(n, r, th)
        assert 1.0 <= nm <= rho(n)


def test_retune_tracks_observed_rate():
    p = EdraParams.derive(1000, 174 * 60)
    p2 = p.retune(observed_n=1000, observed_r=p.r * 4)  # 4x churn
    assert p2.theta < p.theta                            # buffer less
