"""Data pipeline determinism + serving replica behaviour."""
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.models import Model
from repro.runtime import Membership, Placement
from repro.serve import Replica, Request, SessionRouter


def test_pipeline_deterministic_and_host_sharded():
    base = dict(vocab=512, seq_len=64, global_batch=8, seed=9)
    a = SyntheticLM(DataConfig(**base)).batch(3)
    b = SyntheticLM(DataConfig(**base)).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    h0 = SyntheticLM(DataConfig(**base, host_index=0, host_count=2)).batch(3)
    h1 = SyntheticLM(DataConfig(**base, host_index=1, host_count=2)).batch(3)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_prefetcher_order():
    it = iter([{"x": np.array([i])} for i in range(5)])
    out = [b["x"][0] for b in Prefetcher(it, depth=2)]
    assert out == [0, 1, 2, 3, 4]


def test_session_router_matches_placement():
    m = Membership()
    for i in range(16):
        m.request_join(f"10.1.0.{i}", 7000)
    router = SessionRouter(m)
    p = Placement(m.table)
    sids = [f"sess-{i}" for i in range(64)]
    routed = router.route(sids)
    for sid, node in zip(sids, routed):
        assert node in m.members()
    # stability: same input -> same routing
    assert routed == router.route(sids)


@pytest.mark.slow
def test_replica_admit_and_decode():
    import jax
    cfg = get_smoke_config("qwen2.5-3b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rep = Replica(model, slots=2, max_len=32)
    rep.attach_params(params)
    rng = np.random.default_rng(0)
    t1 = rep.admit(Request("a", rng.integers(0, cfg.vocab, 8, dtype=np.int32)))
    t2 = rep.admit(Request("b", rng.integers(0, cfg.vocab, 8, dtype=np.int32)))
    assert 0 <= t1 < cfg.vocab and 0 <= t2 < cfg.vocab
    outs = rep.decode_round()
    assert set(outs) == {"a", "b"}
    for v in outs.values():
        assert 0 <= v < cfg.vocab
    rep.evict("a")
    assert set(rep.decode_round()) == {"b"}
