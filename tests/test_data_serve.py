"""Data pipeline determinism + serving replica behaviour."""
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.models import Model
from repro.runtime import Membership, Placement
from repro.serve import Replica, Request, SessionRouter


def test_pipeline_deterministic_and_host_sharded():
    base = dict(vocab=512, seq_len=64, global_batch=8, seed=9)
    a = SyntheticLM(DataConfig(**base)).batch(3)
    b = SyntheticLM(DataConfig(**base)).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    h0 = SyntheticLM(DataConfig(**base, host_index=0, host_count=2)).batch(3)
    h1 = SyntheticLM(DataConfig(**base, host_index=1, host_count=2)).batch(3)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_prefetcher_order():
    it = iter([{"x": np.array([i])} for i in range(5)])
    out = [b["x"][0] for b in Prefetcher(it, depth=2)]
    assert out == [0, 1, 2, 3, 4]


def test_session_router_matches_placement():
    m = Membership()
    for i in range(16):
        m.request_join(f"10.1.0.{i}", 7000)
    router = SessionRouter(m)
    p = Placement(m.table)
    sids = [f"sess-{i}" for i in range(64)]
    routed = router.route(sids)
    for sid, node in zip(sids, routed):
        assert node in m.members()
    # stability: same input -> same routing
    assert routed == router.route(sids)
    # full-64-bit agreement with the placement oracle
    assert routed == [p.session_owner(s) for s in sids]


def test_session_router_no_32bit_truncation_collision():
    """Regression: two peers sharing the same top 32 bits must stay
    distinct routing targets (the old router truncated IDs to hi words)."""
    m = Membership()
    a = (0x1234ABCD << 32) | 0x00000010
    b = (0x1234ABCD << 32) | 0x00F00000   # same hi word, different lo
    m.admit(a, ("10.9.0.1", 7000))
    m.admit(b, ("10.9.0.2", 7000))
    router = SessionRouter(m)
    state = m.ring_state
    # keys straddling the two peers: key just above a must route to b,
    # key at/below a must route to a
    assert state.lookup(np.asarray([a - 1], np.uint64))[0] == a
    assert state.lookup(np.asarray([a + 1], np.uint64))[0] == b
    assert state.lookup(np.asarray([b + 1], np.uint64))[0] == a  # wrap
    # and real session routing agrees with the 64-bit oracle (under the
    # old hi-word truncation a and b were the SAME table entry, so keys
    # in the (a, b] arc were misrouted to a)
    sids = [f"collide-{i}" for i in range(256)]
    routed = router.route(sids)
    from repro.core.ring import hash_id
    want = [m.table.successor_of(hash_id(f"session/{s}")) for s in sids]
    assert routed == want


def test_session_router_caches_device_table_across_batches():
    """Acceptance: 100 consecutive batches against an unchanged 10^4-peer
    membership reuse ONE uploaded device table, and results match the
    pure-Python RoutingTable.successor_of oracle on full 64-bit IDs."""
    from repro.core.ring import build_ring, hash_id

    ring = build_ring(10_000, seed=4)
    m = Membership()
    m.table = ring                      # adopt the prebuilt shared state
    m.ring_state = ring.state
    router = SessionRouter(m)
    assert router.uploads == 0
    seen = []
    for batch in range(100):
        sids = [f"s{batch}-{i}" for i in range(32)]
        routed = router.route(sids)
        seen.append((sids, routed))
        assert router.uploads == 1      # single upload, reused 100x
    for sids, routed in seen[:5] + seen[-5:]:
        want = [ring.successor_of(hash_id(f"session/{s}")) for s in sids]
        assert routed == want
    # a membership event invalidates exactly once
    nid = m.request_join("10.77.0.1", 7000)
    routed = router.route(["post-churn"])
    assert router.uploads == 2
    assert routed[0] in m.members()


@pytest.mark.slow
def test_replica_admit_and_decode():
    import jax
    cfg = get_smoke_config("qwen2.5-3b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rep = Replica(model, slots=2, max_len=32)
    rep.attach_params(params)
    rng = np.random.default_rng(0)
    t1 = rep.admit(Request("a", rng.integers(0, cfg.vocab, 8, dtype=np.int32)))
    t2 = rep.admit(Request("b", rng.integers(0, cfg.vocab, 8, dtype=np.int32)))
    assert 0 <= t1 < cfg.vocab and 0 <= t2 < cfg.vocab
    outs = rep.decode_round()
    assert set(outs) == {"a", "b"}
    for v in outs.values():
        assert 0 <= v < cfg.vocab
    rep.evict("a")
    assert set(rep.decode_round()) == {"b"}


def test_decode_bucket_shapes_bounded():
    """Active-slot batches pad to powers of two capped at the slot
    count: any session mix maps onto log2(slots)+1 decode shapes."""
    from repro.serve.server import _decode_bucket
    assert [_decode_bucket(a, 8) for a in range(1, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 8]
    assert _decode_bucket(3, 16) == 4
    assert {_decode_bucket(a, 16) for a in range(1, 17)} == {1, 2, 4, 8, 16}


@pytest.mark.slow
def test_bucketized_decode_matches_full_slab():
    """Bucketized decode (gather active rows, step, scatter KV back)
    must be token-identical to decoding the session alone on a fresh
    replica, and padded rows must never corrupt inactive slots."""
    import jax
    cfg = get_smoke_config("qwen2.5-3b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = {s: rng.integers(0, cfg.vocab, 6 + 2 * i, dtype=np.int32)
               for i, s in enumerate("abc")}

    rep = Replica(model, slots=8, max_len=32)     # 3 of 8 slots -> bucket 4
    rep.attach_params(params)
    toks = {s: [rep.admit(Request(s, p))] for s, p in prompts.items()}
    for _ in range(4):
        for s, t in rep.decode_round().items():
            toks[s].append(t)
    rep.evict("b")                                # 2 active -> bucket 2
    for _ in range(2):
        for s, t in rep.decode_round().items():
            toks[s].append(t)

    for s in "ac":                                # solo oracle, bucket 1
        solo = Replica(model, slots=8, max_len=32)
        solo.attach_params(params)
        want = [solo.admit(Request(s, prompts[s]))]
        for _ in range(6):
            want.append(solo.decode_round()[s])
        assert toks[s] == want, f"session {s} diverged under bucketing"
