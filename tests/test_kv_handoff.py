"""DHT-backed KV data plane on the serve path (DESIGN.md §11):
cache-handoff migration and the cross-session prefix cache.

The acceptance properties (ISSUE 7):

  * ``admit_from_blocks`` is bit-faithful: admitting from exported KV
    blocks returns the SAME first token — and the same decode stream —
    as a from-scratch admit (the imported cache is byte-identical to
    what the replica would have computed);
  * a node kill turns migration into a cache handoff (``handoffs`` > 0,
    ``handoff_us`` recorded in the trace) with token-identical output
    through the boundary; a total block miss falls back to re-prefill
    with the same output;
  * a prefix-cache hit skips the shared chunks' prefill calls entirely
    while still producing token-identical decode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.ringstate import RingState
from repro.dht.data import BlockStore, PrefixCache
from repro.models import Model
from repro.runtime import Membership
from repro.serve import Replica, Request, ServeCluster

CHUNK = 8


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _membership(n, t):
    m = Membership(t_q=60.0, now=lambda: t[0])
    for i in range(n):
        m.request_join(f"10.4.0.{i}", 7100 + i)
    return m


def _requests(cfg, count, *, max_new=8, seed=0):
    """Prompts of 9..21 tokens: every session crosses at least one
    CHUNK=8 boundary, so its KV chunks are exported into the store."""
    rng = np.random.default_rng(seed)
    return [Request(f"h{i}",
                    rng.integers(0, cfg.vocab, 9 + (i % 5) * 3,
                                 dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(count)]


def _reference_tokens(model, params, prompt, steps, max_len):
    cache = model.init_cache(1, max_len)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache)
    toks = [int(jnp.argmax(logits[0]))]
    dec = jax.jit(model.decode_step)
    length = len(prompt)
    for _ in range(steps - 1):
        logits, cache = dec(params, cache,
                            jnp.asarray([[toks[-1]]], jnp.int32),
                            jnp.asarray([length], jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
        length += 1
    return toks


def _prefix_store():
    state = RingState()
    for i in range(4):
        state.add((i + 1) * (2**64 // 5))
    return BlockStore(state, replication=2)


# ---------------------------------------------------------------------------
# replica-level block export/import
# ---------------------------------------------------------------------------

def test_admit_from_blocks_matches_admit(smoke_model):
    """Export a 20-token session's two full chunks from one replica,
    admit from them on another: first token and every decode after it
    match a from-scratch admit exactly."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, cfg.vocab, 20, dtype=np.int32)
    a = Replica(model, slots=2, max_len=48, prefill_chunk=CHUNK)
    a.attach_params(params)
    tok_a = a.admit(Request("x", prompt, max_new_tokens=6))
    blocks = [a.export_block("x", j) for j in range(20 // CHUNK)]
    assert all(b.shape == model.kv_block_shape(CHUNK) for b in blocks)

    b = Replica(model, slots=2, max_len=48, prefill_chunk=CHUNK)
    b.attach_params(params)
    tok_b = b.admit_from_blocks(Request("x", prompt, max_new_tokens=6),
                                blocks)
    assert tok_b == tok_a
    assert b.import_us > 0.0
    stream_a = [tok_a] + [a.decode_round()["x"] for _ in range(5)]
    stream_b = [tok_b] + [b.decode_round()["x"] for _ in range(5)]
    want = _reference_tokens(model, params, prompt, 6, 48)
    assert stream_a == want and stream_b == want


def test_admit_from_blocks_guards(smoke_model):
    cfg, model, params = smoke_model
    rng = np.random.default_rng(22)
    prompt = rng.integers(0, cfg.vocab, 16, dtype=np.int32)
    a = Replica(model, slots=2, max_len=48, prefill_chunk=CHUNK)
    a.attach_params(params)
    a.admit(Request("x", prompt))
    blocks = [a.export_block("x", 0), a.export_block("x", 1)]
    b = Replica(model, slots=2, max_len=48, prefill_chunk=CHUNK)
    b.attach_params(params)
    with pytest.raises(ValueError):
        # 2 blocks cover positions [0,16) == the whole 16-token prompt:
        # the final segment would never run, so no logits to admit with
        b.admit_from_blocks(Request("y", prompt), blocks)
    # no blocks degrades to a plain admit
    assert b.admit_from_blocks(Request("y", prompt), []) == \
        a.admit(Request("z", prompt))
    # a failed import (garbage block) leaks no slot
    free_before = b.num_free
    with pytest.raises(Exception):
        b.admit_from_blocks(Request("w", prompt),
                            [np.zeros((3, 3), np.float32)])
    assert b.num_free == free_before
    assert "w" not in b.sessions


# ---------------------------------------------------------------------------
# cluster-level cache-handoff migration
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_kill_migrates_via_cache_handoff(smoke_model):
    """A replica kill re-homes its sessions by FETCHING their KV chunks
    from the block store — not recomputing them — with token-identical
    decode through the boundary and the transfer time split out of the
    trace as ``handoff_us``."""
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(5, t)
    cluster = ServeCluster(m, model, params, slots=16, max_len=64,
                           prefill_chunk=CHUNK)
    assert cluster.blocks is not None      # auto-on for this family
    for r in _requests(cfg, 10, max_new=8):
        cluster.submit(r)
    assert cluster.exported_blocks > 0     # prompt chunks replicated

    by_owner = {}
    for rec in cluster.sessions.values():
        by_owner.setdefault(rec.owner, []).append(rec)
    victim = max(by_owner, key=lambda o: len(by_owner[o]))
    moved = [rec.session_id for rec in by_owner[victim]]
    m.fail(victim)

    assert cluster.handoffs >= 1
    assert cluster.handoff_chunks >= 1
    handed = [sid for sid in moved if cluster.traces[sid].handoff_us > 0]
    assert handed, "no migrated session recorded handoff transfer time"
    cluster.run()
    for rec in cluster.sessions.values():
        want = _reference_tokens(model, params, rec.prompt, 8, 64)
        assert rec.generated == want, f"{rec.session_id} diverged"
    report = cluster.latency_report()
    assert report["handoff_us_mean"] > 0
    stats = cluster.stats()
    assert stats["handoffs"] == cluster.handoffs
    assert stats["block_upload_bytes"] > 0


@pytest.mark.slow
def test_handoff_miss_falls_back_to_reprefill(smoke_model):
    """Every stored block of the victim's sessions is dropped before the
    kill: the handoff misses, the re-prefill path takes over, and the
    output is still token-identical."""
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(5, t)
    cluster = ServeCluster(m, model, params, slots=16, max_len=64,
                           prefill_chunk=CHUNK, prefix_cache=False)
    for r in _requests(cfg, 8, max_new=8, seed=3):
        cluster.submit(r)
    by_owner = {}
    for rec in cluster.sessions.values():
        by_owner.setdefault(rec.owner, []).append(rec)
    victim = max(by_owner, key=lambda o: len(by_owner[o]))
    for rec in by_owner[victim]:
        for j in range(rec.exported_chunks):
            cluster.blocks.remove(cluster._block_name(rec.session_id, j))
    m.fail(victim)
    assert cluster.handoff_misses >= 1
    cluster.run()
    for rec in cluster.sessions.values():
        want = _reference_tokens(model, params, rec.prompt, 8, 64)
        assert rec.generated == want, f"{rec.session_id} diverged"


@pytest.mark.slow
def test_completed_sessions_reclaim_their_blocks(smoke_model):
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(4, t)
    cluster = ServeCluster(m, model, params, slots=8, max_len=64,
                           prefill_chunk=CHUNK, prefix_cache=False)
    for r in _requests(cfg, 4, max_new=4, seed=5):
        cluster.submit(r)
    cluster.run()
    for rec in cluster.sessions.values():
        assert rec.exported_chunks == 0
        assert not cluster.blocks.contains(
            cluster._block_name(rec.session_id, 0))


def test_kv_blocks_opt_out_and_guard(smoke_model):
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(4, t)
    off = ServeCluster(m, model, params, slots=4, max_len=64,
                       prefill_chunk=CHUNK, kv_blocks=False)
    assert off.blocks is None and off.prefix is None
    with pytest.raises(ValueError):
        ServeCluster(m, model, params, slots=4, max_len=64,
                     prefill_chunk=None, kv_blocks=True)


# ---------------------------------------------------------------------------
# cross-session prefix cache
# ---------------------------------------------------------------------------

def test_prefix_hit_skips_prefill_chunks(smoke_model):
    """Second session sharing a 20-token prompt imports the two full
    chunks instead of computing them: one segment call instead of three,
    same tokens."""
    cfg, model, params = smoke_model
    pc = PrefixCache(_prefix_store(), chunk=CHUNK, salt=cfg.name)
    rep = Replica(model, slots=4, max_len=48, prefill_chunk=CHUNK,
                  prefix_cache=pc)
    rep.attach_params(params)
    calls = [0]
    inner = rep._prefill_chunk

    def counting(params_, seg, one, off):
        calls[0] += 1
        return inner(params_, seg, one, off)

    rep._prefill_chunk = counting
    rng = np.random.default_rng(31)
    prompt = rng.integers(0, cfg.vocab, 20, dtype=np.int32)
    tok1 = rep.admit(Request("p1", prompt, max_new_tokens=5))
    assert calls[0] == 3                   # padded 24 / chunk 8
    calls[0] = 0
    tok2 = rep.admit(Request("p2", prompt, max_new_tokens=5))
    assert calls[0] == 1                   # only the final segment ran
    assert tok2 == tok1
    assert pc.hits == 2 and pc.tokens_saved == 16
    want = _reference_tokens(model, params, prompt, 5, 48)
    streams = {"p1": [tok1], "p2": [tok2]}
    for _ in range(4):
        for sid, tok in rep.decode_round().items():
            streams[sid].append(tok)
    assert streams["p1"] == want and streams["p2"] == want


@pytest.mark.slow
def test_cluster_prefix_cache_shares_system_prompt(smoke_model):
    """Cluster-wide: sessions landing on DIFFERENT owners still share
    the prefix KV through the replicated store."""
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(5, t)
    cluster = ServeCluster(m, model, params, slots=8, max_len=64,
                           prefill_chunk=CHUNK)
    rng = np.random.default_rng(41)
    system = rng.integers(0, cfg.vocab, 16, dtype=np.int32)
    prompts = {}
    for i in range(6):
        tail = rng.integers(0, cfg.vocab, 3 + i, dtype=np.int32)
        prompts[f"sys{i}"] = np.concatenate([system, tail])
    for sid, p in prompts.items():
        cluster.submit(Request(sid, p, max_new_tokens=4))
    assert len({rec.owner for rec in cluster.sessions.values()}) > 1
    assert cluster.prefix.hits > 0
    assert cluster.prefix.tokens_saved >= CHUNK
    cluster.run()
    for sid, p in prompts.items():
        want = _reference_tokens(model, params, p, 4, 64)
        assert cluster.sessions[sid].generated == want, f"{sid} diverged"
    assert cluster.stats()["prefix_hits"] == cluster.prefix.hits
