"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ring_lookup.kernel import BW
from repro.kernels.ring_lookup.ops import (ring_lookup, ring_lookup64,
                                           ring_lookup_bucketed)
from repro.kernels.ring_lookup.ref import (ring_lookup64_ref,
                                           ring_lookup_bucketed_ref,
                                           ring_lookup_ref)
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n,q", [(7, 3), (100, 257), (4096, 1024),
                                 (50_000, 2048)])
def test_ring_lookup_sweep(n, q):
    table = np.sort(RNG.choice(2**32 - 1, size=n, replace=False)
                    ).astype(np.uint32)
    keys = RNG.integers(0, 2**32, size=q, dtype=np.uint32)
    got = ring_lookup(jnp.asarray(keys), jnp.asarray(table))
    want = ring_lookup_ref(jnp.asarray(keys), jnp.asarray(table))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _split64(x):
    return ((x >> np.uint64(32)).astype(np.uint32),
            (x & np.uint64(0xFFFFFFFF)).astype(np.uint32))


@pytest.mark.parametrize("n,q,cap", [(7, 3, 2048), (500, 257, 2048),
                                     (4096, 1024, 8192)])
def test_ring_lookup64_sweep(n, q, cap):
    """Two-word kernel vs numpy uint64 searchsorted on a capacity-padded
    table, including IDs that collide in their top 32 bits."""
    base = RNG.integers(0, 2**64, size=n, dtype=np.uint64)
    base[1::4] = (base[0::4][: base[1::4].size] | np.uint64(1))  # same-hi pairs
    table = np.sort(np.unique(base))
    n_live = table.size
    keys = np.concatenate([
        RNG.integers(0, 2**64, size=q, dtype=np.uint64),
        table[:16], table[:16] + np.uint64(1)])
    want = (np.searchsorted(table, keys, side="left") % n_live).astype(np.int32)
    thi = np.zeros(cap, np.uint32)
    tlo = np.zeros(cap, np.uint32)
    thi[:n_live], tlo[:n_live] = _split64(table)
    khi, klo = _split64(keys)
    narr = jnp.asarray([n_live], jnp.int32)
    got = ring_lookup64(jnp.asarray(khi), jnp.asarray(klo),
                        jnp.asarray(thi), jnp.asarray(tlo), narr)
    ref = ring_lookup64_ref(jnp.asarray(khi), jnp.asarray(klo),
                            jnp.asarray(thi), jnp.asarray(tlo), narr)
    np.testing.assert_array_equal(np.asarray(got), want)
    np.testing.assert_array_equal(np.asarray(ref), want)


def test_ring_lookup64_no_recompile_on_churn():
    """Same capacity, different live count -> one jit trace (static shapes)."""
    cap, q = 2048, 256
    keys = RNG.integers(0, 2**64, size=q, dtype=np.uint64)
    khi, klo = _split64(keys)
    traces = []
    for n_live in (100, 101, 612):
        table = np.sort(np.unique(
            RNG.integers(0, 2**64, size=n_live, dtype=np.uint64)))
        thi = np.zeros(cap, np.uint32)
        tlo = np.zeros(cap, np.uint32)
        thi[:table.size], tlo[:table.size] = _split64(table)
        narr = jnp.asarray([table.size], jnp.int32)
        got = ring_lookup64(jnp.asarray(khi), jnp.asarray(klo),
                            jnp.asarray(thi), jnp.asarray(tlo), narr)
        want = (np.searchsorted(table, keys) % table.size).astype(np.int32)
        np.testing.assert_array_equal(np.asarray(got), want)
        traces.append(ring_lookup64._cache_size())
    assert traces[0] == traces[-1]  # no new trace after the first call


def _bucket_arrays(table: np.ndarray, bits: int):
    """Radix-bucketized view of a sorted uint64 table (DESIGN.md §7):
    (2^bits, BW) rows of sorted in-bucket entries with every slack slot
    holding the bucket's successor id."""
    nb = 1 << bits
    edges = np.arange(nb, dtype=np.uint64) << np.uint64(64 - bits)
    starts = np.searchsorted(table, edges)
    ends = np.append(starts[1:], table.size)
    occ = (ends - starts).astype(np.int32)
    assert occ.max() < BW
    pad = table[ends % table.size]
    j = np.arange(BW)[None, :]
    idx = np.minimum(starts[:, None] + j, table.size - 1)
    vals = np.where(j < occ[:, None], table[idx], pad[:, None])
    hi, lo = _split64(vals)
    return hi, lo, occ


@pytest.mark.parametrize("n,q,bits", [(5, 64, 6), (500, 257, 6),
                                      (4096, 1024, 8), (50_000, 2048, 11)])
def test_ring_lookup_bucketed_sweep(n, q, bits):
    """Bucketized kernel vs numpy uint64 searchsorted, including same-hi
    word pairs and the exact ownership boundaries."""
    base = RNG.integers(0, 2**64, size=n, dtype=np.uint64)
    base[1::4] = (base[0::4][: base[1::4].size] | np.uint64(1))
    table = np.sort(np.unique(base))
    keys = np.concatenate([
        RNG.integers(0, 2**64, size=q, dtype=np.uint64),
        table[:16], table[:16] + np.uint64(1), table[:16] - np.uint64(1),
        np.array([0, 2**64 - 1], np.uint64)])
    want = table[np.searchsorted(table, keys) % table.size]
    bhi, blo, occ = _bucket_arrays(table, bits)
    khi, klo = _split64(keys)
    args = (jnp.asarray(khi), jnp.asarray(klo), jnp.asarray(bhi),
            jnp.asarray(blo), jnp.asarray(occ))
    ohi, olo = ring_lookup_bucketed(*args)
    got = (np.asarray(ohi).astype(np.uint64) << np.uint64(32)) \
        | np.asarray(olo).astype(np.uint64)
    np.testing.assert_array_equal(got, want)
    rhi, rlo = ring_lookup_bucketed_ref(*args)
    ref = (np.asarray(rhi).astype(np.uint64) << np.uint64(32)) \
        | np.asarray(rlo).astype(np.uint64)
    np.testing.assert_array_equal(ref, want)


def test_ring_lookup_bucketed_no_recompile_on_churn():
    """Same directory size, different row contents/occupancy -> one jit
    trace: membership churn only moves data."""
    bits, q = 7, 128
    keys = RNG.integers(0, 2**64, size=q, dtype=np.uint64)
    khi, klo = _split64(keys)
    traces = []
    for n_live in (900, 901, 2500):
        table = np.sort(np.unique(
            RNG.integers(0, 2**64, size=n_live, dtype=np.uint64)))
        bhi, blo, occ = _bucket_arrays(table, bits)
        ohi, olo = ring_lookup_bucketed(
            jnp.asarray(khi), jnp.asarray(klo), jnp.asarray(bhi),
            jnp.asarray(blo), jnp.asarray(occ))
        got = (np.asarray(ohi).astype(np.uint64) << np.uint64(32)) \
            | np.asarray(olo).astype(np.uint64)
        want = table[np.searchsorted(table, keys) % table.size]
        np.testing.assert_array_equal(got, want)
        traces.append(ring_lookup_bucketed._cache_size())
    assert traces[0] == traces[-1]  # no new trace after the first call


def test_ring_lookup_boundary_keys():
    table = np.sort(RNG.choice(2**32 - 1, size=64, replace=False)
                    ).astype(np.uint32)
    keys = np.concatenate([table, table + 1, table - 1,
                           [0, 2**32 - 1]]).astype(np.uint32)
    got = ring_lookup(jnp.asarray(keys), jnp.asarray(table))
    want = ring_lookup_ref(jnp.asarray(keys), jnp.asarray(table))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,sq,sk,h,hkv,hd,causal,dtype", [
    (2, 128, 128, 4, 2, 128, True, jnp.float32),
    (1, 256, 256, 8, 8, 64, True, jnp.float32),
    (2, 128, 256, 8, 2, 128, False, jnp.float32),
    (1, 128, 128, 4, 1, 128, True, jnp.bfloat16),
])
def test_flash_attention_sweep(b, sq, sk, h, hkv, hd, causal, dtype):
    q = jnp.asarray(RNG.standard_normal((b, sq, h, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, sk, hkv, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, sk, hkv, hd)), dtype)
    got = flash_attention(q, k, v, causal=causal)
    want = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - want.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("b,h,hkv,hd,s,dtype", [
    (2, 8, 2, 128, 512, jnp.float32),
    (1, 16, 16, 64, 256, jnp.float32),
    (4, 8, 1, 128, 1024, jnp.bfloat16),
])
def test_decode_attention_sweep(b, h, hkv, hd, s, dtype):
    q = jnp.asarray(RNG.standard_normal((b, h, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, hd)), dtype)
    length = jnp.asarray(RNG.integers(1, s, size=(b,)), jnp.int32)
    got = decode_attention(q, k, v, length)
    want = decode_attention_ref(q, k, v, length)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - want.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("bb,l,din,n", [(2, 64, 256, 16), (1, 128, 512, 8),
                                        (3, 32, 256, 4)])
def test_ssm_scan_sweep(bb, l, din, n):
    x = jnp.asarray(RNG.standard_normal((bb, l, din)) * 0.1, jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((bb, l, din))) * 0.1,
                     jnp.float32)
    B = jnp.asarray(RNG.standard_normal((bb, l, n)) * 0.5, jnp.float32)
    C = jnp.asarray(RNG.standard_normal((bb, l, n)) * 0.5, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.standard_normal((din, n))) - 0.1, jnp.float32)
    D = jnp.ones((din,), jnp.float32)
    h0 = jnp.asarray(RNG.standard_normal((bb, din, n)) * 0.1, jnp.float32)
    y1, h1 = ssm_scan(x, dt, B, C, A, D, h0)
    y2, h2 = ssm_scan_ref(x, dt, B, C, A, D, h0)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-4


# ---------------------------------------------------------------------------
# Compiled <-> interpret parity: on an accelerator, the Mosaic-compiled
# kernel must agree with the interpreter that every oracle test above
# runs against.  The whole class self-skips on CPU-only hosts, where
# interpret IS the only execution path and parity is vacuous.
# ---------------------------------------------------------------------------

from repro.kernels.backend import default_interpret, mode_label  # noqa: E402

compiled_only = pytest.mark.skipif(
    default_interpret(),
    reason=f"no compiled backend ({mode_label()}): interpret mode is the "
           "only execution path here, so compiled parity cannot run")


@compiled_only
def test_parity_ring_lookup():
    table = np.sort(RNG.choice(2**32 - 1, size=4096, replace=False)
                    ).astype(np.uint32)
    keys = jnp.asarray(RNG.integers(0, 2**32, size=1024, dtype=np.uint32))
    tbl = jnp.asarray(table)
    np.testing.assert_array_equal(
        np.asarray(ring_lookup(keys, tbl, interpret=False)),
        np.asarray(ring_lookup(keys, tbl, interpret=True)))


@compiled_only
def test_parity_ring_lookup_bucketed():
    table = np.sort(np.unique(
        RNG.integers(0, 2**64, size=2048, dtype=np.uint64)))
    bhi, blo, occ = _bucket_arrays(table, 8)
    khi, klo = _split64(RNG.integers(0, 2**64, size=1024, dtype=np.uint64))
    args = tuple(jnp.asarray(a) for a in (khi, klo, bhi, blo, occ))
    chi, clo = ring_lookup_bucketed(*args, interpret=False)
    ihi, ilo = ring_lookup_bucketed(*args, interpret=True)
    np.testing.assert_array_equal(np.asarray(chi), np.asarray(ihi))
    np.testing.assert_array_equal(np.asarray(clo), np.asarray(ilo))


@compiled_only
def test_parity_edra_tree():
    from repro.kernels.edra_tree.ops import edra_tree
    p, n = 4096, 40_960
    args = tuple(jnp.asarray(a) for a in (
        np.sort(RNG.choice(n, size=p, replace=False)).astype(np.uint32),
        np.full(p, n, np.uint32),
        RNG.integers(0, n, p).astype(np.uint32),
        RNG.uniform(0, 50, p).astype(np.float32),
        RNG.integers(0, 2**32, p, dtype=np.uint64).astype(np.uint32)))
    kw = dict(levels=8, theta=0.25, delta_avg=0.02)
    comp = edra_tree(*args, interpret=False, **kw)
    intp = edra_tree(*args, interpret=True, **kw)
    for c, i in zip(jax.tree_util.tree_leaves(comp),
                    jax.tree_util.tree_leaves(intp)):
        np.testing.assert_allclose(np.asarray(c, np.float64),
                                   np.asarray(i, np.float64), rtol=1e-5)


@compiled_only
def test_parity_decode_attention():
    b, h, hkv, hd, s = 2, 8, 2, 128, 512
    q = jnp.asarray(RNG.standard_normal((b, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, hd)), jnp.float32)
    length = jnp.asarray(RNG.integers(1, s, size=(b,)), jnp.int32)
    comp = decode_attention(q, k, v, length, interpret=False)
    intp = decode_attention(q, k, v, length, interpret=True)
    # both paths accumulate in f32; tolerance covers op-order drift only
    assert float(jnp.max(jnp.abs(comp - intp))) < 1e-5


@compiled_only
def test_parity_flash_attention():
    b, s, h, hkv, hd = 2, 256, 4, 2, 128
    q = jnp.asarray(RNG.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, hd)), jnp.float32)
    comp = flash_attention(q, k, v, causal=True, interpret=False)
    intp = flash_attention(q, k, v, causal=True, interpret=True)
    assert float(jnp.max(jnp.abs(comp - intp))) < 1e-5


@compiled_only
def test_parity_ssm_scan():
    bb, l, din, n = 2, 64, 256, 16
    x = jnp.asarray(RNG.standard_normal((bb, l, din)) * 0.1, jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((bb, l, din))) * 0.1,
                     jnp.float32)
    B = jnp.asarray(RNG.standard_normal((bb, l, n)) * 0.5, jnp.float32)
    C = jnp.asarray(RNG.standard_normal((bb, l, n)) * 0.5, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.standard_normal((din, n))) - 0.1, jnp.float32)
    D = jnp.ones((din,), jnp.float32)
    yc, hc = ssm_scan(x, dt, B, C, A, D, interpret=False)
    yi, hi = ssm_scan(x, dt, B, C, A, D, interpret=True)
    assert float(jnp.max(jnp.abs(yc - yi))) < 1e-4
    assert float(jnp.max(jnp.abs(hc - hi))) < 1e-4


def test_ssm_scan_matches_model_layer():
    """Kernel result == the model's chunked associative-scan path."""
    from repro.models.ssm import _scan_chunks_m1
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("falcon-mamba-7b")
    bb, l, din, n = 2, 64, 256, cfg.ssm_state
    x = jnp.asarray(RNG.standard_normal((bb, l, din)) * 0.1, jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((bb, l, din))) * 0.1,
                     jnp.float32)
    B = jnp.asarray(RNG.standard_normal((bb, l, n)) * 0.5, jnp.float32)
    C = jnp.asarray(RNG.standard_normal((bb, l, n)) * 0.5, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.standard_normal((din, n))) - 0.1, jnp.float32)
    D = jnp.ones((din,), jnp.float32)
    yk, hk = ssm_scan(x, dt, B, C, A, D)
    ym, hm = _scan_chunks_m1(x, dt, B, C, A, D, cfg, None)
    assert float(jnp.max(jnp.abs(yk - ym))) < 1e-4
    assert float(jnp.max(jnp.abs(hk - hm))) < 1e-4
