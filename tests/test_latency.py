"""Latency model checks (paper §VII-D, Figs. 5-6)."""
from repro.dht.latency import dserver_ms, latency_sweep, pastry_ms, single_hop_ms


def test_c6_dserver_saturates_single_hop_flat():
    pts = latency_sweep([800, 1600, 3200, 4000], busy=False)
    d1 = [p.d1ht_ms for p in pts.values()]
    ds = [p.dserver_ms for p in pts.values()]
    # single-hop flat with n; directory server blows up near saturation
    assert max(d1) / min(d1) < 1.01
    assert ds[-1] > 10 * d1[-1]          # "order of magnitude" at 4000
    assert abs(ds[0] - d1[0]) / d1[0] < 1.0   # similar when small


def test_pastry_multihop_worse():
    p = pastry_ms(1600, busy=False, peers_per_node=4)
    s = single_hop_ms(busy=False, peers_per_node=4)
    assert p > 3 * s                      # log4(1600) ~ 5.3 hops


def test_busy_degrades_with_peers_per_node_not_n():
    a = single_hop_ms(busy=True, peers_per_node=4)
    b = single_hop_ms(busy=True, peers_per_node=8)
    assert b > a
