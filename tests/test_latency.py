"""Latency model checks (paper §VII-D, Figs. 5-6)."""
from repro.dht.latency import (DSERVER_SAT_CLIENTS, dserver_ms,
                               latency_sweep, pastry_ms, single_hop_ms)


def test_c6_dserver_saturates_single_hop_flat():
    pts = latency_sweep([800, 1600, 3200, 4000], busy=False)
    d1 = [p.d1ht_ms for p in pts.values()]
    ds = [p.dserver_ms for p in pts.values()]
    # single-hop flat with n; directory server blows up near saturation
    assert max(d1) / min(d1) < 1.01
    assert ds[-1] > 10 * d1[-1]          # "order of magnitude" at 4000
    assert abs(ds[0] - d1[0]) / d1[0] < 1.0   # similar when small


def test_pastry_multihop_worse():
    p = pastry_ms(1600, busy=False, peers_per_node=4)
    s = single_hop_ms(busy=False, peers_per_node=4)
    assert p > 3 * s                      # log4(1600) ~ 5.3 hops



def test_busy_degrades_with_peers_per_node_not_n():
    a = single_hop_ms(busy=True, peers_per_node=4)
    b = single_hop_ms(busy=True, peers_per_node=8)
    assert b > a


def test_dserver_divergence_grows_past_saturation():
    """Regression (ISSUE 5): the old ``min(rho, 0.999)`` clamp froze
    EVERY past-saturation point at the same ~5 ms — n=4000 was
    indistinguishable from n=10^6 and Fig 5a's blow-up was
    unrepresentable.  Finite-window queue growth must keep the
    divergence monotone in n."""
    ms = [dserver_ms(n, busy=False, peers_per_node=n / 400)
          for n in (4000, 10_000, 100_000, 1_000_000)]
    assert ms == sorted(ms), ms
    assert ms[1] > 3 * ms[0]
    assert ms[-1] > 100 * ms[0]


def test_dserver_knee_is_continuous_not_cliff():
    """Crossing the saturation point by 1% must not jump by an order of
    magnitude: the knee residual term keeps the model continuous where
    the measured closed-loop generator is also smooth."""
    mu = DSERVER_SAT_CLIENTS * 30.0
    lo = dserver_ms(int(0.99 * DSERVER_SAT_CLIENTS), busy=False,
                    peers_per_node=8, mu=mu)
    hi = dserver_ms(int(1.01 * DSERVER_SAT_CLIENTS), busy=False,
                    peers_per_node=8, mu=mu)
    assert hi > lo
    assert hi < 10 * lo


def test_dserver_measured_mu_moves_the_knee():
    """The saturation point follows the MEASURED worker rate — the whole
    point of replacing the hardcoded DSERVER_SAT_CLIENTS: with a worker
    twice as fast, n=4000 is comfortably sub-saturation again."""
    fast = dserver_ms(4000, busy=False, peers_per_node=10,
                      mu=2 * DSERVER_SAT_CLIENTS * 30.0)
    slow = dserver_ms(4000, busy=False, peers_per_node=10)
    assert fast < 1.0 < slow


def test_latency_sweep_accepts_measured_fractions():
    """The oracle evaluated at churn-emergent f' (instead of the nominal
    0.01) shifts by exactly the retry-penalty weight."""
    a = latency_sweep([1600], busy=False, d1ht_f=0.0)[1600]
    b = latency_sweep([1600], busy=False, d1ht_f=0.02)[1600]
    assert abs((b.d1ht_ms - a.d1ht_ms) - 0.02 * 2.0) < 1e-9
