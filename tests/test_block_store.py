"""Replicated KV-block data plane (DESIGN.md §11): the dict-of-dicts
oracle twin-check over arbitrary churn sequences.

The properties under test (ISSUE 7 satellite):

  * **replication invariant** — after any sequence of puts/overwrites/
    removes interleaved with joins, graceful leaves, crashes, and same-ID
    rejoins, a single ``sync()`` (convergence) restores every live block
    to ``min(r, live peers)`` live, checksum-valid, up-to-date copies on
    exactly its current replica set;
  * **no torn or stale reads** — ``get`` always returns the last value
    the oracle wrote (or None once removed/lost), never an old version
    surfaced by a rejoining disk and never a checksum-broken copy;
  * **tombstones** — a removed block stays dead even when a stale copy
    rejoins later;
  * **O(affected) repair traffic** — a sync with no membership change
    since the previous one checks zero keys and copies zero bytes.

The hypothesis property skips when hypothesis is absent (the runtime
image bakes in jax + numpy only); the fixed-seed randomized twin below
always runs and covers the same invariants.
"""
import numpy as np
import pytest

from repro.core.ringstate import RingState
from repro.dht.data import (BlockMeta, BlockStore, PrefixCache, pack_array,
                            unpack_array)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

R = 3
# small spread-out id pool so replica sets are controllable
POOL = [(i + 1) * (2**64 // 12) % 2**64 for i in range(11)]
KEYS = [(i * 2**64) // 7 + 5 for i in range(7)]


def _fresh(n=6):
    state = RingState()
    for pid in POOL[:n]:
        state.add(pid)
    return state, BlockStore(state, replication=R)


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "float16", "int32", "uint8"])
def test_pack_roundtrip(dtype):
    arr = (np.arange(24).reshape(2, 3, 4) % 7).astype(dtype)
    out = unpack_array(pack_array(arr))
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


def test_pack_rejects_foreign_bytes():
    with pytest.raises(ValueError):
        unpack_array(b"not a block")


def test_block_meta_integrity():
    meta = BlockMeta.of(3, b"payload")
    assert meta.valid(b"payload")
    assert not meta.valid(b"payloaX")
    assert not meta.valid(b"payload-longer")


# ---------------------------------------------------------------------------
# core semantics
# ---------------------------------------------------------------------------

def test_put_places_on_replica_set_and_meters_uploads():
    state, store = _fresh()
    value = b"x" * 100
    store.put(KEYS[0], value)
    group = state.replica_set(KEYS[0], R)
    assert len(group) == R
    for node in group:
        meta, stored = store._nodes[node][KEYS[0]]
        assert stored == value and meta.version == 1
    assert store.upload_bytes == len(value) * R
    assert store.get(KEYS[0]) == value


def test_overwrite_bumps_version_everywhere():
    _, store = _fresh()
    store.put(KEYS[0], b"v1")
    meta = store.put(KEYS[0], b"v2")
    assert meta.version == 2
    assert store.get(KEYS[0]) == b"v2"
    assert all(c == R for c in store.replica_counts().values())


def test_remove_buries_and_blocks_resurrection():
    state, store = _fresh()
    store.put(KEYS[1], b"secret")
    holder = state.replica_set(KEYS[1], R)[0]
    state.remove(holder)                    # graceful leave: disk intact
    assert store.remove(KEYS[1])
    state.add(holder)                       # same-ID rejoin, stale copy
    assert store.get(KEYS[1]) is None       # tombstone wins
    store.sync()
    assert store.get(KEYS[1]) is None
    assert not store.contains(KEYS[1])


def test_put_after_remove_supersedes_tombstone():
    _, store = _fresh()
    store.put(KEYS[2], b"a")
    store.remove(KEYS[2])
    store.put(KEYS[2], b"b")
    assert store.get(KEYS[2]) == b"b"


def test_corrupt_copy_discarded_and_repaired():
    state, store = _fresh()
    store.put(KEYS[3], b"clean-bytes")
    victim = state.replica_set(KEYS[3], R)[1]
    meta, _ = store._nodes[victim][KEYS[3]]
    store._nodes[victim][KEYS[3]] = (meta, b"torn bytes!")   # bit rot
    assert store.get(KEYS[3]) == b"clean-bytes"
    assert store.corrupt_copies == 1
    # the read repaired the torn member back to the clean value
    assert store._nodes[victim][KEYS[3]][1] == b"clean-bytes"
    assert all(c == R for c in store.replica_counts().values())


def test_stale_rejoin_read_repairs_to_newest():
    state, store = _fresh()
    store.put(KEYS[4], b"old")
    holder = state.replica_set(KEYS[4], R)[0]
    state.remove(holder)                    # leave keeps the disk
    store.sync()
    store.put(KEYS[4], b"new")
    state.add(holder)                       # stale v1 copy resurfaces
    assert store.get(KEYS[4]) == b"new"     # never the stale version
    store.sync()
    assert all(c == R for c in store.replica_counts().values())


def test_crash_destroys_disk_and_sync_restores_r_copies():
    state, store = _fresh()
    store.put(KEYS[5], b"p" * 64)
    victim = state.replica_set(KEYS[5], R)[0]
    state.remove(victim)
    store.drop_node(victim)                 # crash: no disk to rejoin
    stats = store.sync()
    assert stats["repaired"] >= 1 and stats["lost"] == 0
    assert store.get(KEYS[5]) == b"p" * 64
    assert all(c == R for c in store.replica_counts().values())


def test_simultaneous_loss_of_all_replicas_is_surfaced():
    state, store = _fresh()
    store.put(KEYS[6], b"doomed")
    for node in state.replica_set(KEYS[6], R):
        state.remove(node)
        store.drop_node(node)
    stats = store.sync()
    assert stats["lost"] == 1 and store.lost_blocks == 1
    assert store.get(KEYS[6]) is None
    assert KEYS[6] not in store._placement  # no ghost placement entry


def test_sync_without_churn_is_free():
    _, store = _fresh()
    for k in KEYS:
        store.put(k, b"y" * 32)
    store.sync()
    stats = store.sync()                    # no membership change between
    assert stats == {"checked": 0, "repaired": 0,
                     "copied_bytes": 0, "lost": 0}


def test_string_names_hash_into_keyspace():
    _, store = _fresh()
    store.put("kv/sess-1/0", b"named")
    assert store.get("kv/sess-1/0") == b"named"
    assert store.contains("kv/sess-1/0")
    arr = np.arange(6, dtype=np.float32).reshape(2, 3)
    store.put_array("kv/sess-1/1", arr)
    np.testing.assert_array_equal(store.get_array("kv/sess-1/1"), arr)


# ---------------------------------------------------------------------------
# churn-sequence oracle twin (fixed seed — always runs)
# ---------------------------------------------------------------------------

def _apply_op(state, store, oracle, disks, op):
    """One churn/data op against both the store and the python oracle.
    ``oracle`` maps key -> expected bytes; ``disks`` tracks which left
    peers still hold a disk (graceful leave vs crash)."""
    kind = op[0]
    if kind == "put":
        _, key, payload = op
        store.put(key, payload)
        oracle[key] = payload
    elif kind == "remove":
        _, key = op
        store.remove(key)
        oracle.pop(key, None)
    elif kind == "leave":
        _, pid = op
        if len(state) > R:
            state.remove(pid)
            disks.add(pid)
    elif kind == "crash":
        _, pid = op
        if len(state) > R:
            state.remove(pid)
            store.drop_node(pid)
            disks.discard(pid)
    elif kind == "rejoin":
        _, pid = op
        state.add(pid)
        disks.discard(pid)
    elif kind == "sync":
        store.sync()


def _check_converged(state, store, oracle):
    store.sync()
    live = len(state)
    for key, expected in oracle.items():
        assert store.get(key) == expected, "stale or torn read"
    counts = store.replica_counts()
    for key in oracle:
        assert counts.get(key, 0) == min(R, live), \
            f"key {key}: {counts.get(key)} live replicas, want {min(R, live)}"
    # removed keys stay dead
    for key in set(counts) - set(oracle):
        assert store.get(key) is None


def _op_stream(rng, n_ops):
    ops = []
    for _ in range(n_ops):
        roll = rng.random()
        if roll < 0.40:
            ops.append(("put", KEYS[int(rng.integers(len(KEYS)))],
                        bytes(rng.integers(0, 256, size=int(
                            rng.integers(1, 64))).astype(np.uint8))))
        elif roll < 0.50:
            ops.append(("remove", KEYS[int(rng.integers(len(KEYS)))]))
        elif roll < 0.65:
            ops.append(("leave", POOL[int(rng.integers(len(POOL)))]))
        elif roll < 0.80:
            ops.append(("crash", POOL[int(rng.integers(len(POOL)))]))
        elif roll < 0.92:
            ops.append(("rejoin", POOL[int(rng.integers(len(POOL)))]))
        else:
            ops.append(("sync",))
    return ops


def test_replication_invariant_randomized_twin():
    rng = np.random.default_rng(11)
    for trial in range(25):
        state, store = _fresh()
        oracle, disks = {}, set()
        for op in _op_stream(rng, int(rng.integers(5, 40))):
            _apply_op(state, store, oracle, disks, op)
        _check_converged(state, store, oracle)


if HAVE_HYPOTHESIS:
    _key_st = st.sampled_from(KEYS)
    _pid_st = st.sampled_from(POOL)
    _op_st = st.one_of(
        st.tuples(st.just("put"), _key_st,
                  st.binary(min_size=1, max_size=48)),
        st.tuples(st.just("remove"), _key_st),
        st.tuples(st.just("leave"), _pid_st),
        st.tuples(st.just("crash"), _pid_st),
        st.tuples(st.just("rejoin"), _pid_st),
        st.tuples(st.just("sync")),
    )

    @settings(max_examples=60, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(_op_st, max_size=40))
    def test_hypothesis_replication_invariant(ops):
        """After ANY churn sequence plus convergence, every live block
        has min(r, live) live up-to-date replicas and reads match the
        oracle exactly."""
        state, store = _fresh()
        oracle, disks = {}, set()
        for op in ops:
            _apply_op(state, store, oracle, disks, op)
        _check_converged(state, store, oracle)


# ---------------------------------------------------------------------------
# prefix cache
# ---------------------------------------------------------------------------

def test_prefix_cache_content_addressing():
    _, store = _fresh()
    pc = PrefixCache(store, chunk=4, salt="m0")
    shared = np.arange(12, dtype=np.int32)
    blk = np.full((2, 4), 7, np.float32)
    pc.insert(shared, 0, blk)
    pc.insert(shared, 4, np.full((2, 4), 8, np.float32))
    # a DIFFERENT session with the same first 8 tokens hits both chunks
    other = np.concatenate([shared[:8], np.array([99, 98, 97, 96, 95],
                                                 np.int32)])
    covered, blocks = pc.match(other)
    assert covered == 8 and len(blocks) == 2
    np.testing.assert_array_equal(blocks[0], blk)
    # diverging at token 5 kills the second chunk (whole-prefix hashing)
    fork = shared.copy()
    fork[5] = 1000
    covered, blocks = pc.match(fork)
    assert covered == 4 and len(blocks) == 1


def test_prefix_cache_never_covers_final_segment():
    _, store = _fresh()
    pc = PrefixCache(store, chunk=4)
    toks = np.arange(8, dtype=np.int32)
    pc.insert(toks, 0, np.zeros((1, 4), np.float32))
    pc.insert(toks, 4, np.zeros((1, 4), np.float32))  # past max_cover: dropped
    assert pc.max_cover(8) == 4
    covered, blocks = pc.match(toks)
    assert covered == 4 and len(blocks) == 1          # final segment computed
    assert pc.max_cover(9) == 8
    assert pc.max_cover(4) == 0 and pc.max_cover(1) == 0


def test_prefix_cache_salt_isolates_models():
    _, store = _fresh()
    a = PrefixCache(store, chunk=4, salt="model-a")
    b = PrefixCache(store, chunk=4, salt="model-b")
    toks = np.arange(9, dtype=np.int32)
    a.insert(toks, 0, np.ones((1, 4), np.float32))
    covered, _ = b.match(toks)
    assert covered == 0                     # another checkpoint never hits
    covered, _ = a.match(toks)
    assert covered == 4


def test_prefix_cache_counters():
    _, store = _fresh()
    pc = PrefixCache(store, chunk=2)
    toks = np.arange(7, dtype=np.int32)
    for off in (0, 2, 4):
        pc.insert(toks, off, np.float32(off) * np.ones((1, 2), np.float32))
    assert pc.misses == 3
    covered, blocks = pc.match(toks)
    assert covered == 6 and pc.hits == 3 and pc.tokens_saved == 6
