"""Autotune cache behavior + device-memory budget validation.

The search layer is exercised with a fake bench (no accelerator needed):
the contract under test is cache round-tripping, hit-without-research,
and corrupt-file/invalid-entry degradation to the hand-picked defaults.
"""
import json

import pytest

from repro.kernels import autotune
from repro.kernels import backend


@pytest.fixture
def compiled_cache(tmp_path, monkeypatch):
    """Pretend we are on a compiled backend with a private cache file."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    monkeypatch.setattr(autotune, "_is_interpret", lambda: False)
    monkeypatch.setattr(autotune, "_backend_key", lambda: "test:fake-tpu")
    yield path


def _counting_bench(order):
    """bench(tiles) spy: records calls, ranks candidates by ``order``."""
    calls = []

    def bench(tiles):
        calls.append(dict(tiles))
        return float(order(tiles))

    bench.calls = calls
    return bench


def test_search_persists_winner_and_roundtrips(compiled_cache):
    bench = _counting_bench(lambda t: abs(t["bq"] - 512))  # 512 wins
    tiles = autotune.autotune_kernel("ring_lookup_bucketed", {"q": 1000},
                                     bench=bench)
    assert tiles == {"bq": 512}
    assert len(bench.calls) == len(autotune.CANDIDATES["ring_lookup_bucketed"])
    data = json.loads(compiled_cache.read_text())
    assert data["version"] == autotune.CACHE_VERSION
    key = "test:fake-tpu/ring_lookup_bucketed/q1024"  # 1000 -> pow2 bucket
    assert data["entries"][key]["tiles"] == {"bq": 512}
    # resolution consults the same entry (q=1000 and q=1024 share it)
    assert autotune.tiles_for("ring_lookup_bucketed", q=1024) == {"bq": 512}


def test_cache_hit_returns_without_research(compiled_cache):
    first = _counting_bench(lambda t: t["bq"])
    autotune.autotune_kernel("ring_lookup_bucketed", {"q": 512}, bench=first)
    again = _counting_bench(lambda t: t["bq"])
    tiles = autotune.autotune_kernel("ring_lookup_bucketed", {"q": 512},
                                     bench=again)
    assert again.calls == []          # hit: no candidate was ever timed
    assert tiles == {"bq": 256}       # the persisted winner
    forced = _counting_bench(lambda t: -t["bq"])   # force: 2048 wins now
    tiles = autotune.autotune_kernel("ring_lookup_bucketed", {"q": 512},
                                     bench=forced, force=True)
    assert forced.calls != []
    assert tiles == {"bq": 2048}


def test_corrupt_cache_degrades_to_defaults(compiled_cache):
    compiled_cache.write_text("{ not json !!!")
    assert autotune.load_cache() == {"version": autotune.CACHE_VERSION,
                                     "entries": {}}
    assert autotune.tiles_for("ring_lookup", q=1024, n=4096) \
        == autotune.DEFAULTS["ring_lookup"]
    # a search over a corrupt file rewrites it cleanly
    bench = _counting_bench(lambda t: t["bq"] + t["bt"])
    autotune.autotune_kernel("ring_lookup", {"q": 1024, "n": 4096},
                             bench=bench)
    data = json.loads(compiled_cache.read_text())
    assert data["entries"]


def test_wrong_version_cache_ignored(compiled_cache):
    compiled_cache.write_text(json.dumps(
        {"version": 999, "entries": {"x": {"tiles": {"bq": 1}}}}))
    assert autotune.load_cache()["entries"] == {}


def test_invalid_cached_tiles_fall_back(compiled_cache):
    """A stale entry violating the call's divisibility constraint must
    not reach the kernel (decode_attention asserts s % bs == 0)."""
    key = "test:fake-tpu/decode_attention/" + autotune.shape_bucket(s=384)
    autotune._save_cache({"version": autotune.CACHE_VERSION, "entries": {
        key: {"tiles": {"bs": 512}}}})
    assert autotune.tiles_for("decode_attention", s=384) \
        == autotune.DEFAULTS["decode_attention"]


def test_interpret_mode_returns_defaults_without_io(tmp_path, monkeypatch):
    path = tmp_path / "never-created.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    monkeypatch.setattr(autotune, "_is_interpret", lambda: True)
    assert autotune.tiles_for("flash_attention", sq=256, sk=256) \
        == autotune.DEFAULTS["flash_attention"]
    bench = _counting_bench(lambda t: 0.0)
    tiles = autotune.autotune_kernel("flash_attention",
                                     {"sq": 256, "sk": 256}, bench=bench)
    assert tiles == autotune.DEFAULTS["flash_attention"]
    assert bench.calls == []          # no search against the interpreter
    assert not path.exists()          # and no file I/O at all


def test_shape_bucket_rounds_to_pow2():
    assert autotune.shape_bucket(q=1000, n=70_000) == "n131072_q1024"
    assert autotune.shape_bucket(q=1024) == "q1024"
    assert autotune.shape_bucket(s=1) == "s1"


# ---------------------------------------------------------------------------
# bucket_budget_bytes: device-memory validation (regression: the 8 MB
# compiled-path constant must yield to a smaller device's reported memory)
# ---------------------------------------------------------------------------

@pytest.fixture
def budget_caches():
    backend.bucket_budget_bytes.cache_clear()
    yield
    backend.bucket_budget_bytes.cache_clear()


def test_budget_interpret_mode(budget_caches, monkeypatch):
    monkeypatch.setattr(backend, "default_interpret", lambda: True)
    assert backend.bucket_budget_bytes() == 256 << 20


def test_budget_compiled_unknown_memory(budget_caches, monkeypatch):
    monkeypatch.setattr(backend, "default_interpret", lambda: False)
    monkeypatch.setattr(backend, "_device_memory_bytes", lambda: None)
    assert backend.bucket_budget_bytes() == 8 << 20


def test_budget_capped_by_small_device(budget_caches, monkeypatch):
    monkeypatch.setattr(backend, "default_interpret", lambda: False)
    monkeypatch.setattr(backend, "_device_memory_bytes", lambda: 64 << 20)
    assert backend.bucket_budget_bytes() == 4 << 20      # mem // 16
    backend.bucket_budget_bytes.cache_clear()
    monkeypatch.setattr(backend, "_device_memory_bytes", lambda: 32 << 30)
    assert backend.bucket_budget_bytes() == 8 << 20      # constant wins
    backend.bucket_budget_bytes.cache_clear()
    monkeypatch.setattr(backend, "_device_memory_bytes", lambda: 4 << 20)
    assert backend.bucket_budget_bytes() == 1 << 20      # floor


def test_budget_reads_memory_stats_from_device(budget_caches, monkeypatch):
    """End-to-end through _device_memory_bytes with a fake jax device."""
    import jax

    class FakeDev:
        def memory_stats(self):
            return {"bytes_limit": 128 << 20}

    monkeypatch.setattr(backend, "default_interpret", lambda: False)
    monkeypatch.setattr(jax, "devices", lambda *a: [FakeDev()])
    assert backend.bucket_budget_bytes() == 8 << 20      # 128MB/16 = 8MB
    backend.bucket_budget_bytes.cache_clear()

    class TinyDev:
        def memory_stats(self):
            return {"bytes_reservable_limit": 48 << 20}

    monkeypatch.setattr(jax, "devices", lambda *a: [TinyDev()])
    assert backend.bucket_budget_bytes() == 3 << 20
