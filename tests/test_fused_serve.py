"""Fused route→gather→decode rounds + chunked/overlapped prefill:
dispatch accounting, token identity against the unfused and
whole-prompt paths, trace attribution, and pending-failure safety."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.runtime import Membership
from repro.serve import Replica, Request, ServeCluster


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _membership(n, t):
    m = Membership(t_q=60.0, now=lambda: t[0])
    for i in range(n):
        m.request_join(f"10.3.0.{i}", 7000 + i)
    return m


def _requests(cfg, count, *, max_new=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(f"s{i}",
                    rng.integers(0, cfg.vocab, 4 + (i % 4) * 3,
                                 dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(count)]


def _reference_tokens(model, params, prompt, steps, max_len):
    cache = model.init_cache(1, max_len)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache)
    toks = [int(jnp.argmax(logits[0]))]
    dec = jax.jit(model.decode_step)
    length = len(prompt)
    for _ in range(steps - 1):
        logits, cache = dec(params, cache,
                            jnp.asarray([[toks[-1]]], jnp.int32),
                            jnp.asarray([length], jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
        length += 1
    return toks


def _count_calls(rep, names, counter):
    for name in names:
        orig = getattr(rep, name)

        def wrapped(*a, __orig=orig, __name=name, **kw):
            counter[__name] = counter.get(__name, 0) + 1
            return __orig(*a, **kw)

        setattr(rep, name, wrapped)


# ---------------------------------------------------------------------------
# dispatch accounting: one fused program per round, no host-side routing
# ---------------------------------------------------------------------------

def test_fused_round_is_one_program_and_no_host_lookup(smoke_model):
    """With fusion forced, every replica's decode round must enter the
    device through exactly ONE fused program — never the unfused decode
    pair, never a separate ``RingState.lookup`` dispatch."""
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(4, t)
    cluster = ServeCluster(m, model, params, slots=8, max_len=64,
                           fused=True)
    for r in _requests(cfg, 6, max_new=6):
        cluster.submit(r)
    cluster.step()          # warm: traces + the one-time route calibration
    counts = {}
    busy = 0
    for rep in cluster.replicas.values():
        busy += bool(rep.sessions)
        _count_calls(rep, ("_decode_full_fused", "_decode_slots_fused",
                           "_decode_full", "_decode_slots"), counts)

    def no_lookup(*a, **kw):
        raise AssertionError("host-side RingState.lookup during a fused "
                             "decode round")

    cluster.state.lookup = no_lookup
    before = cluster.fused_rounds
    cluster.step()
    del cluster.state.lookup
    fused_calls = counts.get("_decode_full_fused", 0) \
        + counts.get("_decode_slots_fused", 0)
    assert fused_calls == busy          # one fused dispatch per busy replica
    assert counts.get("_decode_full", 0) == 0
    assert counts.get("_decode_slots", 0) == 0
    assert cluster.fused_rounds == before + busy
    assert cluster.fused_routed_keys > 0


def test_fused_tokens_identical_to_unfused(smoke_model):
    """Fusing the route into the decode program must not move a single
    token: same membership, same requests, transcript-for-transcript."""
    cfg, model, params = smoke_model
    outs = {}
    for fused in (True, False):
        t = [0.0]
        cluster = ServeCluster(_membership(4, t), model, params, slots=8,
                               max_len=64, fused=fused)
        for r in _requests(cfg, 6, max_new=8, seed=3):
            cluster.submit(r)
        cluster.run()
        outs[fused] = {sid: list(rec.generated)
                       for sid, rec in cluster.sessions.items()}
    assert outs[True] == outs[False]


def test_fused_rounds_populate_trace_splits(smoke_model):
    """RequestTrace must keep its route/decode split under fusion: the
    round is one dispatch, so the split comes from the calibrated
    per-key route cost — both legs must land nonzero."""
    cfg, model, params = smoke_model
    t = [0.0]
    cluster = ServeCluster(_membership(4, t), model, params, slots=8,
                           max_len=64, fused=True)
    for r in _requests(cfg, 4, max_new=6, seed=7):
        cluster.submit(r)
    base_route = {sid: tr.route_us for sid, tr in cluster.traces.items()}
    cluster.run()
    assert cluster.fused_rounds > 0
    assert cluster._route_cal_us_per_key is not None
    for sid, tr in cluster.traces.items():
        assert tr.done
        assert tr.decode_us > 0
        # the fused rounds added route share on top of the submit walk
        assert tr.route_us >= base_route[sid]
    assert sum(tr.route_us - base_route[sid]
               for sid, tr in cluster.traces.items()) > 0


# ---------------------------------------------------------------------------
# chunked prefill: fixed-shape segments vs whole-prompt, sync and overlapped
# ---------------------------------------------------------------------------

def test_chunked_prefill_matches_whole_prompt(smoke_model):
    """admit() through the fixed-shape segment loop must produce the
    same first token and the same decode stream as the whole-prompt
    prefill (same slab, same positions)."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (3, 8, 13, 21)]     # below/at/above chunk multiples
    streams = {}
    for chunk in (8, None):
        rep = Replica(model, slots=4, max_len=48, prefill_chunk=chunk)
        rep.attach_params(params)
        got = {f"c{i}": [rep.admit(Request(f"c{i}", p))]
               for i, p in enumerate(prompts)}
        for _ in range(5):
            for sid, tok in rep.decode_round().items():
                got[sid].append(tok)
        streams[chunk] = got
    assert streams[8] == streams[None]
    for i, p in enumerate(prompts):
        want = _reference_tokens(model, params, p, 6, 48)
        assert streams[8][f"c{i}"] == want


def test_overlapped_prefill_completes_like_sync_admit(smoke_model):
    """begin_admit parks the prefill; advancing it chunk-by-chunk while
    a sibling decodes must yield the sync path's exact tokens, and the
    pending session must stay invisible to decode until it lands."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab, 21, dtype=np.int32)
    sib = rng.integers(0, cfg.vocab, 5, dtype=np.int32)
    rep = Replica(model, slots=4, max_len=48, prefill_chunk=8)
    rep.attach_params(params)
    sib_toks = [rep.admit(Request("sib", sib))]
    assert rep.begin_admit(Request("ovl", prompt)) is None
    assert rep.num_pending == 1 and "ovl" not in rep.sessions
    ovl_toks = []
    while rep.num_pending:
        sib_toks.extend(rep.decode_round().values())   # decode overlaps
        ovl_toks.extend(rep.advance_prefills().values())
    assert len(ovl_toks) == 1
    for _ in range(4):
        for sid, tok in rep.decode_round().items():
            (sib_toks if sid == "sib" else ovl_toks).append(tok)
    assert ovl_toks == _reference_tokens(model, params, prompt, 5, 48)
    assert sib_toks == _reference_tokens(model, params, sib,
                                         len(sib_toks), 48)


def test_failed_pending_prefill_releases_slot_and_spares_siblings(
        smoke_model):
    """One bad pending must not discard a sibling's completion or leak
    its reserved slot (advance_prefills catches per-session)."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(17)
    rep = Replica(model, slots=4, max_len=48, prefill_chunk=8)
    rep.attach_params(params)
    free0 = rep.num_free
    assert rep.begin_admit(
        Request("good", rng.integers(0, cfg.vocab, 7, dtype=np.int32))) \
        is None
    assert rep.begin_admit(
        Request("bad", rng.integers(0, cfg.vocab, 9, dtype=np.int32))) \
        is None
    rep._pending["bad"]["prompt"] = None       # poison: chunk slice raises
    done = rep.advance_prefills()
    assert "good" in done                      # 7 tokens = one chunk
    assert rep.failed_prefills == ["bad"]
    assert "bad" not in rep._pending and "bad" not in rep.sessions
    assert rep.num_free == free0 - 1           # bad's slot came back
    assert rep.decode_round().keys() == {"good"}


# ---------------------------------------------------------------------------
# overlapped migration end-to-end (fused rounds + chunked re-prefill)
# ---------------------------------------------------------------------------

def test_migration_tokens_identical_under_fused_overlap(smoke_model):
    """Kill an owner mid-decode with fusion + chunked re-prefill on:
    every session must complete with the single-session reference
    stream, straight through the overlapped migration."""
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(5, t)
    cluster = ServeCluster(m, model, params, slots=16, max_len=64,
                           fused=True, prefill_chunk=8, prefill_duty=2)
    for r in _requests(cfg, 8, max_new=10, seed=21):
        cluster.submit(r)
    for _ in range(2):
        cluster.step()
    by_owner = {}
    for rec in cluster.sessions.values():
        by_owner.setdefault(rec.owner, []).append(rec)
    victim = max(by_owner, key=lambda o: len(by_owner[o]))
    m.fail(victim)
    assert all(rec.owner != victim for rec in cluster.sessions.values())
    rounds = 0
    while cluster.live_sessions:
        cluster.step()
        rounds += 1
        assert rounds < 128
    assert cluster.pending_migrations == 0
    assert cluster.migrated_sessions >= len(by_owner[victim])
    for rec in cluster.sessions.values():
        want = _reference_tokens(model, params, rec.prompt, 10, 64)
        assert rec.generated == want, f"{rec.session_id} diverged"


def test_failed_overlapped_migration_restrands_and_recovers(smoke_model):
    """A re-prefill that dies mid-chunk must re-strand the session (slot
    released, no phantom) and a later round must re-home it — the
    transcript still completes bit-identical to the reference."""
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(5, t)
    cluster = ServeCluster(m, model, params, slots=16, max_len=64,
                           prefill_chunk=8, prefill_duty=1)
    for r in _requests(cfg, 8, max_new=10, seed=23):
        cluster.submit(r)
    cluster.step()
    by_owner = {}
    for rec in cluster.sessions.values():
        by_owner.setdefault(rec.owner, []).append(rec)
    victim = max(by_owner, key=lambda o: len(by_owner[o]))
    m.fail(victim)
    assert cluster.pending_migrations > 0
    sid = next(iter(cluster._pending_homes))
    node = cluster._pending_homes[sid]["node"]
    cluster.replicas[node]._pending[sid]["prompt"] = None    # poison
    rounds = 0
    while cluster.live_sessions:
        cluster.step()
        rounds += 1
        assert rounds < 128
    rec = cluster.sessions[sid]
    assert rec.done and rec.migrations >= 2    # initial + post-failure
    want = _reference_tokens(model, params, rec.prompt, 10, 64)
    assert rec.generated == want

# ---------------------------------------------------------------------------
# repro-lint RL003 regression: greedy argmax lives INSIDE the program
# ---------------------------------------------------------------------------

def test_decode_argmax_is_fused_into_the_program(smoke_model, monkeypatch):
    """Regression (repro-lint RL003): the per-round greedy pick must
    ride inside the compiled decode program — the host sees only the B
    int32 token transfer, never a (B, V) logits readback.  Warm the
    bucket's trace, then poison host-side ``jnp.argmax``: decode rounds
    must keep producing the reference stream without ever calling it."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(29)
    prompts = [rng.integers(0, cfg.vocab, 5, dtype=np.int32)
               for _ in range(3)]
    rep = Replica(model, slots=4, max_len=48)
    rep.attach_params(params)
    streams = {f"a{i}": [rep.admit(Request(f"a{i}", p))]
               for i, p in enumerate(prompts)}
    for sid, tok in rep.decode_round().items():    # warm this bucket's trace
        streams[sid].append(tok)

    def poisoned(*a, **kw):
        raise AssertionError("host-side jnp.argmax in the decode loop")

    monkeypatch.setattr(jnp, "argmax", poisoned)
    for _ in range(4):
        for sid, tok in rep.decode_round().items():
            streams[sid].append(tok)
    monkeypatch.undo()
    for i, p in enumerate(prompts):
        want = _reference_tokens(model, params, p, 6, 48)
        assert streams[f"a{i}"] == want
