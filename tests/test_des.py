"""Protocol-level DES integration tests (paper §VII methodology)."""
import random

import pytest

from repro.core.ring import RoutingTable, build_ring
from repro.core.tuning import EdraParams
from repro.dht import ChurnConfig, run_churn
from repro.dht.calot_node import CalotPeer
from repro.dht.d1ht_node import D1HTPeer
from repro.dht.des import LanDelay, SimNet, SimPeer


def _static_net(cls, n, seed=0):
    net = SimNet(LanDelay(), seed=seed)
    params = EdraParams.derive(n, 174 * 60)
    ids = list(build_ring(n, seed=seed).ids)
    for pid in ids:
        net.add_peer(cls(pid, net, params))
    net.ring = RoutingTable(ids)
    rng = random.Random(seed + 1)
    for pid in ids:
        p = net.peers[pid]
        p.table = RoutingTable(ids)
        net.schedule(rng.random() * max(params.theta, 1.0),
                     (lambda q: (lambda: q.start()))(p))
    net.run_until(40)
    return net, params, ids


class _SinkPeer(SimPeer):
    """Minimal live peer: receives datagrams, does nothing."""

    def start(self):
        self.alive = True

    def stop(self, *, crash):
        self.alive = False


def _two_peer_net(seed=3):
    net = SimNet(LanDelay(), seed=seed)
    for pid in (1, 2):
        p = _SinkPeer(pid, net)
        p.alive = True
        net.add_peer(p)
    return net


def test_metering_captured_at_send_time_warmup_edge():
    """Regression (ISSUE 5): ``SimNet.send`` read ``self.metering`` at
    DELIVERY time inside the deliver closure, so a datagram straddling
    the warmup->measurement boundary metered its recv and ack without
    its send — §VII-A accounting was biased at the window edge.  A
    warmup datagram delivered inside the window must now count
    nowhere."""
    net = _two_peer_net()
    net.metering = False                  # still warming up at send time
    net.send(1, 2, 320, "maint")
    net.metering = True                   # window opens mid-flight
    net.run_until(1.0)
    assert net.meters[2].in_bits == 0, "recv leg metered without its send"
    assert net.meters[2].out_bits == 0, "ack leg metered without its send"
    assert net.meters[1].in_bits == 0
    assert net.meters[1].out_bits == 0


def test_metering_sticks_through_window_close():
    """The converse edge: a datagram sent INSIDE the window but
    delivered after it closes keeps its recv/ack legs — the exchange
    belongs, whole, to the window that sent it."""
    net = _two_peer_net()
    net.metering = True
    net.send(1, 2, 320, "maint")
    net.metering = False                  # window closes mid-flight
    net.run_until(1.0)
    assert net.meters[1].out_bits == 320
    assert net.meters[2].in_bits == 320
    assert net.meters[2].out_bits == 288  # the v_a ack
    assert net.meters[1].in_bits == 288


def test_lan_delay_mean_matches_docstring():
    """Regression: the 10 us floor used to be ADDED to an Exp(70 us)
    draw, inflating the realized mean to ~80 us.  The shifted
    exponential must realize the documented 70 us one-way mean while
    keeping the floor as a hard lower bound."""
    rng = random.Random(0)
    d = LanDelay()
    xs = [d.sample(rng) for _ in range(200_000)]
    assert min(xs) >= 10e-6
    assert sum(xs) / len(xs) == pytest.approx(70e-6, rel=0.02)


@pytest.mark.parametrize("cls", [D1HTPeer, CalotPeer])
def test_single_crash_reaches_all_peers(cls):
    net, params, ids = _static_net(cls, 48)
    victim = ids[10]
    net.peers[victim].stop(crash=True)
    net.ring.remove(victim)
    net.run_until(40 + 30 * params.theta)
    stale = [p for p in ids if p != victim
             and victim in net.peers[p].table]
    assert not stale


@pytest.mark.parametrize("cls", [D1HTPeer, CalotPeer])
def test_voluntary_leave_faster_than_crash(cls):
    net, params, ids = _static_net(cls, 32)
    victim = ids[3]
    net.peers[victim].stop(crash=False)    # flush + notify successor
    net.ring.remove(victim)
    net.run_until(40 + 6 * params.theta)   # well under T_detect-based path
    stale = [p for p in ids if p != victim and victim in net.peers[p].table]
    assert not stale


def test_join_protocol_propagates():
    net, params, ids = _static_net(D1HTPeer, 32)
    joiner = ids[7]
    net.peers[joiner].stop(crash=True)
    net.ring.remove(joiner)
    net.run_until(net.now + 30 * params.theta)
    succ = net.ring.successor_of(joiner)
    net.send(joiner, succ, 288, "join-request", None)
    net.ring.add(joiner)
    net.run_until(net.now + 30 * params.theta)
    missing = [p for p in ids if joiner not in net.peers[p].table
               and net.is_alive(p)]
    assert not missing


@pytest.mark.slow
def test_churn_one_hop_fraction_c1():
    """Paper C1: >99% of lookups solved with one hop under churn."""
    r = run_churn(ChurnConfig(n=256, s_avg=174 * 60, duration=600,
                              warmup=120, protocol="d1ht", seed=11))
    assert r.one_hop_fraction >= 0.99


@pytest.mark.slow
def test_churn_bandwidth_matches_analysis_c5():
    r = run_churn(ChurnConfig(n=256, s_avg=60 * 60, duration=600,
                              warmup=120, protocol="d1ht", seed=5))
    ratio = r.mean_out_bps / r.analytical_bps
    assert 0.6 < ratio < 1.4, ratio


@pytest.mark.slow
def test_quarantine_reduces_traffic_in_des():
    base = run_churn(ChurnConfig(n=200, s_avg=174 * 60, duration=600,
                                 warmup=120, protocol="d1ht", seed=7,
                                 volatile_fraction=0.31))
    quar = run_churn(ChurnConfig(n=200, s_avg=174 * 60, duration=600,
                                 warmup=120, protocol="d1ht", seed=7,
                                 volatile_fraction=0.31,
                                 quarantine_tq=600.0))
    assert quar.mean_out_bps < base.mean_out_bps
    assert quar.quarantine_skipped > 0
    assert quar.one_hop_fraction >= 0.985
