"""Per-arch smoke tests: one forward/train step on CPU with a reduced
config of the same family — shapes + finiteness + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.launch.inputs import dummy_batch, input_specs
from repro.models import Model

TRAIN_SHAPE = ShapeConfig("smoke_train", 64, 4, "train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_finite(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = dummy_batch(cfg, TRAIN_SHAPE)
    loss = jax.jit(m.train_loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # uniform-vocab sanity: CE near ln(vocab) at init
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_finite(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S, MAX = 2, 8, 16
    batch = dummy_batch(cfg, ShapeConfig("p", S, B, "prefill"))
    cache = m.init_cache(B, MAX)
    logits, cache = jax.jit(m.prefill)(params, batch, cache)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    dec = jax.jit(m.decode_step)
    for i in range(3):
        logits, cache = dec(params, cache, tok, jnp.asarray(S + i, jnp.int32))
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "falcon-mamba-7b",
                                  "zamba2-7b", "deepseek-v2-236b",
                                  "qwen3-moe-235b-a22b"])
def test_decode_matches_teacher_forcing(arch):
    """Prefill(t0..t6) + decode(t7) logits == prefill(t0..t7) logits.

    Exercises cache correctness for GQA, SSM state carry, hybrid shared
    attention, and absorbed-MLA decode.  MoE configs run DROPLESS here
    (capacity = S*k) — capacity dropping legitimately depends on batch
    composition, which would mask cache bugs."""
    cfg = get_smoke_config(arch)
    if cfg.moe_experts:
        cfg = cfg.with_overrides(moe_capacity_factor=float(cfg.moe_experts))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, size=(2, 8), dtype=np.int32)

    cache_a = m.init_cache(2, 16)
    la, cache_a = jax.jit(m.prefill)(params, {"tokens": jnp.asarray(toks[:, :7])},
                                     cache_a)
    la2, _ = jax.jit(m.decode_step)(params, cache_a,
                                    jnp.asarray(toks[:, 7:8]),
                                    jnp.asarray(7, jnp.int32))
    cache_b = m.init_cache(2, 16)
    lb, _ = jax.jit(m.prefill)(params, {"tokens": jnp.asarray(toks)}, cache_b)
    err = float(jnp.max(jnp.abs(la2 - lb)))
    assert err < 0.15, err   # bf16 accumulation tolerance


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "whisper-small": dict(num_layers=12, d_model=768, num_heads=12,
                              d_ff=3072, vocab=51865),
        "qwen3-moe-235b-a22b": dict(num_layers=94, d_model=4096,
                                    num_heads=64, num_kv_heads=4,
                                    moe_experts=128, moe_top_k=8,
                                    vocab=151936),
        "deepseek-v2-236b": dict(num_layers=60, d_model=5120, num_heads=128,
                                 moe_experts=160, moe_top_k=6,
                                 moe_shared_experts=2, mla_kv_lora=512,
                                 vocab=102400),
        "nemotron-4-15b": dict(num_layers=32, d_model=6144, num_heads=48,
                               num_kv_heads=8, d_ff=24576, vocab=256000,
                               act="relu2"),
        "internlm2-20b": dict(num_layers=48, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab=92544),
        "qwen2.5-3b": dict(num_layers=36, d_model=2048, num_heads=16,
                           num_kv_heads=2, d_ff=11008, vocab=151936,
                           qkv_bias=True),
        "command-r-35b": dict(num_layers=40, d_model=8192, num_heads=64,
                              num_kv_heads=8, d_ff=22528, vocab=256000),
        "falcon-mamba-7b": dict(num_layers=64, d_model=4096, ssm_state=16,
                                mamba_version=1, vocab=65024),
        "zamba2-7b": dict(num_layers=81, d_model=3584, num_heads=32,
                          d_ff=14336, ssm_state=64, mamba_version=2,
                          vocab=32000),
        "internvl2-2b": dict(num_layers=24, d_model=2048, num_heads=16,
                             num_kv_heads=8, d_ff=8192, vocab=92553),
    }[arch]
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_in_expected_range():
    """Sanity: full-config parameter counts near the advertised sizes."""
    expects = {"qwen2.5-3b": (2.5e9, 4.2e9),
               "internlm2-20b": (17e9, 23e9),
               "command-r-35b": (30e9, 40e9),
               "falcon-mamba-7b": (6e9, 8.5e9),
               "zamba2-7b": (6e9, 9e9),
               "deepseek-v2-236b": (210e9, 260e9),
               "qwen3-moe-235b-a22b": (200e9, 260e9),
               "nemotron-4-15b": (13e9, 18e9)}
    for arch, (lo, hi) in expects.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_input_specs_cover_all_cells():
    from repro.configs.registry import shape_cells
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shp in shape_cells(arch):
            specs = input_specs(cfg, shp)
            assert "tokens" in specs
            for v in specs.values():
                assert all(d > 0 for d in v.shape)
