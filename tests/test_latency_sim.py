"""Measured request-latency plane (DESIGN.md §9): closed-loop queue
calibration against queueing theory, system orderings, measured-vs-model
agreement, and the saturation-measurement machinery."""
import struct

import numpy as np
import pytest

from repro.dht.latency_sim import (DirectoryWorker, PeerWorker,
                                   ServiceProfile, closed_loop_fcfs,
                                   latency_point,
                                   measure_worker_service_us,
                                   simulate_pastry, simulate_single_hop)

# a synthetic profile pins the measured quantities so the tests are
# deterministic and runner-speed-independent (the real measurement is
# exercised separately below and by bench_latency)
PROFILE = ServiceProfile(route_us_per_key=0.5, dserver_service_us=10.4,
                         peer_service_us=9.0, table_n=4000, requests=0)
FP = {"d1ht": 0.01, "calot": 0.012}


# ---------------------------------------------------------------------------
# closed-loop FCFS generator vs queueing theory
# ---------------------------------------------------------------------------

def test_closed_loop_matches_mdl_below_saturation():
    """Sub-saturation the closed loop is an M/D/1 queue: mean sojourn =
    S + S*rho/(2(1-rho))."""
    rng = np.random.default_rng(0)
    s = 10e-6
    soj = closed_loop_fcfs(rng, clients=800, think_s=1 / 30.0,
                           service_s=s, window_s=4.0)
    rho = 800 * 30.0 * s
    want = s + s * rho / (2 * (1 - rho))
    assert soj.mean() == pytest.approx(want, rel=0.15)


def test_closed_loop_saturated_hits_littles_law_cap():
    """Past saturation the server never idles: throughput is 1/S and
    Little's law pins the mean sojourn at N*S - Z."""
    rng = np.random.default_rng(1)
    s = 10e-6
    soj = closed_loop_fcfs(rng, clients=4000, think_s=1 / 30.0,
                           service_s=s, window_s=4.0)
    cap = 4000 * s - 1 / 30.0 + s
    assert soj.mean() == pytest.approx(cap, rel=0.2)
    assert soj.size == pytest.approx(4.0 / s, rel=0.1)   # service-bound


def test_closed_loop_empty_window():
    rng = np.random.default_rng(2)
    out = closed_loop_fcfs(rng, clients=4, think_s=10.0, service_s=1e-6,
                           window_s=0.001)
    assert out.size == 0


# ---------------------------------------------------------------------------
# per-system simulators
# ---------------------------------------------------------------------------

def test_single_hop_retry_fraction_shows_in_the_mean():
    rng = np.random.default_rng(3)
    kw = dict(requests=60_000, service_us=9.0, busy_mult=1.0,
              route_us_per_key=0.5)
    base = simulate_single_hop(rng, retry_fraction=0.0, **kw)
    retry = simulate_single_hop(rng, retry_fraction=0.05, **kw)
    # each retry pays the 2 ms timeout + a second full attempt
    assert (retry.mean() - base.mean()) * 1e3 == pytest.approx(
        0.05 * (2.0 + 0.14 + 0.009), rel=0.25)


def test_single_hop_flat_in_n_pastry_grows():
    rng = np.random.default_rng(4)
    kw = dict(requests=40_000, service_us=9.0, busy_mult=1.0)
    p1600 = simulate_pastry(rng, n=1600, **kw)
    p105 = simulate_pastry(rng, n=10**5, **kw)
    s = simulate_single_hop(rng, retry_fraction=0.01,
                            route_us_per_key=0.5, **kw)
    assert p1600.mean() > 3 * s.mean()        # log4(1600) ~ 5.3 hops
    assert p105.mean() > 1.4 * p1600.mean()   # and it grows with log n


def test_latency_point_reproduces_fig5_shape():
    """Sub-saturation: D1HT ~ dserver, every system within the
    cross-validation ratio band.  Past the (synthetic) saturation
    point: dserver diverges by >5x while D1HT stands still."""
    sub = latency_point(800, busy=False, profile=PROFILE, fprime=FP,
                        requests=20_000, window_s=2.0, drive_kernel=False,
                        seed=1)
    assert sub["sub_saturation"]
    s = sub["systems"]
    assert s["dserver"]["mean_ms"] < 1.5 * s["d1ht"]["mean_ms"]
    for name in ("d1ht", "calot", "pastry", "dserver"):
        assert 0.7 <= s[name]["ratio_measured_over_model"] <= 1.4, (
            name, s[name])

    sat = latency_point(4000, busy=False, profile=PROFILE, fprime=FP,
                        requests=20_000, window_s=2.0, drive_kernel=False,
                        seed=1)
    assert not sat["sub_saturation"]
    t = sat["systems"]
    assert t["dserver"]["mean_ms"] > 5 * t["d1ht"]["mean_ms"]
    assert t["d1ht"]["mean_ms"] == pytest.approx(s["d1ht"]["mean_ms"],
                                                 rel=0.1)   # C1: flat


def test_latency_point_drives_the_real_lookup_kernel():
    """With ``drive_kernel=True`` the route component is measured off
    real batched RingState lookups (bucketized at n >= threshold)."""
    row = latency_point(2400, busy=False, profile=PROFILE, fprime=FP,
                        requests=4096, window_s=0.5, drive_kernel=True,
                        seed=2)
    assert row["systems"]["d1ht"]["mean_ms"] > 0.1   # legs dominate
    assert row["systems"]["d1ht"]["requests"] == 4096


def test_busy_factor_inflates_both_planes_alike():
    idle = latency_point(1600, busy=False, profile=PROFILE, fprime=FP,
                         requests=20_000, window_s=1.0, drive_kernel=False)
    busy = latency_point(1600, busy=True, profile=PROFILE, fprime=FP,
                         requests=20_000, window_s=1.0, drive_kernel=False)
    b, i = busy["systems"], idle["systems"]
    assert b["d1ht"]["mean_ms"] > 1.2 * i["d1ht"]["mean_ms"]
    # the ratio stays in band because model and sim share busy_factor
    assert 0.7 <= b["d1ht"]["ratio_measured_over_model"] <= 1.4


# ---------------------------------------------------------------------------
# the measurement machinery itself
# ---------------------------------------------------------------------------

def test_directory_worker_resolves_the_successor():
    ids = [100, 200, 300]
    w = DirectoryWorker(ids)
    from repro.core.ring import hash_id
    reply = w.handle(b"abc")
    key, owner = struct.unpack("!QQ", reply)
    assert key == hash_id("session/abc")
    import bisect
    assert owner == ids[bisect.bisect_left(ids, key) % 3]


def test_peer_worker_answers_from_local_store():
    w = PeerWorker(entries=8)
    (val,) = struct.unpack("!Q", w.handle(b"s3"))
    assert val == 3
    (miss,) = struct.unpack("!Q", w.handle(b"nope"))
    assert miss == 0


def test_saturation_measurement_returns_sane_service_time():
    """The real measurement on this host: a saturated local worker must
    land between 0.2 us (nothing measurable) and 1 ms (pathological) per
    request — the bench gates everything else relatively."""
    us = measure_worker_service_us(DirectoryWorker(list(range(1, 4001))),
                                   requests=3000, repeats=1)
    assert 0.2 < us < 1000.0
