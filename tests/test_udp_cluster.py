"""Live asyncio/UDP D1HT ring on loopback: join, converge, crash, detect.

Runs the actual datagram protocol (Fig. 2-style wire format) with real
sockets — the deployment path of the same EDRA state machine the DES
verifies deterministically."""
import asyncio

import pytest

from repro.core.tuning import EdraParams
from repro.dht.udp_node import UdpD1HTPeer

BASE_PORT = 39120
N = 8


async def _converged(peers, expect_n, timeout=20.0):
    deadline = asyncio.get_event_loop().time() + timeout
    while asyncio.get_event_loop().time() < deadline:
        if all(len(p.table) == expect_n for p in peers if p.running):
            return True
        await asyncio.sleep(0.2)
    return False


@pytest.mark.slow
def test_live_udp_ring_join_and_crash():
    async def scenario():
        params = EdraParams.derive(N, 174 * 60).retune(N, 2.0)  # fast Θ
        peers = [UdpD1HTPeer("127.0.0.1", BASE_PORT + i, params)
                 for i in range(N)]
        await peers[0].start()
        for p in peers[1:]:
            await p.join(("127.0.0.1", BASE_PORT))
            await asyncio.sleep(0.15)
        assert await _converged(peers, N), \
            [len(p.table) for p in peers]

        # one-hop check: every peer resolves every key to the same owner
        owners = {p.table.owner("some/key") for p in peers}
        assert len(owners) == 1

        # crash a peer: Rule 5 detection + EDRA dissemination over UDP
        victim = peers[3]
        await victim.stop()
        alive = [p for p in peers if p is not victim]
        assert await _converged(alive, N - 1, timeout=30.0), \
            [len(p.table) for p in alive]
        for p in alive:
            assert victim.id not in p.table

        for p in alive:
            await p.stop()

    asyncio.run(scenario())
