"""Replica slot-engine interleavings (ISSUE 5 satellite): hypothesis
property tests over admit/evict/decode_round sequences — including
FAILING admits (bad tokens) and oversize rejections — with the slab
invariants checked after every operation:

  * slot conservation: the free list and the session slots partition the
    slab (a slot is never leaked, never double-freed, never shared);
  * phantom-session invariant: ``sessions.keys()`` ⊆ active slots after
    ANY exception (the pre-fix admit left a phantom session whose slot
    had ``active=False``, poisoning every later decode_round);
  * evicted/free rows are zeroed (stale lengths used to survive).

The hypothesis tests skip when hypothesis is absent (the runtime image
bakes in jax + numpy only); the fixed-seed randomized twin below always
runs and covers the same invariants.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serve import Replica, Request

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

SLOTS = 3
MAX_LEN = 24
SIDS = ("a", "b", "c", "d")


@pytest.fixture(scope="module")
def model_params():
    cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32")
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _check_invariants(rep: Replica) -> None:
    owned = list(rep.sessions.values())
    free = rep._free
    assert len(free) == len(set(free)), "double-freed slot"
    assert len(owned) == len(set(owned)), "two sessions share a slot"
    assert not (set(free) & set(owned)), "slot both free and owned"
    assert len(free) + len(owned) == rep.slots, "slot leaked"
    for s in owned:
        assert rep.active[s], "phantom session: owned slot inactive"
    for s in free:
        assert not rep.active[s]
        assert rep.lengths[s] == 0, "stale length on a free slot"
        assert rep.tokens[s, 0] == 0, "stale token on a free slot"


def _run_ops(cfg, model, params, ops) -> None:
    rep = Replica(model, slots=SLOTS, max_len=MAX_LEN)
    rep.attach_params(params)
    for op in ops:
        kind = op[0]
        if kind == "admit":
            _, sid, plen, fail = op
            if fail:
                # bad tokens: fails INSIDE prefill, after validation
                prompt = np.array(["tok"] * plen, dtype=object)
            else:
                prompt = (np.arange(plen) % cfg.vocab).astype(np.int32)
            try:
                rep.admit(Request(sid, prompt, max_new_tokens=8))
            except RuntimeError:
                assert rep.num_free == 0      # only a full replica rejects
            except Exception:
                assert fail, "healthy admit must not raise"
        elif kind == "admit_oversize":
            with pytest.raises(ValueError):
                rep.admit(Request(op[1], np.zeros(MAX_LEN, np.int32)))
        elif kind == "evict":
            rep.evict(op[1])
        else:                                 # decode round
            out = rep.decode_round()
            assert set(out) == set(rep.sessions)
        _check_invariants(rep)


def _op_list_from_rng(rng, length: int):
    ops = []
    for _ in range(length):
        r = rng.integers(0, 10)
        sid = SIDS[rng.integers(0, len(SIDS))]
        if r < 5:
            ops.append(("admit", sid, int(rng.integers(1, 7)),
                        bool(rng.integers(0, 3) == 0)))
        elif r < 7:
            ops.append(("evict", sid))
        elif r < 8:
            ops.append(("admit_oversize", sid))
        else:
            ops.append(("decode",))
    return ops


def test_slot_engine_random_interleavings(model_params):
    """Always-run twin of the hypothesis property (fixed seeds)."""
    cfg, model, params = model_params
    rng = np.random.default_rng(7)
    for _ in range(12):
        _run_ops(cfg, model, params, _op_list_from_rng(rng, 10))


def test_full_replica_of_failed_admits_stays_usable(model_params):
    """Saturate the slab through a mix of failures: the free list must
    come back to full size via evictions, never shrink through leaks."""
    cfg, model, params = model_params
    rep = Replica(model, slots=SLOTS, max_len=MAX_LEN)
    rep.attach_params(params)
    for i in range(SLOTS + 2):                # overfill on purpose
        try:
            rep.admit(Request(f"s{i}", np.arange(3, dtype=np.int32)))
        except RuntimeError:
            pass
        with pytest.raises(Exception):
            rep.admit(Request(f"bad{i}", np.array(["x"], dtype=object)))
        _check_invariants(rep)
    assert rep.num_active == SLOTS
    for i in range(SLOTS):
        rep.evict(f"s{i}")
        _check_invariants(rep)
    assert rep.num_free == SLOTS


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.one_of(
            st.tuples(st.just("admit"), st.sampled_from(SIDS),
                      st.integers(1, 6), st.booleans()),
            st.tuples(st.just("evict"), st.sampled_from(SIDS)),
            st.tuples(st.just("admit_oversize"), st.sampled_from(SIDS)),
            st.tuples(st.just("decode")),
        ), max_size=12)

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=_ops)
    def test_slot_engine_interleavings_hypothesis(model_params, ops):
        cfg, model, params = model_params
        _run_ops(cfg, model, params, ops)
